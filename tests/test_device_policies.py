"""The pluggable device-policy plane (repro.serving.policies).

Mechanical invariants every registered policy must satisfy to ride the
fused serve loop — fixed plan capacity, per-lane active gating, owner
consistency through `apply_migrations`, zero retraces on state-value
changes — plus the bitwise pin that `importance` IS the planner the
engine shipped with, and the one-executable-per-policy serve-stream
assert.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.placement import POLICIES
from repro.core.tiers import GH200
from repro.kvcache.migrate import apply_migrations
from repro.kvcache.paged import CacheGeometry, prefill_cache
from repro.models.model import Model
from repro.serving import control
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.policies import make_policy, policy_names
from repro.serving.scheduler import Request

BUDGET = 2


def _geo():
    return CacheGeometry(num_layers=2, batch=2, page_tokens=4,
                         hbm_pages=2, host_pages=6, kv_heads=2,
                         head_dim=8, dtype=jnp.float32)


def _cfg(policy="importance"):
    return EngineConfig(policy=policy, attention_sparsity=0.5,
                        promote_thresh=0.02, migration_budget_frac=1.0,
                        spec=GH200)


def _cache():
    """Seven alive pages (2 HBM, 5 host) with an importance profile
    that makes every dynamic policy want at least one move: page 1
    (HBM) is cold and outside the Quest mask; pages 2 and 6 (host) are
    hot / recent."""
    geo = _geo()
    rng = np.random.default_rng(0)
    kv = jnp.asarray(rng.standard_normal((2, 2, 28, 2, 8)), jnp.float32)
    cache = prefill_cache(geo, kv, kv, 28)
    imp = np.tile(np.asarray(
        [0.5, 0.01, 0.9, 0.3, 0.05, 0.02, 0.2, 0.0], np.float32),
        (2, 2, 1))
    return geo, dataclasses.replace(cache, importance=jnp.asarray(imp))


def assert_owner_consistent(cache):
    """page_table and the two owner maps must stay a bijection."""
    pt = np.asarray(cache.page_table)
    ho = np.asarray(cache.hbm_owner)
    eo = np.asarray(cache.host_owner)
    L, B, _ = pt.shape
    hbm = ho.shape[2]
    for l in range(L):
        for b in range(B):
            for s, page in enumerate(ho[l, b]):
                if page >= 0:
                    assert pt[l, b, page] == s, (l, b, s, page)
            for s, page in enumerate(eo[l, b]):
                if page >= 0:
                    assert pt[l, b, page] == hbm + s, (l, b, s, page)
            for page, slot in enumerate(pt[l, b]):
                if slot >= 0:
                    if slot < hbm:
                        assert ho[l, b, slot] == page, (l, b, page, slot)
                    else:
                        assert eo[l, b, slot - hbm] == page, \
                            (l, b, page, slot)
            owned = [p for p in ho[l, b] if p >= 0] + \
                [p for p in eo[l, b] if p >= 0]
            assert len(owned) == len(set(owned)), (l, b, owned)


@pytest.mark.parametrize("name", policy_names())
class TestPolicyInvariants:
    def test_plan_capacity_is_geometry_constant(self, name):
        geo, cache = _cache()
        pol = make_policy(name, cfg=_cfg(name), geo=geo)
        plan, _, (n_pro, n_dem) = pol.plan(
            cache, pol.init_state(geo), None, BUDGET)
        capacity = geo.num_layers * geo.batch * BUDGET
        for field in dataclasses.fields(plan):
            assert getattr(plan, field.name).shape == (capacity,), \
                field.name
        got_pro, got_dem = plan.row_counts()
        assert int(got_pro) == int(n_pro) <= capacity
        assert int(got_dem) == int(n_dem) <= capacity
        assert int(n_dem) <= int(n_pro)      # demotes pair with promotes

    def test_inactive_lanes_plan_zero_moves(self, name):
        geo, cache = _cache()
        pol = make_policy(name, cfg=_cfg(name), geo=geo)
        active = jnp.asarray([True, False])
        plan, _, _ = pol.plan(cache, pol.init_state(geo), active, BUDGET)
        for rows in (plan.pro_batch, plan.dem_batch):
            rows = np.asarray(rows)
            assert not np.any(rows == 1), (name, rows)

    def test_owner_maps_consistent_through_apply(self, name):
        geo, cache = _cache()
        pol = make_policy(name, cfg=_cfg(name), geo=geo)
        state = pol.init_state(geo)
        for _ in range(3):
            plan, state, _ = pol.plan(cache, state, None, BUDGET)
            cache = apply_migrations(cache, plan)
            assert_owner_consistent(cache)

    def test_zero_retraces_on_state_value_changes(self, name):
        geo, cache = _cache()
        pol = make_policy(name, cfg=_cfg(name), geo=geo)

        @jax.jit
        def planner(cache, state):
            return pol.plan(cache, state, None, BUDGET)

        state = pol.init_state(geo)
        _, state, _ = planner(cache, state)
        bumped = jax.tree.map(lambda x: x + 1, state)
        hotter = dataclasses.replace(
            cache, importance=cache.importance * 0.5 + 0.1)
        planner(hotter, bumped)
        assert planner._cache_size() == 1


class TestPolicyBehaviour:
    def test_every_dynamic_policy_plans_a_move(self):
        """The fixture cache is built so each dynamic policy has at
        least one profitable move — a policy that never migrates
        under these conditions is wired wrong."""
        geo, cache = _cache()
        for name in policy_names():
            if name == "static":
                continue
            pol = make_policy(name, cfg=_cfg(name), geo=geo)
            _, _, (n_pro, _) = pol.plan(
                cache, pol.init_state(geo), None, BUDGET)
            assert int(n_pro) >= 1, name

    def test_static_plans_nothing(self):
        geo, cache = _cache()
        pol = make_policy("static", cfg=_cfg("static"), geo=geo)
        plan, _, (n_pro, n_dem) = pol.plan(
            cache, pol.init_state(geo), None, BUDGET)
        assert int(n_pro) == 0 and int(n_dem) == 0
        assert np.all(np.asarray(plan.pro_layer) == -1)
        # applying the empty plan is a bitwise no-op
        after = apply_migrations(cache, plan)
        for field in dataclasses.fields(cache):
            np.testing.assert_array_equal(
                np.asarray(getattr(cache, field.name)),
                np.asarray(getattr(after, field.name)))

    def test_importance_policy_is_plan_migrations(self):
        """Bitwise pin: the extracted `importance` policy reproduces
        `control.plan_migrations` row for row."""
        geo, cache = _cache()
        cfg = _cfg("importance")
        pol = make_policy("importance", cfg=cfg, geo=geo)
        for active in (None, jnp.asarray([True, False])):
            got, _, (g_pro, g_dem) = pol.plan(
                cache, pol.init_state(geo), active, BUDGET)
            want, w_pro, w_dem = control.plan_migrations(
                cache, budget=BUDGET,
                promote_thresh=cfg.promote_thresh, active=active)
            for field in dataclasses.fields(want):
                np.testing.assert_array_equal(
                    np.asarray(getattr(got, field.name)),
                    np.asarray(getattr(want, field.name)))
            assert int(g_pro) == int(w_pro)
            assert int(g_dem) == int(w_dem)

    def test_cost_aware_threshold_scales_with_link(self):
        """A harsher link (TPU PCIe vs GH200 NVLink-C2C) must raise
        the promote bar."""
        from repro.core.placement.cost_aware import payback_threshold
        from repro.core.tiers import TPU_V5E
        assert payback_threshold(TPU_V5E, 4.0) > \
            payback_threshold(GH200, 4.0)

    def test_sim_policies_name_live_counterparts(self):
        """Cross-layer interface: every simulator policy that claims a
        live mirror must point at a registered device policy."""
        mirrored = {cls.device_counterpart
                    for cls in POLICIES.values()
                    if cls.device_counterpart is not None}
        assert mirrored <= set(policy_names()), mirrored
        assert {"static", "recency", "cost_aware", "quest"} <= mirrored

    def test_unknown_policy_rejected_at_construction(self):
        cfg = configs.get_smoke("internlm2-1.8b")
        model = Model(cfg)
        with pytest.raises(ValueError, match="importance"):
            ServingEngine(model, None, EngineConfig(policy="lru"))


@pytest.fixture(scope="module")
def dense_model():
    cfg = configs.get_smoke("internlm2-1.8b")
    m = Model(cfg)
    return m, m.init(jax.random.key(0))


@pytest.mark.parametrize("name", policy_names())
def test_serve_stream_one_executable_per_policy(dense_model, name):
    """Acceptance pin: every registered policy drives the FULL serve
    stream — mixed prompt lengths, admissions, completions — on ONE
    compiled executable."""
    model, params = dense_model
    eng = ServingEngine(model, params, EngineConfig(
        max_context=128, hbm_fraction=0.25, policy=name,
        attention_sparsity=0.0, spec=GH200, promote_thresh=1e-4,
        telemetry_stride=4, prefill_chunk=16))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, model.cfg.vocab,
                                        (16 + 16 * (i % 2),)),
                    max_new_tokens=3 + i)
            for i in range(3)]
    report = eng.serve(reqs, num_slots=2, seed=0)
    assert len(report) == 3
    assert all(len(r.output) == r.max_new_tokens for r in report)
    assert eng._serve_jit._cache_size() == 1
    assert eng.batcher.free_pages == eng.batcher.total_pages
