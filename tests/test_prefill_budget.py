"""Per-BATCH prefill token budget (EngineConfig.prefill_budget).

The ROADMAP PR 3 follow-up: `prefill_chunk` bounds each LANE's slice,
but a wave of prefilling lanes still taxes every mixed step with a full
prefill-plane execution. The token bucket caps the batch's aggregate
prefill rate, so under a heavy wave most steps skip the prefill plane
entirely (lax.cond) — decode TPOT improves while the wave's TTFT
stretches. Greedy streams must be token-for-token unchanged: for them
the budget is a SCHEDULE, not a semantic (sampled streams draw from a
shifted point of the per-lane key chain, since keys advance every
step and the budget moves the prefill-to-decode crossing).
"""

import jax
import numpy as np
import pytest

from repro import configs
from repro.core.tiers import GH200
from repro.models.model import Model
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.scheduler import Request


@pytest.fixture(scope="module")
def dense_model():
    cfg = configs.get_smoke("internlm2-1.8b")
    m = Model(cfg)
    return m, m.init(jax.random.key(0))


def _engine(model, params, budget):
    return ServingEngine(model, params, EngineConfig(
        max_context=256, hbm_fraction=0.25, policy="importance",
        attention_sparsity=0.0, spec=GH200, promote_thresh=1e-4,
        telemetry_stride=4, prefill_chunk=32, prefill_budget=budget))


def _stream(vocab, *, waves=8):
    """One decode-heavy request admitted first, then a heavy prefill
    wave: more long prompts than spare lanes, so prefill demand
    outlasts the decode request's lifetime."""
    rng = np.random.default_rng(7)
    reqs = [Request(rid=0, prompt=rng.integers(0, vocab, (16,)),
                    max_new_tokens=40)]
    reqs += [Request(rid=1 + i, prompt=rng.integers(0, vocab, (160,)),
                     max_new_tokens=2)
             for i in range(waves)]
    return reqs


def test_budget_validation(dense_model):
    model, params = dense_model
    with pytest.raises(ValueError, match="prefill_budget"):
        ServingEngine(model, params, EngineConfig(prefill_budget=0))


def test_budget_changes_schedule_not_tokens(dense_model):
    """Greedy outputs must be bitwise identical with and without the
    cap — each lane's tokens depend only on its own prompt and
    history, and the bucket only re-times prefill slices."""
    model, params = dense_model
    outs = {}
    for budget in (None, 32):
        eng = _engine(model, params, budget)
        report = eng.serve(_stream(model.cfg.vocab, waves=4),
                           num_slots=4, seed=0)
        outs[budget] = {r.rid: list(r.output) for r in report}
        assert len(report) == 5
    assert outs[None] == outs[32]


def test_decode_tpot_improves_under_heavy_wave(dense_model):
    """The backlog (25 x 160-token prompts through 3 spare lanes)
    saturates prefill demand past the decode request's whole lifetime
    in BOTH runs — uncapped, nearly every one of its decode steps pays
    a full prefill-plane execution (3 staggered lanes leave few
    prefill-free steps); capped at one lane-chunk per step (32 tokens
    vs ~96 wanted), roughly two of three steps skip the plane via the
    lax.cond. The decode request's measured TPOT must improve."""
    model, params = dense_model

    engines = {}
    for budget in (None, 32):
        engines[budget] = _engine(model, params, budget)
        engines[budget].serve(_stream(model.cfg.vocab, waves=4),
                              num_slots=4, seed=0)          # warm/compile

    def measure(budget):
        report = engines[budget].serve(
            _stream(model.cfg.vocab, waves=25), num_slots=4, seed=0)
        r = next(r for r in report if r.rid == 0)
        assert len(r.output) == 40
        return ((r.finished_at - r.first_token_at)
                / (len(r.output) - 1),
                max(x.finished_step for x in report))

    # interleave the two arms and keep each arm's minimum: the serve
    # schedule is deterministic and load spikes on shared CI runners
    # only ever inflate wall time (and are correlated in time, so
    # alternating arms exposes both to the same bursts) — the per-arm
    # min is the clean estimate
    best = {None: np.inf, 32: np.inf}
    steps = {}
    for _ in range(3):
        for budget in (None, 32):
            t, steps[budget] = measure(budget)
            best[budget] = min(best[budget], t)
    uncapped, capped = best[None], best[32]
    steps_uncapped, steps_capped = steps[None], steps[32]
    assert capped < uncapped, (capped, uncapped)
    # ... and it is a TRADE, not a free lunch: the capped stream's
    # prefill work spreads over strictly more steps, so the wave
    # itself drains later (deterministic — a step count, not a clock)
    assert steps_capped > steps_uncapped, (steps_capped, steps_uncapped)
