"""Per-architecture smoke tests: every assigned arch instantiates a
reduced config, runs one forward and one train step on CPU, asserts
output shapes + no NaNs. (Full configs are exercised only via the
allocation-free dry-run.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.model import Model
from repro.training.train_step import init_train_state, make_train_step

ARCHS = configs.all_arch_names()


def _extra_for(cfg, B, rng):
    extra = {}
    if cfg.family == "vlm":
        extra["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend.num_embeddings,
                                 cfg.d_model)) * 0.02, jnp.float32)
    if cfg.family == "encdec":
        extra["frame_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend.num_embeddings,
                                 cfg.d_model)) * 0.02, jnp.float32)
    return extra


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = configs.get_smoke(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 16
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    extra = _extra_for(cfg, B, rng)
    logits = model.forward(params, tokens, extra=extra)
    if isinstance(logits, tuple):
        logits = logits[0]
    exp_s = S + (cfg.frontend.num_embeddings if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_s, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = configs.get_smoke(arch)
    model = Model(cfg)
    state = init_train_state(model, jax.random.key(1))
    B, S = 2, 16
    rng = np.random.default_rng(1)
    extra = _extra_for(cfg, B, rng)
    step = make_train_step(model, extra_keys=tuple(extra), lr=1e-3)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32), **extra}
    new_state, metrics = jax.jit(step)(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss)
    assert loss > 0
    # params actually changed
    delta = max(float(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)).max())
                for a, b in zip(jax.tree.leaves(state.params),
                                jax.tree.leaves(new_state.params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_schema_consistency(arch):
    """The FULL config's schema must be constructible abstractly (no
    allocation) and its logical axes tree must mirror the param tree."""
    cfg = configs.get(arch)
    model = Model(cfg)
    abstract = model.abstract_params()
    axes = model.logical_axes()
    flat_a = jax.tree.leaves(abstract)
    flat_x = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    assert len(flat_a) == len(flat_x)
    for leaf, ax in zip(flat_a, flat_x):
        assert len(leaf.shape) == len(ax), (leaf.shape, ax)
    # param_count sanity: within 2x of the schema's true count
    from repro.models.params import count_params
    true = count_params(model.schema())
    approx = cfg.param_count()
    assert 0.3 < approx / true < 3.0, (approx, true)
