"""SLO plane: TTFT decomposition, SLO-aware admission shedding,
goodput scoring, and real-EOS termination of sampled traffic.

The regression contracts (repro.serving.slo / ServingEngine.serve):

  * per-request `queue_wait_s + prefill_s + throttle_s == TTFT`, exact
    up to float rounding of the chunk-stride stamps — with a tight
    `prefill_budget` forcing genuinely nonzero throttle time;
  * "timeout"/"cancelled" and SLO-shed are mutually exclusive: a
    queued request with an expired deadline is always the reaper's,
    never converted into an "slo_shed" rejection;
  * shedding removes only QUEUED requests (live lanes finish), tier
    targets select who sheds, every shed is a typed "rejected";
  * `score_goodput` counts exactly the "ok"-within-scaled-targets
    fraction, wall or modeled latency;
  * sampled (non-greedy) streams stop on the model config's REAL
    `eos_id` within budget, with consistent EOS statistics on the
    report (the stale-tokenizer follow-up: no probed sentinel ids).
"""

import time

import jax
import numpy as np
import pytest

from repro import configs
from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.serving import (
    EngineConfig, Request, SLOPolicy, SLOTarget, ServingEngine,
    TERMINAL_STATUSES, score_goodput,
)
from repro.serving.engine import ServeReport
from repro.serving.sampling import SamplingConfig
from repro.serving.slo import (
    DEFAULT_TIER, ttft_decomposition_residual,
)
from repro.core.tiers import GH200


@pytest.fixture(scope="module")
def dense_model():
    cfg = configs.get_smoke("internlm2-1.8b")
    m = Model(cfg)
    return m, m.init(jax.random.key(0))


def _cfg(**kw):
    return EngineConfig(max_context=128, hbm_fraction=0.25,
                        policy="importance", attention_sparsity=0.0,
                        spec=GH200, promote_thresh=0.005,
                        telemetry_stride=4, prefill_chunk=16, **kw)


def _mk_requests(vocab, n=4, seed=3, budget=6, plen=32, **kw):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, vocab, (plen,)),
                    max_new_tokens=budget, **kw) for i in range(n)]


# --------------------------------------------------------------------------- #
# SLOPolicy mechanics (pure, no model)
# --------------------------------------------------------------------------- #

class TestPolicy:
    def test_target_for_tier_fallback(self):
        pol = SLOPolicy({"interactive": SLOTarget(0.1, 0.01),
                         DEFAULT_TIER: SLOTarget(1.0, 0.1)})
        assert pol.target_for(Request(rid=0, tier="interactive")).ttft_s \
            == 0.1
        assert pol.target_for(Request(rid=1, tier="unknown")).ttft_s \
            == 1.0
        assert pol.target_for(Request(rid=2)).ttft_s == 1.0
        bare = SLOPolicy({"interactive": SLOTarget(0.1, 0.01)})
        assert bare.target_for(Request(rid=3, tier="batch")) is None

    def test_projection_counts_wait_and_prefill(self):
        pol = SLOPolicy.uniform(1.0, 0.1)
        r = Request(rid=0, prompt_len=33)
        r.submitted_at = 100.0
        # unknown cadence: projection is the wait alone
        assert pol.projected_ttft(r, 100.5, None, 16) == 0.5
        # 33 tokens / chunk 16 -> 3 steps at 0.2s each
        assert abs(pol.projected_ttft(r, 100.5, 0.2, 16)
                   - (0.5 + 0.6)) < 1e-12

    def test_should_shed_respects_slack(self):
        pol = SLOPolicy.uniform(1.0, 0.1, shed_slack=2.0)
        r = Request(rid=0, prompt_len=16)
        r.submitted_at = 0.0
        assert pol.should_shed(r, 1.5, None, 16) is None   # < 2x target
        reason = pol.should_shed(r, 2.5, None, 16)
        assert reason is not None and "target" in reason
        # no target -> never shed
        bare = SLOPolicy({})
        assert bare.should_shed(r, 1e9, None, 16) is None

    def test_scaled_target(self):
        t = SLOTarget(1.0, 0.1).scaled(2.0)
        assert t.ttft_s == 2.0 and t.tpot_s == 0.2


# --------------------------------------------------------------------------- #
# goodput scoring (pure, no model)
# --------------------------------------------------------------------------- #

def _stamped(rid, *, status="ok", ttft=0.5, tpot=0.05, n_out=4,
             tier=None):
    r = Request(rid=rid, prompt_len=8, max_new_tokens=n_out, tier=tier)
    r.status = status
    r.submitted_at = 100.0
    if status == "ok":
        r.first_token_at = 100.0 + ttft
        r.finished_at = r.first_token_at + tpot * (n_out - 1)
        r.output = list(range(n_out))
    return r


class TestGoodput:
    def test_wall_goodput_counts_within_target(self):
        from repro.serving.scheduler import RequestError
        pol = SLOPolicy.uniform(1.0, 0.1)
        fast = _stamped(0, ttft=0.5, tpot=0.05)
        slow = _stamped(1, ttft=2.0, tpot=0.05)         # misses TTFT
        shed = _stamped(2, status="rejected")
        shed.error = RequestError("slo_shed", "projected over target")
        rep = ServeReport.build([fast, slow], [shed])
        out = score_goodput(rep, pol)
        assert out["good_requests"] == 1
        assert out["total_requests"] == 3
        assert abs(out["goodput"] - 1 / 3) < 1e-12
        assert out["shed_requests"] == 1
        assert rep.goodput == out                       # stamped
        # looser scale admits the slow one; shed never recovers
        loose = score_goodput(rep, pol, scale=4.0)
        assert loose["good_requests"] == 2

    def test_modeled_goodput_reads_request_scores(self):
        pol = SLOPolicy.uniform(1.0, 0.1)
        a = _stamped(0, ttft=50.0)      # wall TTFT hopeless: ignored
        b = _stamped(1, ttft=50.0)
        rep = ServeReport.build([a, b])
        rep.request_scores.update({
            0: {"steps": 4.0, "live_total_s": 0.2},     # tpot 0.05: good
            1: {"steps": 4.0, "live_total_s": 0.8},     # tpot 0.2: bad
        })
        out = score_goodput(rep, pol, latency="modeled")
        assert out["good_requests"] == 1
        # a request with no modeled score cannot be judged good
        rep2 = ServeReport.build([a])
        assert score_goodput(rep2, pol,
                             latency="modeled")["good_requests"] == 0

    def test_per_tier_split(self):
        pol = SLOPolicy({"interactive": SLOTarget(1.0, 0.1),
                         "batch": SLOTarget(100.0, 10.0)})
        rep = ServeReport.build([
            _stamped(0, tier="interactive", ttft=0.5),
            _stamped(1, tier="interactive", ttft=5.0),
            _stamped(2, tier="batch", ttft=5.0)])
        out = score_goodput(rep, pol)
        assert out["per_tier"]["interactive"] == \
            {"good": 1, "total": 2, "goodput": 0.5}
        assert out["per_tier"]["batch"]["goodput"] == 1.0


# --------------------------------------------------------------------------- #
# TTFT decomposition: queue_wait + prefill + throttle == TTFT
# --------------------------------------------------------------------------- #

class TestDecomposition:
    def test_identity_with_throttle(self, dense_model):
        """Tight prefill budget (8 tokens/step vs 2 lanes x 16 demand)
        forces bucket-starved steps: throttle_s must be genuinely
        nonzero and the three parts must still sum to TTFT exactly
        (float rounding of the chunk-stride stamps only)."""
        model, params = dense_model
        eng = ServingEngine(model, params, _cfg(prefill_budget=8))
        reqs = _mk_requests(model.cfg.vocab, n=5, budget=6)
        report = eng.serve(reqs, num_slots=2, seed=0)
        assert all(s == "ok" for s in report.statuses.values())
        res = ttft_decomposition_residual(report)
        assert res.size == 5
        assert res.max() < 1e-5, res
        assert any(r.throttle_s > 0 for r in report.completed)
        assert all(r.prefill_s > 0 for r in report.completed)
        # later admissions genuinely queued behind the 2 slots
        waits = [r.queue_wait_s for r in report.completed]
        assert all(w is not None and w >= 0 for w in waits)
        assert max(waits) > min(waits)
        parts = report.ttft_parts
        assert set(parts) == {"queue_wait", "prefill", "throttle"}
        for row in parts.values():
            assert {"mean", "p50", "p95"} <= set(row)

    def test_identity_without_budget(self, dense_model):
        """Unbudgeted streams decompose too (throttle is then just the
        boundary host overhead)."""
        model, params = dense_model
        eng = ServingEngine(model, params, _cfg())
        report = eng.serve(_mk_requests(model.cfg.vocab), num_slots=2,
                           seed=0)
        res = ttft_decomposition_residual(report)
        assert res.size == 4 and res.max() < 1e-5, res


# --------------------------------------------------------------------------- #
# SLO-aware admission shedding
# --------------------------------------------------------------------------- #

class TestShedding:
    def test_tight_slo_sheds_queued_as_typed_rejection(self, dense_model):
        """An impossible target: the first `num_slots` requests admit
        at stream start (nobody has waited yet) and finish; every
        QUEUED request sheds as a typed "rejected"/"slo_shed" with an
        event each, and nothing ends in two statuses."""
        model, params = dense_model
        eng = ServingEngine(model, params, _cfg())
        reqs = _mk_requests(model.cfg.vocab, n=4, budget=4)
        report = eng.serve(reqs, num_slots=2, seed=0,
                           slo=SLOPolicy.uniform(0.0, 10.0))
        statuses = report.statuses
        assert len(statuses) == 4
        assert statuses[0] == "ok" and statuses[1] == "ok"
        assert statuses[2] == "rejected" and statuses[3] == "rejected"
        for r in report.rejected:
            assert r.error.code == "slo_shed"
            assert "target" in r.error.detail
        shed_events = [e for e in report.events
                       if e["kind"] == "slo_shed"]
        assert sorted(e["rid"] for e in shed_events) == [2, 3]

    def test_loose_slo_sheds_nothing(self, dense_model):
        model, params = dense_model
        eng = ServingEngine(model, params, _cfg())
        report = eng.serve(_mk_requests(model.cfg.vocab), num_slots=2,
                           seed=0, slo=SLOPolicy.uniform(300.0, 60.0))
        assert all(s == "ok" for s in report.statuses.values())
        assert not [e for e in report.events if e["kind"] == "slo_shed"]

    def test_tier_targets_select_who_sheds(self, dense_model):
        """Queued interactive requests shed under an impossible
        interactive target; queued batch requests (loose target) and
        already-admitted interactive ones keep serving."""
        model, params = dense_model
        eng = ServingEngine(model, params, _cfg())
        reqs = _mk_requests(model.cfg.vocab, n=6, budget=4)
        for i, r in enumerate(reqs):
            r.tier = "interactive" if i in (0, 1, 2, 4) else "batch"
        pol = SLOPolicy({"interactive": SLOTarget(0.0, 10.0),
                         "batch": SLOTarget(300.0, 60.0)})
        report = eng.serve(reqs, num_slots=2, seed=0, slo=pol)
        statuses = report.statuses
        # 0, 1 admitted before the first shed pass -> live -> finish;
        # queued interactive 2, 4 shed; batch 3, 5 survive the queue
        assert statuses[0] == "ok" and statuses[1] == "ok"
        assert statuses[2] == "rejected" and statuses[4] == "rejected"
        assert statuses[3] == "ok" and statuses[5] == "ok"
        for rid in (2, 4):
            victim = next(r for r in report.rejected if r.rid == rid)
            assert victim.error.code == "slo_shed"

    def test_timeout_and_shed_mutually_exclusive(self, dense_model):
        """A queued request with an expired deadline belongs to the
        reaper even under an impossible SLO: exactly one terminal
        status ("timeout"), no slo_shed event for it."""
        model, params = dense_model
        eng = ServingEngine(model, params, _cfg())
        reqs = _mk_requests(model.cfg.vocab, n=5, budget=4)
        reqs[3].deadline_s = 0.0                        # queued victim
        report = eng.serve(reqs, num_slots=2, seed=0,
                           slo=SLOPolicy.uniform(0.0, 10.0))
        statuses = report.statuses
        assert statuses[3] == "timeout"
        victim = next(r for r in report.completed + report.rejected
                      if r.rid == 3)
        assert victim.error.code == "deadline_exceeded"
        assert not [e for e in report.events
                    if e["kind"] == "slo_shed" and e["rid"] == 3]
        # every rid appears exactly once across completed + rejected
        rids = [r.rid for r in report.completed + report.rejected]
        assert sorted(rids) == sorted(set(rids))
        assert all(s in TERMINAL_STATUSES for s in statuses.values())

    def test_open_loop_arrivals_queue_wait_measured(self, dense_model):
        """arrival_s > 0 holds a request back: it is submitted at a
        later boundary and its submitted_at reflects the live submit,
        so queue_wait measures real queueing, not generation time."""
        model, params = dense_model
        eng = ServingEngine(model, params, _cfg())
        reqs = _mk_requests(model.cfg.vocab, n=3, budget=4)
        reqs[2].arrival_s = 0.15
        t0 = time.time()
        report = eng.serve(reqs, num_slots=2, seed=0)
        assert all(s == "ok" for s in report.statuses.values())
        late = next(r for r in report.completed if r.rid == 2)
        assert late.submitted_at >= t0 + 0.15
        assert late.first_token_at is not None


# --------------------------------------------------------------------------- #
# sampled traffic terminates on the config's REAL eos id
# --------------------------------------------------------------------------- #

class TestEOS:
    def test_model_config_validates_eos(self):
        with pytest.raises(AssertionError):
            ModelConfig(name="bad", family="dense", num_layers=1,
                        d_model=32, num_heads=2, kv_heads=2, d_ff=64,
                        vocab=16, head_dim=16, eos_id=16)

    def test_public_configs_carry_eos(self):
        assert configs.get_smoke("internlm2-1.8b").eos_id == 2
        from repro.configs.llama31_8b import CONFIG as llama
        assert llama.eos_id == 128001
        from repro.configs.qwen3_32b import CONFIG as qwen
        assert qwen.eos_id == 151645

    def test_sampled_stream_stops_on_real_eos(self):
        """Tiny vocab (16) + high temperature: every decode step has
        ~1/16 chance of drawing the real eos id, so over 4 requests x
        48-token budgets at a pinned seed the probability NO stream
        stops on EOS is ~(15/16)^192 ~ 4e-6. Structural contracts hold
        regardless: termination within budget, per-request stop_reason
        consistent with the emitted tokens, report EOS statistics
        consistent with stop reasons."""
        cfg = ModelConfig(name="eos-smoke", family="dense",
                          num_layers=2, d_model=32, num_heads=2,
                          kv_heads=2, d_ff=64, vocab=16, head_dim=16,
                          eos_id=3)
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        eng = ServingEngine(model, params, _cfg(eos_id=cfg.eos_id))
        budget = 48
        reqs = _mk_requests(cfg.vocab, n=4, budget=budget, plen=16)
        report = eng.serve(
            reqs, num_slots=2, seed=7,
            sampling=SamplingConfig(temperature=1.5))
        assert all(s == "ok" for s in report.statuses.values())
        assert report.eos["eos_id"] == 3
        for r in report.completed:
            assert 1 <= len(r.output) <= budget
            if r.stop_reason == "eos":
                assert r.output[-1] == 3
                assert len(r.output) <= budget
            else:
                assert r.stop_reason == "budget"
                assert len(r.output) == budget
        assert report.eos["eos_stops"] == sum(
            1 for r in report.completed if r.stop_reason == "eos")
        assert report.eos["budget_stops"] == sum(
            1 for r in report.completed if r.stop_reason == "budget")
        assert report.eos["eos_stops"] + report.eos["budget_stops"] \
            == len(report.completed)
        assert report.eos["eos_stops"] >= 1    # P(fail) ~ 4e-6, pinned

    def test_greedy_budget_stream_reports_budget_stops(self, dense_model):
        """Without an eos_id the engine never stops early and every ok
        request reports stop_reason "budget" (the pre-EOS behavior,
        bitwise unchanged)."""
        model, params = dense_model
        eng = ServingEngine(model, params, _cfg())
        report = eng.serve(_mk_requests(model.cfg.vocab, budget=5),
                           num_slots=2, seed=0)
        assert report.eos["eos_id"] is None
        assert report.eos["eos_stops"] == 0
        assert all(r.stop_reason == "budget" and len(r.output) == 5
                   for r in report.completed)
