"""Eq. (1)-(5) latency model: hand-computed cases + property invariants."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.latency_model import (
    StepTraffic, dram_latency, hbm_latency, step_latency, total_latency,
)
from repro.core.tiers import GH200, MemorySystemSpec, SPECS, TPU_V5E

SIMPLE = MemorySystemSpec(name="simple", hbm_bw=100.0, hbm_capacity=1e9,
                          link_bw=10.0, dram_bw=20.0, dram_capacity=1e12)


class TestHandComputed:
    def test_eq3_hbm(self):
        t = StepTraffic(h_read=50.0, h_write=10.0, m_in=20.0, m_out=20.0)
        assert hbm_latency(t, SIMPLE) == pytest.approx(100.0 / 100.0)

    def test_eq4_read_term_uses_min_bandwidth(self):
        t = StepTraffic(e_read=40.0)
        # min(B_k=10, B_d=20) = 10
        assert dram_latency(t, SIMPLE) == pytest.approx(4.0)

    def test_eq4_max_of_three(self):
        t = StepTraffic(e_write=10.0, m_in=30.0, m_out=10.0)
        # link_out = (10+10)/10 = 2 ; link_in = 30/10 = 3
        # dram_chan = (10+30+10)/20 = 2.5  -> max = 3
        assert dram_latency(t, SIMPLE) == pytest.approx(3.0)

    def test_eq2_concurrency(self):
        t = StepTraffic(h_read=200.0, e_read=10.0)
        # t_h = 2.0, t_e = 1.0 -> max
        assert step_latency(t, SIMPLE) == pytest.approx(2.0)

    def test_eq1_sum(self):
        t = StepTraffic(h_read=np.array([100.0, 200.0, 300.0]))
        assert total_latency(t, SIMPLE) == pytest.approx(6.0)

    def test_gh200_table1_values(self):
        assert GH200.hbm_bw == pytest.approx(4.9 * 1024**4 / 1e12 * 1e12,
                                             rel=0.1)
        assert GH200.link_bw == pytest.approx(900e9)
        assert GH200.dram_bw == pytest.approx(500e9)
        assert GH200.effective_dram_read_bw == pytest.approx(500e9)


traffic_st = st.builds(
    StepTraffic,
    h_read=st.floats(0, 1e12), e_read=st.floats(0, 1e12),
    h_write=st.floats(0, 1e10), e_write=st.floats(0, 1e10),
    m_in=st.floats(0, 1e10), m_out=st.floats(0, 1e10))


class TestProperties:
    @given(traffic_st, st.sampled_from(list(SPECS)))
    @settings(max_examples=100, deadline=None)
    def test_nonnegative(self, t, spec_name):
        spec = SPECS[spec_name]
        assert step_latency(t, spec) >= 0.0

    @given(traffic_st, st.sampled_from(list(SPECS)),
           st.floats(1.0, 100.0))
    @settings(max_examples=100, deadline=None)
    def test_monotone_in_traffic(self, t, spec_name, factor):
        """Scaling every traffic term up never reduces latency."""
        spec = SPECS[spec_name]
        assert step_latency(t.scale(factor), spec) >= \
            step_latency(t, spec) - 1e-12

    @given(traffic_st, st.sampled_from(list(SPECS)))
    @settings(max_examples=100, deadline=None)
    def test_step_is_max_of_tiers(self, t, spec_name):
        spec = SPECS[spec_name]
        s = step_latency(t, spec)
        assert s == pytest.approx(
            max(float(hbm_latency(t, spec)), float(dram_latency(t, spec))))

    @given(st.floats(1.0, 1e12))
    @settings(max_examples=50, deadline=None)
    def test_hbm_faster_than_dram_for_reads(self, nbytes):
        """Same bytes read from HBM must not be slower than from DRAM."""
        th = step_latency(StepTraffic(h_read=nbytes), TPU_V5E)
        te = step_latency(StepTraffic(e_read=nbytes), TPU_V5E)
        assert th <= te

    @given(st.floats(1e3, 1e12), st.floats(0.0, 1.0))
    @settings(max_examples=100, deadline=None)
    def test_tier_splitting_never_worse_than_worst_tier(self, nbytes, frac):
        """Splitting reads across concurrent tiers is bounded by putting
        everything on the slow tier (the aggregation premise)."""
        split = StepTraffic(h_read=nbytes * frac,
                            e_read=nbytes * (1 - frac))
        all_dram = StepTraffic(e_read=nbytes)
        assert step_latency(split, GH200) <= \
            step_latency(all_dram, GH200) + 1e-12
