"""Serve-stream trace capture + per-request headroom attribution (PR 5).

Pins the tentpole invariants:

  * a single-request serve stream's stitched trace is BITWISE equal to
    the `generate` bridge's record (access, tiers, prompt_len) and
    scores identically — the serve capture is the same instrument
    pointed at the same program;
  * attribution across a lane-REUSE boundary: two requests that occupy
    the same lane one after the other get disjoint, uncontaminated
    records (identity comes from the scheduler's bindings, never the
    lane index);
  * telemetry on/off leaves serve outputs and StepStats identical, and
    capture adds ZERO retraces (one serve-chunk executable either way);
  * per-request and aggregate bound fractions are sane (<= 1 + tol)
    under a mixed continuous-batching stream with real HBM pressure.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.placement.base import UNALLOC
from repro.core.sa import SAConfig
from repro.core.tiers import GH200
from repro.models.model import Model
from repro.serving import trace_bridge
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.scheduler import Request

SA_CFG = SAConfig(max_evaluations=8, iters_per_level=3, seed=0)


@pytest.fixture(scope="module")
def dense_model():
    cfg = configs.get_smoke("internlm2-1.8b")
    m = Model(cfg)
    return m, m.init(jax.random.key(0))


def _cfg(stride=4, policy="importance", sparsity=0.0, max_context=128,
         **kw):
    return EngineConfig(max_context=max_context, hbm_fraction=0.25,
                        policy=policy, attention_sparsity=sparsity,
                        spec=GH200, promote_thresh=0.005,
                        telemetry_stride=stride, prefill_chunk=16, **kw)


def _mixed_requests(model, rng, n=5):
    return [Request(rid=i,
                    prompt=rng.integers(0, model.cfg.vocab,
                                        (16 + 16 * (i % 3),)),
                    max_new_tokens=4 + 2 * (i % 3))
            for i in range(n)]


class TestSingleRequestParity:
    """The load-bearing pin: serve's stitched per-request trace IS the
    generate bridge's record for the same stream."""

    def test_stitched_trace_bitwise_equals_generate_bridge(
            self, dense_model):
        model, params = dense_model
        rng = np.random.default_rng(11)
        S, n = 32, 9
        prompt = rng.integers(0, model.cfg.vocab, (S,))

        ref = ServingEngine(model, params, _cfg(trace_telemetry=True))
        logits0 = ref.start(jnp.asarray(prompt[None], jnp.int32))
        tok0 = jnp.argmax(logits0, -1).astype(jnp.int32)
        ref.generate(tok0, n - 1)
        grec = trace_bridge.collect(ref)

        eng = ServingEngine(model, params, _cfg(trace_telemetry=True))
        eng.serve([Request(rid=7, prompt=prompt, max_new_tokens=n)],
                  num_slots=1)
        atts = trace_bridge.attribute(trace_bridge.collect_serve(eng))
        assert [a.rid for a in atts] == [7]
        rec = atts[0].record

        np.testing.assert_array_equal(rec.access, grec.access)
        np.testing.assert_array_equal(rec.tier, grec.tier)
        assert rec.prompt_len == grec.prompt_len
        assert rec.num_steps == n - 1
        # identical records -> identical scores (oracle replay included)
        g = trace_bridge.score_headroom(grec, GH200, oracles=())
        s = trace_bridge.score_headroom(rec, GH200, oracles=())
        assert g == s

    def test_first_token_step_excluded_from_access_model(
            self, dense_model):
        """The crossing step samples the first token from the PREFILL
        plane; it must not appear as a decode access row."""
        model, params = dense_model
        rng = np.random.default_rng(3)
        prompt = rng.integers(0, model.cfg.vocab, (24,))
        eng = ServingEngine(model, params, _cfg(trace_telemetry=True))
        eng.serve([Request(rid=0, prompt=prompt, max_new_tokens=5)],
                  num_slots=1)
        rec = trace_bridge.collect_serve(eng)
        crossing = np.nonzero((rec.first[:, 0] >= 0))[0]
        assert crossing.size == 1
        assert not rec.access[crossing[0]].any()
        # and every access row is an emitted (decode) row of its lane
        step_has_access = rec.access.any(axis=(1, 3))        # [S, B]
        assert not np.any(step_has_access & ~(rec.emitted >= 0))


class TestLaneReuseAttribution:
    """Two requests through ONE slot: the lane index is reused, the
    records must not cross-contaminate."""

    @pytest.mark.parametrize("overlap", [False, True],
                             ids=["inline", "overlap"])
    def test_sequential_requests_get_disjoint_clean_records(
            self, dense_model, overlap):
        """overlap=True is the lane-reuse NON-CONTAMINATION pin for the
        staged migration buffer: request 0's final staged plan names
        lane 0's slots, request 1 is rebound onto the SAME lane at the
        boundary, and — static placement being deterministic — can
        reproduce the exact (slot, logical) pairs revalidation would
        wave through. `mask_plan_lanes` must drop those rows, or
        request 1's rows here would show request 0's leaked pages."""
        model, params = dense_model
        rng = np.random.default_rng(5)
        # first request is LONGER than the second: leaked pages from
        # request 0 would be visible as extra existing pages in 1's rows
        r0 = Request(rid=0, prompt=rng.integers(0, model.cfg.vocab, (48,)),
                     max_new_tokens=6)
        r1 = Request(rid=1, prompt=rng.integers(0, model.cfg.vocab, (16,)),
                     max_new_tokens=6)
        eng = ServingEngine(model, params,
                            _cfg(trace_telemetry=True,
                                 overlap_migrations=overlap))
        eng.serve([r0, r1], num_slots=1, seed=0)
        rec = trace_bridge.collect_serve(eng)
        atts = {a.rid: a for a in trace_bridge.attribute(rec)}
        assert set(atts) == {0, 1}
        # same lane, strictly ordered in time
        assert np.all(atts[0].lanes == 0) and np.all(atts[1].lanes == 0)
        assert atts[0].rows.max() < atts[1].rows.min()
        for rid, req in ((0, r0), (1, r1)):
            a = atts[rid]
            assert a.record.prompt_len == req.prompt_len
            assert a.record.num_steps == req.max_new_tokens - 1
            # at each decode row s the lane holds exactly the request's
            # own pages: prompt + first token + s decoded tokens
            pt = rec.page_tokens
            for s in range(a.record.num_steps):
                want = -(-(req.prompt_len + 1 + s) // pt)
                exists = (a.record.tier[s] != UNALLOC).sum(axis=-1)
                np.testing.assert_array_equal(
                    exists, np.full_like(exists, want))

    def test_scheduler_bindings_ledger(self, dense_model):
        model, params = dense_model
        rng = np.random.default_rng(6)
        reqs = _mixed_requests(model, rng, n=4)
        eng = ServingEngine(model, params, _cfg())
        eng.serve(reqs, num_slots=2, seed=0)
        bindings = eng.batcher.bindings
        assert [b["rid"] for b in bindings] == sorted(
            b["rid"] for b in bindings)          # FIFO admission order
        assert len(bindings) == len(reqs)
        for b in bindings:
            assert 0 <= b["lane"] < 2
            assert b["released_step"] >= b["admitted_step"] >= 0
        # slots were actually reused across the stream
        lanes = [b["lane"] for b in bindings]
        assert len(lanes) > len(set(lanes))


class TestTelemetryIsPureObservation:
    def test_serve_outputs_and_stats_identical_on_off(self, dense_model):
        model, params = dense_model

        def run(capture):
            eng = ServingEngine(model, params,
                                _cfg(trace_telemetry=capture))
            rep = eng.serve(_mixed_requests(model,
                                            np.random.default_rng(9)),
                            num_slots=2, seed=3)
            outs = {r.rid: list(r.output) for r in rep}
            return outs, eng.stats, eng

        outs_on, stats_on, _ = run(True)
        outs_off, stats_off, _ = run(False)
        assert outs_on == outs_off
        assert stats_on == stats_off

    def test_zero_retraces_with_capture(self, dense_model):
        """Telemetry rides the existing scan ys: one serve-chunk
        executable across a mixed stream, capture on."""
        model, params = dense_model
        rng = np.random.default_rng(2)
        eng = ServingEngine(model, params, _cfg(trace_telemetry=True))
        eng.serve(_mixed_requests(model, rng, n=6), num_slots=2, seed=1)
        assert eng._serve_jit._cache_size() == 1


class TestMixedStreamScoring:
    @pytest.fixture(scope="class")
    def scored(self, dense_model):
        """A contended stream: 272/288-token prompts spill past the
        16-page per-lane HBM pool (ctx 512) and Quest sparsity
        concentrates reads, so placement matters."""
        model, params = dense_model
        rng = np.random.default_rng(17)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, model.cfg.vocab,
                                            (272 + 16 * (i % 2),)),
                        max_new_tokens=8)
                for i in range(4)]
        eng = ServingEngine(model, params, _cfg(
            stride=8, policy="static", sparsity=0.5,
            trace_telemetry=True, max_context=512))
        report = eng.serve(reqs, num_slots=2, seed=0)
        rec = trace_bridge.collect_serve(eng)
        out = trace_bridge.score_serve(rec, GH200, sa_cfg=SA_CFG,
                                       report=report)
        return rec, report, out

    def test_per_request_bound_fraction_sane(self, scored):
        rec, report, out = scored
        assert len(out["requests"]) == 4
        for rid, sc in out["requests"].items():
            assert sc["live_total_s"] > 0
            assert 0.0 < sc["hit_fraction"] <= 1.0
            # the live policy is static, and live static == simulated
            # static (the bridge self-test), so the SA bound can never
            # come out meaningfully above the live total
            assert 0.0 < sc["bound_fraction"] <= 1.0 + 1e-3, (rid, sc)
            assert sc["sa_total_s"] <= sc["static_total_s"] * 1.001
            assert sc["live_total_s"] == \
                pytest.approx(sc["static_total_s"], rel=1e-9)

    def test_aggregate_stream_headroom(self, scored):
        rec, report, out = scored
        agg = out["aggregate"]
        assert agg["live_total_s"] > 0
        assert 0.0 < agg["bound_fraction"] <= 1.0 + 1e-3
        assert 0.0 < agg["live_hit_fraction"] < 1.0
        # max is subadditive: summing lanes BEFORE the Eq.(2) max lets
        # one lane's HBM time overlap another's DRAM time, so the
        # aggregate can only be <= the per-request totals in isolation
        iso = sum(sc["live_total_s"] for sc in out["requests"].values())
        assert agg["live_total_s"] <= iso * (1 + 1e-9)

    def test_report_carries_attribution(self, scored):
        rec, report, out = scored
        assert set(report.request_scores) == set(out["requests"])
        assert report.headroom["bound_fraction"] == \
            out["aggregate"]["bound_fraction"]
        for sc in report.request_scores.values():
            assert {"hit_fraction", "bound_fraction"} <= set(sc)
