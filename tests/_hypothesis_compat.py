"""Optional-hypothesis shim for the test suite.

The tier-1 suite must collect and pass with neither `hypothesis` nor
`zstandard` installed (offline CI images). Property-based tests import
`given` / `settings` / `st` from here instead of from hypothesis
directly: when hypothesis is available they are the real thing; when it
is missing, `given` turns each property test into an explicit skip (so
the non-hypothesis smoke cases in the same module still run and keep
coverage alive).
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def decorate(fn):
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return decorate

    def settings(*_args, **_kwargs):
        def decorate(fn):
            return fn
        return decorate

    class _Strategy:
        """Stand-in accepted anywhere a SearchStrategy is built."""

        def __getattr__(self, name):
            return _Strategy()

        def __call__(self, *args, **kwargs):
            return _Strategy()

    st = _Strategy()
