"""Sharded-vs-single-device serve parity (EXPERIMENTS.md
§Mesh-sharding).

The pins: the same request stream through a 1-device engine and a
mesh-attached engine yields identical tokens and terminal statuses,
tolerance-close hit/bound fractions, ONE serve executable with zero
retraces under the mesh, and genuinely sharded cache buffers.

The in-process tests need >= 4 jax devices — the CI mesh leg provides
them with `XLA_FLAGS=--xla_force_host_platform_device_count=8`; on a
default 1-device host they skip, and the subprocess test (which spawns
its own 4-device interpreter, XLA_FLAGS must precede jax init) keeps
the parity contract in tier-1 everywhere.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro import configs
from repro.core.sa import SAConfig
from repro.core.tiers import GH200
from repro.models.model import Model
from repro.serving import trace_bridge
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.scheduler import Request

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >= 4 jax devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


@pytest.fixture(scope="module")
def model_params():
    cfg = configs.get_smoke("internlm2-1.8b")
    model = Model(cfg)
    return model, model.init(jax.random.key(0))


def _requests(vocab, n=5, base=32):
    rng = np.random.default_rng(3)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, (base + 16 * (i % 3),)),
                    max_new_tokens=5 + (i % 2))
            for i in range(n)]


def _serve(model, params, mesh, *, policy="importance", trace=False,
           sparsity=0.0, ctx=160, slots=2, reqs=None, overlap=False):
    eng = ServingEngine(model, params, EngineConfig(
        max_context=ctx, hbm_fraction=0.25, policy=policy,
        attention_sparsity=sparsity, spec=GH200, promote_thresh=1e-4,
        telemetry_stride=8, prefill_chunk=16, trace_telemetry=trace,
        overlap_migrations=overlap),
        mesh=mesh)
    report = eng.serve(reqs if reqs is not None
                       else _requests(model.cfg.vocab),
                       num_slots=slots, seed=0)
    return eng, report


def _mesh(data, model):
    from repro.launch.mesh import make_test_mesh
    return make_test_mesh(data=data, model=model)


@needs_mesh
def test_mesh_parity_tokens_statuses_zero_retraces(model_params):
    model, params = model_params
    _, ref = _serve(model, params, None)
    eng, got = _serve(model, params, _mesh(2, 2))
    assert eng._serve_jit._cache_size() == 1, \
        eng._serve_jit._cache_size()
    assert ref.statuses == got.statuses
    assert {r.rid: list(r.output) for r in ref} == \
        {r.rid: list(r.output) for r in got}


@needs_mesh
def test_mesh_cache_buffers_actually_sharded(model_params):
    model, params = model_params
    eng, _ = _serve(model, params, _mesh(2, 2))
    kh = eng._cache.k_hbm                  # [L, B, Ph, T, KH, HD]
    shards = kh.addressable_shards
    assert len(shards) == 4
    shape = shards[0].data.shape
    assert shape[1] == kh.shape[1] // 2    # lanes over data
    assert shape[4] == kh.shape[4] // 2    # kv_heads over model
    # per-lane carries follow the lanes; fault caps stay replicated
    assert eng._cache.length.addressable_shards[0].data.shape[0] == \
        eng._cache.length.shape[0] // 2


@needs_mesh
def test_mesh_overlap_pipeline_parity(model_params):
    """The async-migration pipeline under a mesh: the staged
    MigrationPlan carry is replicated (launch/shardings.py "plan"
    entry), the commit is a per-shard local scatter, and the overlap
    serve matches the 1-device overlap serve token-for-token on ONE
    executable — the pipeline never forks the compiled surface."""
    model, params = model_params
    _, ref = _serve(model, params, None, overlap=True)
    eng, got = _serve(model, params, _mesh(2, 2), overlap=True)
    assert eng._serve_jit._cache_size() == 1, \
        eng._serve_jit._cache_size()
    assert ref.statuses == got.statuses
    assert {r.rid: list(r.output) for r in ref} == \
        {r.rid: list(r.output) for r in got}


@needs_mesh
def test_mesh_data_parallel_stateful_policy_parity(model_params):
    # recency threads [L, B, P] state through the scan: a pure
    # data-parallel mesh shards it over lanes and must not perturb it
    model, params = model_params
    _, ref = _serve(model, params, None, policy="recency")
    eng, got = _serve(model, params, _mesh(4, 1), policy="recency",
                      slots=4)
    assert eng._serve_jit._cache_size() == 1
    assert ref.statuses == got.statuses
    # slots differ (4 lanes vs 2) so scheduling differs; compare the
    # per-request token streams, which sampling keys make lane-invariant
    assert {r.rid: list(r.output) for r in ref} == \
        {r.rid: list(r.output) for r in got}


@needs_mesh
def test_mesh_hit_bound_fractions_tolerance_pinned(model_params):
    # a stream that actually spills HBM (272/288-token prompts, ctx
    # 512) so the fractions are non-trivial; mesh float reassociation
    # may flip individual migration choices, hence tolerances
    model, params = model_params
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, model.cfg.vocab, (272 + 16 * (i % 2),))
               for i in range(3)]

    def mk():
        return [Request(rid=i, prompt=p, max_new_tokens=4 + (i % 2))
                for i, p in enumerate(prompts)]

    sa_cfg = SAConfig(max_evaluations=6, iters_per_level=2, seed=0)
    frac = {}
    for tag, mesh in (("1dev", None), ("mesh", _mesh(2, 2))):
        eng, rep = _serve(model, params, mesh, trace=True, ctx=512,
                          sparsity=0.5, reqs=mk())
        agg = trace_bridge.score_serve(
            trace_bridge.collect_serve(eng), GH200, sa_cfg=sa_cfg,
            report=rep)["aggregate"]
        frac[tag] = agg
    assert frac["1dev"]["live_hit_fraction"] < 1.0   # stream spilled
    assert abs(frac["1dev"]["live_hit_fraction"]
               - frac["mesh"]["live_hit_fraction"]) <= 0.02
    assert abs(frac["1dev"]["bound_fraction"]
               - frac["mesh"]["bound_fraction"]) <= 0.05


def test_parity_cli_subprocess():
    """Tier-1 everywhere: spawn a 4-host-device interpreter and run
    `repro.launch.serve --parity` (1-device vs data=2,model=2)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4")
    src = os.path.join(REPO, "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--smoke",
         "--parity", "--requests", "4", "--new-tokens", "6",
         "--batch-slots", "2", "--stride", "8"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=540)
    assert proc.returncode == 0, (proc.stdout, proc.stderr)
    assert "MESH PARITY OK" in proc.stdout, proc.stdout


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
