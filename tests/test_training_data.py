"""Training step, chunked loss, grad accumulation, data determinism."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models.model import Model
from repro.training.train_step import (
    init_train_state, loss_fn, make_train_step,
)


def _model():
    import dataclasses
    cfg = dataclasses.replace(configs.get_smoke("internlm2-1.8b"),
                              dtype=jnp.float32, param_dtype=jnp.float32)
    return Model(cfg)


class TestLoss:
    def test_chunked_equals_naive(self):
        model = _model()
        params = model.init(jax.random.key(0))
        rng = np.random.default_rng(0)
        tokens = jnp.asarray(rng.integers(0, model.cfg.vocab, (2, 33)),
                             jnp.int32)
        chunked = loss_fn(model, params, tokens, logit_chunk=8)
        naive = loss_fn(model, params, tokens, logit_chunk=512)
        np.testing.assert_allclose(float(chunked), float(naive), rtol=1e-5)

    def test_loss_near_log_vocab_at_init(self):
        model = _model()
        params = model.init(jax.random.key(1))
        rng = np.random.default_rng(1)
        tokens = jnp.asarray(rng.integers(0, model.cfg.vocab, (4, 32)),
                             jnp.int32)
        loss = float(loss_fn(model, params, tokens))
        assert abs(loss - np.log(model.cfg.vocab)) < 1.0

    def test_loss_decreases(self):
        model = _model()
        state = init_train_state(model, jax.random.key(0))
        step = jax.jit(make_train_step(model, lr=5e-3))
        corpus = SyntheticCorpus(DataConfig(vocab=model.cfg.vocab,
                                            seq_len=32, global_batch=4))
        losses = []
        for i in range(10):
            state, m = step(state, {"tokens": jnp.asarray(
                corpus.batch(0, i)["tokens"])})
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]


class TestGradAccumulation:
    def test_accum_matches_full_batch(self):
        model = _model()
        state = init_train_state(model, jax.random.key(0))
        rng = np.random.default_rng(2)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, model.cfg.vocab, (4, 24)), jnp.int32)}
        s1, m1 = jax.jit(make_train_step(model, lr=1e-3))(state, batch)
        s2, m2 = jax.jit(make_train_step(model, accum_steps=2,
                                         lr=1e-3))(state, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-5)
        for a, b in zip(jax.tree.leaves(s1.params),
                        jax.tree.leaves(s2.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-4)


class TestOptimizer:
    def test_adamw_moves_toward_minimum(self):
        from repro.training.optimizer import adamw_init, adamw_update
        params = {"w": jnp.array([5.0, -3.0])}
        opt = adamw_init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}   # d/dw w^2
            params, opt = adamw_update(grads, opt, params, lr=0.05,
                                       weight_decay=0.0)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_grad_clip(self):
        from repro.training.optimizer import adamw_init, adamw_update
        params = {"w": jnp.zeros(3)}
        opt = adamw_init(params)
        huge = {"w": jnp.full(3, 1e9)}
        p2, _ = adamw_update(huge, opt, params, lr=0.1, grad_clip=1.0,
                             weight_decay=0.0)
        # first-step Adam update magnitude is bounded by ~lr
        assert float(jnp.abs(p2["w"]).max()) < 0.2


class TestData:
    def test_determinism(self):
        cfg = DataConfig(vocab=100, seq_len=16, global_batch=4, seed=7)
        a = SyntheticCorpus(cfg).batch(0, 5)["tokens"]
        b = SyntheticCorpus(cfg).batch(0, 5)["tokens"]
        np.testing.assert_array_equal(a, b)

    def test_shards_differ(self):
        cfg = DataConfig(vocab=100, seq_len=16, global_batch=8,
                         num_shards=2)
        c = SyntheticCorpus(cfg)
        assert not np.array_equal(c.batch(0, 0)["tokens"],
                                  c.batch(1, 0)["tokens"])

    def test_skip_ahead_recovery(self):
        """Any worker can recompute any other worker's batch at any
        step — the straggler/failure recovery property."""
        cfg = DataConfig(vocab=100, seq_len=8, global_batch=4,
                         num_shards=2, seed=3)
        worker_a = SyntheticCorpus(cfg)
        worker_b = SyntheticCorpus(cfg)   # fresh process after failure
        np.testing.assert_array_equal(worker_a.batch(1, 17)["tokens"],
                                      worker_b.batch(1, 17)["tokens"])

    def test_has_structure(self):
        """n-gram structure means the corpus is learnable (non-uniform)."""
        cfg = DataConfig(vocab=50, seq_len=64, global_batch=8)
        toks = SyntheticCorpus(cfg).batch(0, 0)["tokens"]
        # successor entropy should be far below uniform
        pairs = {}
        for row in toks:
            for a, b in zip(row[:-1], row[1:]):
                pairs.setdefault(int(a), []).append(int(b))
        repeat_frac = np.mean([
            max(np.bincount(v).max() / len(v), 0.0)
            for v in pairs.values() if len(v) >= 3])
        assert repeat_frac > 0.3
