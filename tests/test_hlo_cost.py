"""Trip-count-weighted HLO cost analyzer: validated against graphs with
analytically known FLOP counts."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze


def _hlo(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


class TestAnalyzer:
    def test_plain_matmul(self):
        spec = jax.ShapeDtypeStruct((128, 256), jnp.float32)
        spec2 = jax.ShapeDtypeStruct((256, 64), jnp.float32)
        r = analyze(_hlo(lambda a, b: a @ b, spec, spec2))
        expected = 2 * 128 * 256 * 64
        assert r["flops"] == pytest.approx(expected, rel=0.1)

    def test_scan_multiplies_trip_count(self):
        def f(xs):
            def body(c, x):
                return c @ x, None
            c, _ = jax.lax.scan(body, jnp.eye(64, dtype=jnp.float32), xs)
            return c
        r = analyze(_hlo(f, jax.ShapeDtypeStruct((12, 64, 64),
                                                 jnp.float32)))
        expected = 12 * 2 * 64 ** 3
        assert r["flops"] == pytest.approx(expected, rel=0.15)

    def test_nested_scan(self):
        def f(xs):
            def outer(c, x):
                def inner(ci, xi):
                    return ci @ xi, None
                c2, _ = jax.lax.scan(inner, c, x)
                return c2, None
            c, _ = jax.lax.scan(outer, jnp.eye(32, dtype=jnp.float32), xs)
            return c
        r = analyze(_hlo(f, jax.ShapeDtypeStruct((3, 5, 32, 32),
                                                 jnp.float32)))
        expected = 15 * 2 * 32 ** 3
        assert r["flops"] == pytest.approx(expected, rel=0.2)

    def test_scan_sliced_weights_bytes_not_inflated(self):
        """A scan body that dynamic-slices one layer of a stacked weight
        must charge ~L * one-layer bytes, not L * full-stack bytes."""
        L, D = 16, 128

        def f(ws, x):
            def body(c, w):
                return jnp.tanh(c @ w), None
            c, _ = jax.lax.scan(body, x, ws)
            return c
        r = analyze(_hlo(f, jax.ShapeDtypeStruct((L, D, D), jnp.float32),
                         jax.ShapeDtypeStruct((D, D), jnp.float32)))
        one_layer = D * D * 4
        # generous bound: a few tensors of one-layer size per iteration
        assert r["bytes"] < L * one_layer * 12

    def test_comment_headers_parsed(self):
        """Computations whose headers contain /*index=N*/ comments (big
        tuples) must still be discovered."""
        def f(xs):
            def body(carry, x):
                a, b, c = carry
                return (a @ x, b + 1.0, c * 2.0), None
            init = (jnp.eye(96, dtype=jnp.float32),
                    jnp.zeros((4,), jnp.float32),
                    jnp.ones((3, 3), jnp.float32))
            out, _ = jax.lax.scan(body, init, xs)
            return out[0]
        hlo = _hlo(f, jax.ShapeDtypeStruct((8, 96, 96), jnp.float32))
        r = analyze(hlo)
        assert r["flops"] == pytest.approx(8 * 2 * 96 ** 3, rel=0.15)

    def test_collective_parse(self):
        from repro.launch.roofline import collective_bytes_of_hlo
        fake = """
ENTRY %main () -> f32[] {
  %x = bf16[16,512]{1,0} all-gather(%p), dimensions={0}
  %y = f32[8,8]{1,0} all-reduce(%q), to_apply=%add
  %z = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(%a, %b)
}
"""
        got = collective_bytes_of_hlo(fake)
        assert got["all-gather"] == 16 * 512 * 2
        assert got["all-reduce"] == 8 * 8 * 4 * 2      # ring 2x factor
        assert got["all-to-all"] == 2 * 4 * 4 * 4


class TestRooflineDerivation:
    def test_terms_and_dominance(self):
        from repro.launch.roofline import roofline_terms
        rec = {
            "devices": 256,
            "flops_per_device": 197e12 * 0.5,      # 0.5 s compute
            "bytes_per_device": 819e9 * 0.1,       # 0.1 s memory
            "collective_bytes_per_device": {"total": 50e9 * 0.2},
            "active_params": 1e9, "batch": 8, "seq": 128,
            "kind": "train",
        }
        t = roofline_terms(rec)
        assert t["dominant"] == "compute"
        assert t["compute_s"] == pytest.approx(0.5)
        assert t["memory_s"] == pytest.approx(0.1)
        assert t["collective_s"] == pytest.approx(0.2)
        assert 0 < t["roofline_fraction"] <= 1.0 + 1e-9
