"""The continuous-batching fused serve loop (ServingEngine.serve):
single-request bitwise parity with `generate`, mixed-length streams
with zero retraces, page reclaim accounting, sampling reproducibility,
starvation bounds, and the quest-mask plumbing for moe/hybrid/encdec.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.tiers import GH200
from repro.models.model import Model
from repro.serving import control
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.sampling import SamplingConfig, make_sampler
from repro.serving.scheduler import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def dense_model():
    cfg = configs.get_smoke("internlm2-1.8b")
    m = Model(cfg)
    return m, m.init(jax.random.key(0))


def _cfg(policy="importance", sparsity=0.0, stride=4, **kw):
    return EngineConfig(max_context=128, hbm_fraction=0.25, policy=policy,
                        attention_sparsity=sparsity, spec=GH200,
                        promote_thresh=0.005, telemetry_stride=stride,
                        **kw)


class TestServeParity:
    """A single full-length greedy request through `serve` must be the
    same program as prefill + fused `generate`: tokens bitwise equal,
    StepStats identical."""

    @pytest.mark.parametrize("policy,sparsity", [
        ("static", 0.0), ("importance", 0.0), ("importance", 0.5)])
    def test_single_request_matches_generate(self, dense_model, policy,
                                             sparsity):
        model, params = dense_model
        rng = np.random.default_rng(0)
        # prompt length a multiple of page_tokens: serve's page-padded
        # admission prefill is then shape-identical to `start`
        prompt = rng.integers(0, model.cfg.vocab, (32,))
        n = 10

        ref = ServingEngine(model, params, _cfg(policy, sparsity))
        logits0 = ref.start(jnp.asarray(prompt[None], jnp.int32))
        tok0 = jnp.argmax(logits0, -1).astype(jnp.int32)
        toks = ref.generate(tok0, n - 1)
        want = [int(tok0[0])] + [int(t) for t in np.asarray(toks)[:, 0]]

        eng = ServingEngine(model, params, _cfg(policy, sparsity))
        done = eng.serve([Request(rid=0, prompt=prompt, max_new_tokens=n)],
                         num_slots=1)
        assert done[0].output == want
        assert eng.stats == ref.stats

    def test_ragged_prompt_pads_to_page_boundary(self, dense_model):
        """Off-page prompt lengths serve fine: pads are invisible."""
        model, params = dense_model
        rng = np.random.default_rng(1)
        prompt = rng.integers(0, model.cfg.vocab, (21,))   # 21 % 16 != 0
        eng = ServingEngine(model, params, _cfg())
        done = eng.serve([Request(rid=0, prompt=prompt, max_new_tokens=6)],
                         num_slots=1)
        assert len(done[0].output) == 6
        assert all(0 <= t < model.cfg.vocab for t in done[0].output)


class TestServeStream:
    def test_mixed_length_stream_zero_retraces(self, dense_model):
        """More requests than slots, mixed prompt/budget lengths: every
        request completes with its exact budget, the fused chunk
        compiles exactly once, and all pages are reclaimed."""
        model, params = dense_model
        rng = np.random.default_rng(2)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, model.cfg.vocab,
                                            (16 + 8 * (i % 3),)),
                        max_new_tokens=4 + 3 * (i % 3))
                for i in range(6)]
        eng = ServingEngine(model, params, _cfg(stride=4))
        done = eng.serve(reqs, num_slots=2, seed=3)
        assert sorted(r.rid for r in done) == list(range(6))
        for r in done:
            assert len(r.output) == r.max_new_tokens
            assert r.generated == r.max_new_tokens
        # zero retraces after warmup: one executable for the serve chunk
        assert eng._serve_jit._cache_size() == 1
        # byte accounting balances: every page reclaimed on completion
        assert eng.batcher.free_pages == eng.batcher.total_pages
        assert int(np.asarray((eng._cache.hbm_owner >= 0).sum())) == 0
        assert int(np.asarray((eng._cache.host_owner >= 0).sum())) == 0

    def test_eos_stops_early_and_reclaims(self, dense_model):
        """An always-hit EOS (greedy argmax probed first) finishes the
        request before its budget and still balances pages."""
        model, params = dense_model
        rng = np.random.default_rng(3)
        prompt = rng.integers(0, model.cfg.vocab, (32,))
        probe = ServingEngine(model, params, _cfg())
        probed = probe.serve(
            [Request(rid=0, prompt=prompt, max_new_tokens=8)], num_slots=1)
        eos = probed[0].output[2]        # the 3rd greedy token

        eng = ServingEngine(model, params, _cfg(eos_id=int(eos)))
        done = eng.serve(
            [Request(rid=0, prompt=prompt, max_new_tokens=8)], num_slots=1)
        out = done[0].output
        assert len(out) <= 8
        assert out[-1] == eos
        assert eng.batcher.free_pages == eng.batcher.total_pages

    def test_starvation_bound_under_fused_loop(self, dense_model):
        """A page-hungry request blocked behind live slots is admitted
        once completions free its pages — it never starves, and the
        whole stream completes through the fused loop."""
        model, params = dense_model
        rng = np.random.default_rng(4)
        big = Request(rid=0, prompt=rng.integers(0, model.cfg.vocab, (48,)),
                      max_new_tokens=8)           # 4 pages of 16
        smalls = [Request(rid=1 + i,
                          prompt=rng.integers(0, model.cfg.vocab, (16,)),
                          max_new_tokens=4)       # 2 pages each
                  for i in range(4)]
        eng = ServingEngine(model, params, _cfg(stride=4))
        # pool of 6 pages: two smalls fill it; big (4 pages) must wait
        done = eng.serve(smalls + [big], num_slots=2, total_pages=6,
                         seed=0, max_skips=1)
        assert sorted(r.rid for r in done) == list(range(5))
        assert big.started_step > 0          # actually waited
        assert len(big.output) == 8
        assert eng.batcher.free_pages == 6

    def test_moe_family_serves_with_quest_mask(self):
        """serve() drives any cache-backed decode state: moe decodes
        through the same masked, batched hot path."""
        cfg = configs.get_smoke("granite-moe-3b-a800m")
        m = Model(cfg)
        params = m.init(jax.random.key(0))
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab, (16 + 8 * i,)),
                        max_new_tokens=4 + 2 * i) for i in range(3)]
        eng = ServingEngine(m, params, EngineConfig(
            max_context=96, hbm_fraction=0.25, policy="importance",
            attention_sparsity=0.5, spec=GH200, telemetry_stride=4))
        done = eng.serve(reqs, num_slots=2,
                         sampling=SamplingConfig(temperature=0.7), seed=1)
        assert sorted((r.rid, len(r.output)) for r in done) == \
            [(0, 4), (1, 6), (2, 8)]
        assert eng._serve_jit._cache_size() == 1
        assert eng.batcher.free_pages == eng.batcher.total_pages

    def test_recurrent_family_serve_raises(self):
        cfg = configs.get_smoke("xlstm-125m")
        m = Model(cfg)
        params = m.init(jax.random.key(0))
        eng = ServingEngine(m, params, EngineConfig(max_context=64))
        with pytest.raises(NotImplementedError, match="dense/moe"):
            eng.serve([Request(rid=0, prompt=np.arange(8),
                               max_new_tokens=4)])

    def test_instant_completions_drain_queue(self, dense_model):
        """Requests that finish at admission (budget 1) free their slot
        within the same boundary, so a queue of them drains through one
        slot instead of tripping the no-active-lane guard."""
        model, params = dense_model
        rng = np.random.default_rng(8)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, model.cfg.vocab, (16,)),
                        max_new_tokens=1) for i in range(3)]
        eng = ServingEngine(model, params, _cfg())
        done = eng.serve(reqs, num_slots=1)
        assert sorted(r.rid for r in done) == [0, 1, 2]
        assert all(len(r.output) == 1 for r in done)
        assert eng.batcher.free_pages == eng.batcher.total_pages

    def test_request_objects_reusable_across_serves(self, dense_model):
        """Re-submitting the same Request objects starts a fresh run:
        outputs don't accumulate across serve() calls."""
        model, params = dense_model
        rng = np.random.default_rng(9)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, model.cfg.vocab, (16,)),
                        max_new_tokens=4) for i in range(2)]
        eng = ServingEngine(model, params, _cfg())
        first = {r.rid: list(r.output)
                 for r in eng.serve(reqs, num_slots=1)}
        second = {r.rid: list(r.output)
                  for r in eng.serve(reqs, num_slots=1)}
        assert first == second
        assert all(len(v) == 4 for v in second.values())

    def test_zero_budget_request_rejected(self, dense_model):
        """An invalid decode budget is a per-request rejection with a
        typed error, never a batch-wide abort: the valid neighbor in
        the same stream still completes normally."""
        model, params = dense_model
        rng = np.random.default_rng(5)
        eng = ServingEngine(model, params, _cfg())
        bad = Request(rid=0, prompt=np.arange(8), max_new_tokens=0)
        good = Request(rid=1,
                       prompt=rng.integers(0, model.cfg.vocab, (16,)),
                       max_new_tokens=4)
        report = eng.serve([bad, good], num_slots=1)
        assert bad.status == "rejected"
        assert bad.error.code == "zero_budget"
        assert [r.rid for r in report.rejected] == [0]
        assert good.status == "ok" and len(good.output) == 4
        assert report.statuses == {0: "rejected", 1: "ok"}

    def test_infeasible_request_rejected(self, dense_model):
        """A prompt+budget over the cache capacity is rejected at
        submit (typed error), not raised after the stream started."""
        model, params = dense_model
        rng = np.random.default_rng(5)
        # pool padding (pad_to=16) gives max_context=128 a 512-token
        # capacity; exceed THAT, not the nominal context
        bad = Request(rid=0, prompt=rng.integers(0, model.cfg.vocab,
                                                 (32,)),
                      max_new_tokens=600)
        good = Request(rid=1,
                       prompt=rng.integers(0, model.cfg.vocab, (16,)),
                       max_new_tokens=3)
        eng = ServingEngine(model, params, _cfg())
        report = eng.serve([bad, good], num_slots=1)
        assert bad.status == "rejected"
        assert bad.error.code == "infeasible_context"
        assert good.status == "ok" and len(good.output) == 3
        assert len(report.completed) == 1


class TestServeSampling:
    def test_sampled_decode_reproducible(self, dense_model):
        """Fixed seed -> identical streams; different seed -> the PRNG
        actually samples (some request differs from greedy)."""
        model, params = dense_model
        rng = np.random.default_rng(6)
        prompts = [rng.integers(0, model.cfg.vocab, (24,))
                   for _ in range(3)]

        def run(seed, sampling):
            eng = ServingEngine(model, params, _cfg(stride=4))
            done = eng.serve(
                [Request(rid=i, prompt=p, max_new_tokens=6)
                 for i, p in enumerate(prompts)],
                num_slots=2, sampling=sampling, seed=seed)
            return {r.rid: list(r.output) for r in done}

        hot = SamplingConfig(temperature=1.5, top_k=64)
        a = run(0, hot)
        b = run(0, hot)
        assert a == b
        greedy = run(0, SamplingConfig())
        assert any(a[i] != greedy[i] for i in a)

    def test_per_slot_keys_isolate_requests(self, dense_model):
        """A request's sampled tokens don't depend on batch company:
        serving it alone or with neighbours gives the same stream
        (per-request keys derived from (seed, rid))."""
        model, params = dense_model
        rng = np.random.default_rng(7)
        target = rng.integers(0, model.cfg.vocab, (32,))
        other = rng.integers(0, model.cfg.vocab, (32,))
        hot = SamplingConfig(temperature=1.0, top_k=32)

        eng1 = ServingEngine(model, params, _cfg(stride=4))
        solo = eng1.serve([Request(rid=5, prompt=target,
                                   max_new_tokens=6)],
                          num_slots=1, sampling=hot, seed=0)
        eng2 = ServingEngine(model, params, _cfg(stride=4))
        both = eng2.serve([Request(rid=5, prompt=target, max_new_tokens=6),
                           Request(rid=9, prompt=other, max_new_tokens=6)],
                          num_slots=2, sampling=hot, seed=0)
        got = {r.rid: r.output for r in both}
        assert got[5] == solo[0].output


class TestSamplerUnits:
    def test_greedy_is_argmax(self):
        logits = jnp.asarray(np.random.default_rng(0)
                             .standard_normal((3, 17)), jnp.float32)
        keys = jax.random.split(jax.random.PRNGKey(0), 3)
        out = make_sampler(SamplingConfig())(logits, keys)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.argmax(np.asarray(logits), -1))

    def test_top_k_restricts_support(self):
        rng = np.random.default_rng(1)
        logits = jnp.asarray(rng.standard_normal((2, 50)), jnp.float32)
        sampler = make_sampler(SamplingConfig(temperature=1.0, top_k=5))
        topk = np.argsort(-np.asarray(logits), -1)[:, :5]
        for s in range(20):
            keys = jax.random.split(jax.random.PRNGKey(s), 2)
            toks = np.asarray(sampler(logits, keys))
            for b in range(2):
                assert toks[b] in topk[b]

    def test_top_p_keeps_nucleus_only(self):
        # one dominant token -> tiny nucleus at modest top_p
        logits = jnp.asarray([[10.0, 0.0, 0.0, 0.0]], jnp.float32)
        sampler = make_sampler(SamplingConfig(temperature=1.0, top_p=0.9))
        for s in range(10):
            keys = jax.random.split(jax.random.PRNGKey(s), 1)
            assert int(sampler(logits, keys)[0]) == 0

    def test_zero_temperature_needs_no_key_entropy(self):
        logits = jnp.asarray([[1.0, 3.0, 2.0]], jnp.float32)
        keys = jax.random.split(jax.random.PRNGKey(0), 1)
        s1 = make_sampler(SamplingConfig(temperature=0.0))
        assert int(s1(logits, keys)[0]) == 1


class TestLaneOps:
    def _cache(self):
        from repro.kvcache.paged import CacheGeometry, prefill_cache
        geo = CacheGeometry(num_layers=1, batch=2, page_tokens=4,
                            hbm_pages=2, host_pages=4, kv_heads=2,
                            head_dim=8, dtype=jnp.float32)
        rng = np.random.default_rng(0)
        kv = jnp.asarray(rng.standard_normal((1, 2, 16, 2, 8)),
                         jnp.float32)
        return geo, prefill_cache(geo, kv, kv, 16)

    def test_release_lanes_frees_pages(self):
        _, cache = self._cache()
        out = control.release_lanes(cache,
                                    jnp.asarray(np.array([True, False])))
        assert int(np.asarray((out.hbm_owner[:, 0] >= 0).sum())) == 0
        assert int(np.asarray((out.host_owner[:, 0] >= 0).sum())) == 0
        assert int(out.length[0]) == 0
        # untouched lane keeps its pages
        assert int(np.asarray((out.hbm_owner[:, 1] >= 0).sum())) == 2
        assert int(out.length[1]) == 16

    def test_insert_lane_binds_batch1_cache(self):
        geo, cache = self._cache()
        empty = control.release_lanes(
            cache, jnp.asarray(np.array([True, True])))
        geo1 = dataclasses.replace(geo, batch=1)
        from repro.kvcache.paged import prefill_cache
        rng = np.random.default_rng(1)
        kv1 = jnp.asarray(rng.standard_normal((1, 1, 8, 2, 8)), jnp.float32)
        lane_cache = prefill_cache(geo1, kv1, kv1, 8)
        out = control.insert_lane(empty, lane_cache, jnp.int32(1))
        assert int(out.length[1]) == 8 and int(out.length[0]) == 0
        np.testing.assert_array_equal(np.asarray(out.page_table[:, 1]),
                                      np.asarray(lane_cache.page_table[:, 0]))
        np.testing.assert_array_equal(np.asarray(out.k_hbm[:, 1]),
                                      np.asarray(lane_cache.k_hbm[:, 0]))
        assert int(np.asarray((out.hbm_owner[:, 0] >= 0).sum())) == 0

    def test_lane_merge_all_active_is_identity(self):
        _, cache = self._cache()
        bumped = dataclasses.replace(cache, length=cache.length + 1)
        out = control.lane_merge(cache, bumped,
                                 jnp.asarray(np.array([True, True])))
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(bumped)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_lane_merge_freezes_inactive(self):
        _, cache = self._cache()
        bumped = dataclasses.replace(cache, length=cache.length + 1,
                                     importance=cache.importance + 1.0)
        out = control.lane_merge(cache, bumped,
                                 jnp.asarray(np.array([False, True])))
        assert int(out.length[0]) == 16 and int(out.length[1]) == 17
        assert float(out.importance[0, 0, 0]) == 0.0
        assert float(out.importance[0, 1, 0]) == 1.0


class TestMaskPlumbing:
    """Quest logical_page_mask flows through every cache-backed family."""

    def _drive_masked(self, name, extra_fn=None, steps=2, sparsity=0.6):
        cfg = configs.get_smoke(name)
        m = Model(cfg)
        params = m.init(jax.random.key(0))
        rng = np.random.default_rng(0)
        B, S = 2, 24
        prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
        extra = extra_fn(cfg, B, rng) if extra_fn else None
        geo = m.cache_geometry(B, 96)
        logits, state = m.prefill(params, prompts, geo, extra=extra)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for _ in range(steps):
            cache = state if not isinstance(state, dict) else state["kv"]
            mask = control.quest_page_mask(cache, sparsity)
            logits, state = m.decode_step(params, state, tok,
                                          logical_page_mask=mask)
            assert np.isfinite(np.asarray(logits, np.float32)).all()
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return logits

    def test_moe_masked_decode(self):
        self._drive_masked("granite-moe-3b-a800m")

    def test_hybrid_masked_decode(self):
        self._drive_masked("zamba2-1.2b")

    def test_encdec_masked_decode(self):
        self._drive_masked(
            "whisper-tiny",
            extra_fn=lambda cfg, B, rng: {
                "frame_embeds": jnp.asarray(
                    rng.standard_normal((B, 8, cfg.d_model)), jnp.float32)})

    def test_recurrent_families_refuse_mask(self):
        cfg = configs.get_smoke("xlstm-125m")
        m = Model(cfg)
        params = m.init(jax.random.key(0))
        st = m.init_decode_state(2)
        with pytest.raises(ValueError, match="paged KV cache"):
            m.decode_step(params, st, jnp.array([1, 2]),
                          logical_page_mask=jnp.ones((1, 2, 4), bool))


class TestSchedulerEngineProtocol:
    def test_pages_needed_uses_engine_page_size(self):
        """Regression: pages_needed once hardcoded page size 16; the
        batcher stamps its geometry's page size at submit."""
        cb = ContinuousBatcher(num_slots=1, total_pages=100, page_tokens=4)
        r = Request(rid=0, prompt_len=10, max_new_tokens=6)
        assert r.pages_needed == 1          # default 16-token pages
        cb.submit(r)
        assert r.pages_needed == 4          # ceil(16 / 4)
        cb.admit()
        assert cb.free_pages == 96

    def test_admit_binds_lanes_and_device_view(self):
        cb = ContinuousBatcher(num_slots=3, total_pages=100)
        for i in range(2):
            cb.submit(Request(rid=i, prompt_len=16, max_new_tokens=8))
        admitted = cb.admit()
        assert [r.lane for r in admitted] == [0, 1]
        view = cb.device_view()
        np.testing.assert_array_equal(view.active,
                                      np.array([True, True, False]))
        np.testing.assert_array_equal(view.remaining[:2], np.array([8, 8]))
        assert view.lane_of == {0: 0, 1: 1}
        cb.complete(admitted[0])
        view = cb.device_view()
        assert not view.active[0] and view.rids[0] == -1
        assert cb.free_pages == 100 - admitted[1].pages_needed

    def test_starvation_bound_limits_leapfrogging(self):
        """The starvation bound caps how many blocked requests may be
        passed over per admission round: with two page-hungry requests
        at the head — FEASIBLE (they fit the whole pool) but blocked
        behind a hog's pages — max_skips=1 admits nothing (the fitting
        smalls may not leapfrog further), max_skips=2 admits them.
        (Requests that could NEVER fit are rejected at submit, not
        skipped — see test_oversized_footprint_rejected_at_submit.)"""
        def build(max_skips):
            cb = ContinuousBatcher(num_slots=4, total_pages=10,
                                   max_skips=max_skips)
            hog = Request(rid=9, prompt_len=64, max_new_tokens=32)
            cb.submit(hog)                  # 6 pages -> 4 left free
            assert [r.rid for r in cb.admit()] == [9]
            cb.submit(Request(rid=0, prompt_len=64, max_new_tokens=64))
            cb.submit(Request(rid=1, prompt_len=64, max_new_tokens=64))
            cb.submit(Request(rid=2, prompt_len=16, max_new_tokens=8))
            cb.submit(Request(rid=3, prompt_len=16, max_new_tokens=8))
            return cb

        strict = build(max_skips=1)
        assert [r.rid for r in strict.admit()] == []
        assert [r.rid for r in strict.queue] == [0, 1, 2, 3]  # FIFO kept

        loose = build(max_skips=2)
        assert [r.rid for r in loose.admit()] == [2, 3]
        assert [r.rid for r in loose.queue] == [0, 1]
