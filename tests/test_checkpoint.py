"""Checkpoint/restart: roundtrip, commit protocol, retention, elasticity."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import restore_pytree, save_pytree
from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.standard_normal((16, 8)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.standard_normal((4, 4, 4)),
                                    jnp.bfloat16),
                   "c": jnp.asarray(rng.integers(0, 100, (7,)), jnp.int32)},
        "scalar": jnp.asarray(3, jnp.int32),
    }


def _assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(
            np.asarray(x, np.float32), np.asarray(y, np.float32))


class TestRoundtrip:
    def test_save_restore(self, tmp_path):
        t = _tree()
        save_pytree(t, str(tmp_path / "ck"))
        r = restore_pytree(t, str(tmp_path / "ck"))
        _assert_tree_equal(t, r)

    def test_restore_into_abstract(self, tmp_path):
        t = _tree()
        save_pytree(t, str(tmp_path / "ck"))
        abstract = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
        r = restore_pytree(abstract, str(tmp_path / "ck"))
        _assert_tree_equal(t, r)

    def test_corruption_detected(self, tmp_path):
        t = _tree()
        d = str(tmp_path / "ck")
        save_pytree(t, d)
        # flip bytes in a chunk file (extension depends on the codec)
        victim = [f for f in os.listdir(d)
                  if f.endswith((".zstd", ".zlib", ".zst"))][0]
        path = os.path.join(d, victim)
        blob = bytearray(open(path, "rb").read())
        blob[10] ^= 0xFF
        open(path, "wb").write(bytes(blob))
        with pytest.raises(AssertionError, match="corrupt"):
            restore_pytree(t, d)

    def test_zlib_codec_roundtrip(self, tmp_path):
        """The stdlib fallback codec must roundtrip without zstandard."""
        t = _tree()
        d = str(tmp_path / "ck")
        save_pytree(t, d, codec="zlib")
        assert any(f.endswith(".zlib") for f in os.listdir(d))
        r = restore_pytree(t, d)
        _assert_tree_equal(t, r)


class TestCommitProtocol:
    def test_uncommitted_invisible(self, tmp_path):
        t = _tree()
        d = str(tmp_path / "root")
        mgr = CheckpointManager(d)
        mgr.save(1, t, blocking=True)
        # simulate a torn write: step_2 without COMMIT
        os.makedirs(os.path.join(d, "step_2"))
        assert mgr.latest_step() == 1
        # and a fresh manager garbage-collects it
        mgr2 = CheckpointManager(d)
        assert not os.path.exists(os.path.join(d, "step_2"))

    def test_keep_n(self, tmp_path):
        t = _tree()
        mgr = CheckpointManager(str(tmp_path / "r"), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, t, blocking=True)
        assert mgr.steps() == [3, 4]

    def test_restore_or_init(self, tmp_path):
        t = _tree()
        mgr = CheckpointManager(str(tmp_path / "r"))
        got, step = mgr.restore_or_init(t, lambda: t)
        assert step == 0
        mgr.save(7, t, blocking=True)
        got, step = mgr.restore_or_init(t, lambda: None)
        assert step == 7
        _assert_tree_equal(t, got)

    def test_async_save_overlaps(self, tmp_path):
        t = _tree()
        mgr = CheckpointManager(str(tmp_path / "r"))
        mgr.save(1, t, blocking=False)   # returns immediately
        mgr.wait()
        assert mgr.steps() == [1]


class TestElasticRestore:
    def test_restore_with_different_sharding(self, tmp_path):
        """Save unsharded, restore with an explicit (1-device) mesh
        sharding — the mesh-shape-at-restore-time path."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        t = _tree()
        d = str(tmp_path / "ck")
        save_pytree(t, d)
        mesh = jax.make_mesh((1,), ("data",))
        sh = jax.tree.map(lambda x: NamedSharding(mesh, P()), t)
        r = restore_pytree(t, d, shardings=sh)
        _assert_tree_equal(t, r)
        for leaf in jax.tree.leaves(r):
            assert leaf.sharding.mesh.shape["data"] == 1
