"""Chunked prefill inside the fused serve loop (PR 3).

Pins the tentpole invariants:

  * chunked prefill at ANY token budget is bitwise-identical to the
    whole-prompt prefill — logits, pool contents, page tables — across
    prompt lengths that straddle page boundaries;
  * serve() with chunked admission still reproduces `generate` bitwise
    (tokens, StepStats, pool contents) for a single greedy request;
  * ONE serve-chunk executable across a stream spanning >= 3 distinct
    page-rounded prompt lengths (admission compiles nothing);
  * admission fairness / starvation bounds hold while long prompts
    prefill across several chunks (hypothesis-optional property plus
    always-run smoke cases);
  * the lane state machine (queued -> prefilling -> decoding -> done)
    and the ServeReport TTFT/TPOT stamps.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro import configs
from repro.core.tiers import GH200
from repro.kvcache.paged import (
    init_cache, prefill_cache, write_token_layer, write_tokens_layer,
)
from repro.models.model import Model
from repro.serving.engine import EngineConfig, ServeReport, ServingEngine
from repro.serving.scheduler import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def dense_model():
    cfg = configs.get_smoke("internlm2-1.8b")
    m = Model(cfg)
    return m, m.init(jax.random.key(0))


def _cfg(stride=4, prefill_chunk=16, **kw):
    return EngineConfig(max_context=128, hbm_fraction=0.25,
                        policy="importance", attention_sparsity=0.0,
                        spec=GH200, promote_thresh=0.005,
                        telemetry_stride=stride,
                        prefill_chunk=prefill_chunk, **kw)


def _pools(cache):
    return (cache.k_hbm, cache.v_hbm, cache.k_host, cache.v_host)


class TestChunkedForwardParity:
    """Model.prefill_chunk vs the whole-prompt forward, straight at the
    model layer: same logits, same cache, any chunking."""

    @pytest.mark.parametrize("S,C", [(15, 4), (17, 16), (33, 6)])
    def test_matches_whole_prompt_prefill(self, dense_model, S, C):
        model, params = dense_model
        rng = np.random.default_rng(S)
        prompt = rng.integers(0, model.cfg.vocab, (S,))
        geo = model.cache_geometry(1, 128)
        logits_full, (k, v) = model.forward(
            params, jnp.asarray(prompt[None], jnp.int32), collect_kv=True)
        ref = prefill_cache(geo, k, v, S)

        pf = jax.jit(lambda c, t, s, n: model.prefill_chunk(params, c, t,
                                                            s, n))
        cache = init_cache(geo)
        buf = np.zeros((1, geo.max_tokens), np.int32)
        buf[0, :S] = prompt
        prog, last = 0, None
        while prog < S:
            nv = min(C, S - prog)
            idx = np.clip(prog + np.arange(C), 0, geo.max_tokens - 1)
            lg, cache = pf(cache, jnp.asarray(buf[:, idx]),
                           jnp.asarray([prog], jnp.int32),
                           jnp.asarray([nv], jnp.int32))
            last = lg[0, nv - 1]
            prog += nv
        np.testing.assert_array_equal(np.asarray(last),
                                      np.asarray(logits_full[0, S - 1]))
        for got, want in zip(jax.tree.leaves(cache), jax.tree.leaves(ref)):
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(want))

    def test_chunk_straddles_page_and_tier_boundaries(self, dense_model):
        """A single slice crossing a page boundary writes both pages;
        one crossing the HBM/host pool boundary writes both pools."""
        model, params = dense_model
        rng = np.random.default_rng(0)
        geo = dataclasses.replace(model.cache_geometry(1, 128),
                                  hbm_pages=1, host_pages=3)
        S = 40                                # pages 0..2, page 1+ on host
        prompt = rng.integers(0, model.cfg.vocab, (S,))
        pf = jax.jit(lambda c, t, s, n: model.prefill_chunk(params, c, t,
                                                            s, n))
        cache = init_cache(geo)
        buf = np.zeros((1, geo.max_tokens), np.int32)
        buf[0, :S] = prompt
        for prog in range(0, S, 20):          # 20-token slices: 16+4
            nv = min(20, S - prog)
            idx = np.clip(prog + np.arange(20), 0, geo.max_tokens - 1)
            _, cache = pf(cache, jnp.asarray(buf[:, idx]),
                          jnp.asarray([prog], jnp.int32),
                          jnp.asarray([nv], jnp.int32))
        assert int(cache.length[0]) == S
        np.testing.assert_array_equal(np.asarray(cache.hbm_owner[0, 0]),
                                      [0])
        np.testing.assert_array_equal(np.asarray(cache.host_owner[0, 0]),
                                      [1, 2, -1])
        # partial page 2 (8 tokens) is placement-visible
        _, _, _, ev = cache.tier_lists(layer=0)
        np.testing.assert_array_equal(np.asarray(ev[0]), [16, 8, 0])


class TestServeBudgetInvariance:
    """serve() outputs and final cache contents are bitwise-identical
    at every prefill budget, including whole-prompt-in-one-step."""

    def _serve(self, model, params, budget, reqs):
        eng = ServingEngine(model, params, _cfg(prefill_chunk=budget))
        report = eng.serve(reqs, num_slots=len(reqs), seed=7)
        outs = {r.rid: list(r.output) for r in report}
        return outs, eng._cache

    @pytest.mark.parametrize("budget", [3, 16, 24])
    def test_budget_bitwise_invariant(self, dense_model, budget):
        model, params = dense_model
        rng = np.random.default_rng(3)
        # page-straddling prompt lengths: 15/17/33 over 16-token pages;
        # submit() resets per-run state, so the same Request objects
        # drive both serves
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, model.cfg.vocab, (ln,)),
                        max_new_tokens=5)
                for i, ln in enumerate((15, 17, 33))]
        outs, cache = self._serve(model, params, budget, reqs)
        # budget 512 >= any prompt: the whole prompt in one mixed step
        ref_outs, ref_cache = self._serve(model, params, 512, reqs)
        assert outs == ref_outs
        for got, want in zip(jax.tree.leaves(cache),
                             jax.tree.leaves(ref_cache)):
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(want))


class TestServeGenerateParity:
    """A single full-length greedy request through chunked-prefill
    serve still reproduces prefill + fused `generate` bitwise."""

    @pytest.mark.parametrize("budget,S", [(5, 32), (16, 21), (512, 32)])
    def test_tokens_stats_pools_match_generate(self, dense_model, budget,
                                               S):
        model, params = dense_model
        rng = np.random.default_rng(11)
        prompt = rng.integers(0, model.cfg.vocab, (S,))
        n = 9

        ref = ServingEngine(model, params, _cfg())
        logits0 = ref.start(jnp.asarray(prompt[None], jnp.int32))
        tok0 = jnp.argmax(logits0, -1).astype(jnp.int32)
        toks = ref.generate(tok0, n - 1)
        want = [int(tok0[0])] + [int(t) for t in np.asarray(toks)[:, 0]]

        eng = ServingEngine(model, params, _cfg(prefill_chunk=budget))
        report = eng.serve(
            [Request(rid=0, prompt=prompt, max_new_tokens=n)],
            num_slots=1)
        assert report[0].output == want
        assert eng.stats == ref.stats
        # the write history (prompt pages + decode tokens + migrations)
        # is bitwise the same program; release only clears the tables
        for got, want_p in zip(_pools(eng._cache), _pools(ref._cache)):
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(want_p))


class TestMixedStreamRetraces:
    def test_three_page_rounded_lengths_one_executable(self, dense_model):
        """Prompts spanning >= 3 distinct page-rounded lengths (1..4
        pages) serve through ONE executable: admission compiles
        nothing, whatever lengths arrive."""
        model, params = dense_model
        rng = np.random.default_rng(5)
        lengths = (16, 17, 40, 55, 33, 64)     # 1, 2, 3, 4, 3, 4 pages
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, model.cfg.vocab, (ln,)),
                        max_new_tokens=3 + (i % 3))
                for i, ln in enumerate(lengths)]
        eng = ServingEngine(model, params, _cfg(prefill_chunk=16))
        report = eng.serve(reqs, num_slots=2, seed=1)
        assert sorted(r.rid for r in report) == list(range(len(lengths)))
        for r in report:
            assert len(r.output) == r.max_new_tokens
            assert r.prefilled == r.prompt_len
            assert r.phase == "done"
        assert eng._serve_jit._cache_size() == 1
        assert eng.batcher.free_pages == eng.batcher.total_pages

    def test_prefill_spans_chunks_while_others_decode(self, dense_model):
        """A long prompt at a tiny budget prefills across several chunk
        boundaries (progress is visible between them) while a short
        request decodes — the serialization PR 2 had is gone."""
        model, params = dense_model
        rng = np.random.default_rng(6)
        long = Request(rid=0,
                       prompt=rng.integers(0, model.cfg.vocab, (64,)),
                       max_new_tokens=2)
        short = Request(rid=1,
                        prompt=rng.integers(0, model.cfg.vocab, (16,)),
                        max_new_tokens=12)
        eng = ServingEngine(model, params, _cfg(stride=4,
                                                prefill_chunk=4))
        report = eng.serve([long, short], num_slots=2, seed=0)
        # 64 tokens / (4 per step * 4 steps per chunk) = 4 chunks of
        # prefill; the short request decoded through those same chunks
        # and finished before the long one
        assert {r.rid for r in report} == {0, 1}
        assert long.first_token_at > short.first_token_at
        assert len(long.output) == 2 and len(short.output) == 12


class TestAdmissionFairnessUnderPressure:
    """Satellite: starvation bound under page pressure with mixed
    prompt lengths — long prompts prefill across several chunks while
    short ones queue."""

    def _run(self, model, params, lengths, budgets, *, total_pages,
             max_skips, num_slots=2, prefill_chunk=8):
        reqs = [Request(rid=i, prompt=np.arange(ln) % model.cfg.vocab,
                        max_new_tokens=b)
                for i, (ln, b) in enumerate(zip(lengths, budgets))]
        eng = ServingEngine(model, params,
                            _cfg(stride=4, prefill_chunk=prefill_chunk))
        report = eng.serve(reqs, num_slots=num_slots,
                           total_pages=total_pages, max_skips=max_skips,
                           seed=0)
        return eng, report, reqs

    def test_long_prefill_does_not_starve_queued_shorts(self,
                                                        dense_model):
        model, params = dense_model
        # 80-token prompt = 6 pages incl. decode; pool of 8 pages keeps
        # one short queued while the long one prefills for 20+ steps
        lengths = (80, 16, 16, 16)
        budgets = (4, 4, 4, 4)
        eng, report, reqs = self._run(model, params, lengths, budgets,
                                      total_pages=8, max_skips=1)
        assert sorted(r.rid for r in report) == [0, 1, 2, 3]
        for r in report:
            assert len(r.output) == r.max_new_tokens
        # the queued shorts were admitted only as pages freed — after
        # the stream, accounting balances exactly
        assert eng.batcher.free_pages == 8
        assert max(r.started_step for r in reqs) > 0

    def test_max_skips_still_bounds_leapfrogging(self, dense_model):
        """While a long request holds pages in prefill, a second long
        request at the queue head may be passed over at most max_skips
        times per admission round (scheduler-level bound unchanged by
        the mixed-step rework)."""
        cb = ContinuousBatcher(num_slots=4, total_pages=6, max_skips=1)
        cb.submit(Request(rid=0, prompt_len=64, max_new_tokens=16))  # 5p
        cb.admit()
        cb.submit(Request(rid=1, prompt_len=64, max_new_tokens=16))  # 5p
        cb.submit(Request(rid=2, prompt_len=8, max_new_tokens=8))    # 1p
        cb.submit(Request(rid=3, prompt_len=8, max_new_tokens=8))    # 1p
        # rid=1 cannot fit (1 page free) and may be skipped once: only
        # rid=2 leapfrogs, rid=3 stays FIFO-queued behind the bound
        assert [r.rid for r in cb.admit()] == [2]
        assert [r.rid for r in cb.queue] == [1, 3]

    @given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
    @settings(max_examples=5, deadline=None)
    def test_property_streams_complete_and_balance(self, dense_model,
                                                   seed):
        model, params = dense_model
        rng = np.random.default_rng(seed)
        lengths = rng.integers(8, 72, size=4)
        budgets = rng.integers(1, 8, size=4)
        # a few discrete budgets only, so the property run compiles at
        # most 3 serve-chunk executables across all examples
        eng, report, _ = self._run(model, params, lengths, budgets,
                                   total_pages=12, max_skips=2,
                                   prefill_chunk=(4, 8, 16)[seed % 3])
        assert sorted(r.rid for r in report) == [0, 1, 2, 3]
        for r, b in zip(sorted(report, key=lambda r: r.rid), budgets):
            assert len(r.output) == b
        assert eng.batcher.free_pages == 12


class TestServeReportAndPhases:
    def test_report_percentiles_and_stamps(self, dense_model):
        model, params = dense_model
        rng = np.random.default_rng(9)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, model.cfg.vocab, (24,)),
                        max_new_tokens=4) for i in range(3)]
        eng = ServingEngine(model, params, _cfg())
        report = eng.serve(reqs, num_slots=2, seed=0)
        assert isinstance(report, ServeReport)
        assert len(report) == 3 and report[0] in report.completed
        for key in ("p50", "p95", "mean"):
            assert report.ttft[key] >= 0.0
            assert report.tpot[key] >= 0.0
        assert report.ttft["p50"] <= report.ttft["p95"]
        for r in report:
            assert r.submitted_at <= r.first_token_at <= r.finished_at
            assert r.phase == "done"

    def test_single_token_requests_excluded_from_tpot(self, dense_model):
        model, params = dense_model
        rng = np.random.default_rng(10)
        reqs = [Request(rid=0,
                        prompt=rng.integers(0, model.cfg.vocab, (16,)),
                        max_new_tokens=1)]
        eng = ServingEngine(model, params, _cfg())
        report = eng.serve(reqs, num_slots=1)
        assert report.ttft and not report.tpot

    def test_phase_machine_through_scheduler(self):
        cb = ContinuousBatcher(num_slots=1, total_pages=16)
        r = Request(rid=0, prompt_len=32, max_new_tokens=4)
        cb.submit(r)
        assert r.phase == "queued" and r.submitted_at > 0.0
        cb.admit()
        assert r.phase == "prefilling"
        view = cb.device_view()
        assert view.prompt_len[0] == 32 and view.prefilled[0] == 0
        r.prefilled = 32
        assert cb.device_view().prefilled[0] == 32
        cb.complete(r)
        assert r.phase == "done" and r.finished_at >= r.submitted_at


class TestWriteTokensLayer:
    def test_matches_sequential_single_token_writes(self):
        """The vectorized slice write is the per-token write, fused:
        same pools for a slice that starts mid-page, crosses a page
        boundary, and spills from the HBM pool into the host pool."""
        rng = np.random.default_rng(0)
        B, P_h, P_e, T, KH, HD = 2, 1, 2, 4, 2, 3
        pools = [jnp.zeros((B, P, T, KH, HD)) for P in (P_h, P_h, P_e,
                                                        P_e)]
        C = 6
        start = np.array([2, 5])               # mid-page offsets
        n_valid = np.array([6, 3])
        k_new = jnp.asarray(rng.standard_normal((B, C, KH, HD)),
                            jnp.float32)
        v_new = jnp.asarray(rng.standard_normal((B, C, KH, HD)),
                            jnp.float32)

        pos = start[:, None] + np.arange(C)[None, :]
        slot = jnp.asarray(pos // T, jnp.int32)
        off = jnp.asarray(pos % T, jnp.int32)
        valid = jnp.asarray(np.arange(C)[None, :] < n_valid[:, None])
        got = write_tokens_layer(*pools, slot, off, k_new, v_new, valid)

        # reference: the single-token primitive, one call per valid
        # token (other lanes parked on an OOB slot and dropped)
        want = list(pools)
        for b in range(B):
            for j in range(int(n_valid[b])):
                p, o = divmod(int(pos[b, j]), T)
                sl = np.full((B,), P_h + P_e, np.int32)
                sl[b] = p
                want = list(write_token_layer(
                    *want, jnp.asarray(sl), jnp.full((B,), o, jnp.int32),
                    k_new[:, j], v_new[:, j]))
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
