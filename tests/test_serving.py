"""Serving engine + continuous batcher behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.tiers import GH200
from repro.models.model import Model
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.scheduler import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def dense_model():
    cfg = configs.get_smoke("internlm2-1.8b")
    m = Model(cfg)
    return m, m.init(jax.random.key(0))


class TestEngine:
    def _drive(self, model, params, policy, steps=10, sparsity=0.5):
        eng = ServingEngine(model, params, EngineConfig(
            max_context=128, hbm_fraction=0.25, policy=policy,
            attention_sparsity=sparsity, spec=GH200))
        rng = np.random.default_rng(0)
        prompts = jnp.asarray(
            rng.integers(0, model.cfg.vocab, (2, 32)), jnp.int32)
        eng.start(prompts)
        tok = jnp.array([1, 2], jnp.int32)
        for _ in range(steps):
            lg = eng.step(tok)
            assert lg.shape == (2, model.cfg.vocab)
            assert np.isfinite(np.asarray(lg, np.float32)).all()
            tok = jnp.argmax(lg, -1).astype(jnp.int32)
        return eng

    def test_static_policy_never_migrates(self, dense_model):
        eng = self._drive(*dense_model, policy="static")
        assert eng.summary()["migrated_bytes"] == 0.0

    def test_importance_policy_stats(self, dense_model):
        eng = self._drive(*dense_model, policy="importance")
        s = eng.summary()
        assert s["steps"] == 10
        assert 0.0 <= s["mean_hbm_hit_rate"] <= 1.0
        assert s["modeled_tokens_per_s"] > 0

    def test_migration_budget_respected(self, dense_model):
        model, params = dense_model
        cfg = EngineConfig(max_context=128, hbm_fraction=0.25,
                           policy="importance", attention_sparsity=0.0,
                           migration_budget_frac=0.05, spec=GH200)
        eng = ServingEngine(model, params, cfg)
        rng = np.random.default_rng(1)
        prompts = jnp.asarray(
            rng.integers(0, model.cfg.vocab, (2, 48)), jnp.int32)
        eng.start(prompts)
        budget_pages = max(1, int(0.05 * eng.geo.hbm_pages))
        tok = jnp.array([1, 2], jnp.int32)
        for _ in range(6):
            eng.step(tok)
        pb = eng.geo.page_bytes()
        L, B = eng.geo.num_layers, eng.geo.batch
        for s in eng.stats:
            assert s.m_in <= budget_pages * pb * L * B


class TestContinuousBatcher:
    def test_admission_and_completion(self):
        cb = ContinuousBatcher(num_slots=2, total_pages=100)
        cb.submit(Request(rid=1, prompt_len=32, max_new_tokens=3))
        cb.submit(Request(rid=2, prompt_len=32, max_new_tokens=5))
        cb.submit(Request(rid=3, prompt_len=32, max_new_tokens=2))
        # slots: r1, r2 admitted; r3 queued
        active = cb.step()
        assert cb.utilization() == 1.0
        for _ in range(10):
            cb.step()
        assert sorted(r.rid for r in cb.completed) == [1, 2, 3]

    def test_page_capacity_blocks_admission(self):
        cb = ContinuousBatcher(num_slots=4, total_pages=10)
        cb.submit(Request(rid=1, prompt_len=64, max_new_tokens=64))  # 8pg
        cb.submit(Request(rid=2, prompt_len=64, max_new_tokens=64))  # 8pg
        cb.step()
        live = [s.request.rid for s in cb.slots if not s.free]
        assert live == [1]      # r2 waits for pages
        # r1 finishes -> its pages free -> r2 admitted
        for _ in range(70):
            cb.step()
        assert any(r.rid == 2 for r in cb.completed) or \
            any(not s.free and s.request.rid == 2 for s in cb.slots)

    def test_page_accounting_balances(self):
        cb = ContinuousBatcher(num_slots=3, total_pages=50)
        for i in range(6):
            cb.submit(Request(rid=i, prompt_len=16, max_new_tokens=4))
        for _ in range(30):
            cb.step()
        assert cb.free_pages == 50
        assert len(cb.completed) == 6
