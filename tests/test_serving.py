"""Serving engine + continuous batcher behaviour."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.tiers import GH200
from repro.models.model import Model
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.scheduler import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def dense_model():
    cfg = configs.get_smoke("internlm2-1.8b")
    m = Model(cfg)
    return m, m.init(jax.random.key(0))


class TestEngine:
    def _drive(self, model, params, policy, steps=10, sparsity=0.5):
        eng = ServingEngine(model, params, EngineConfig(
            max_context=128, hbm_fraction=0.25, policy=policy,
            attention_sparsity=sparsity, spec=GH200))
        rng = np.random.default_rng(0)
        prompts = jnp.asarray(
            rng.integers(0, model.cfg.vocab, (2, 32)), jnp.int32)
        eng.start(prompts)
        tok = jnp.array([1, 2], jnp.int32)
        for _ in range(steps):
            lg = eng.step(tok)
            assert lg.shape == (2, model.cfg.vocab)
            assert np.isfinite(np.asarray(lg, np.float32)).all()
            tok = jnp.argmax(lg, -1).astype(jnp.int32)
        return eng

    def test_static_policy_never_migrates(self, dense_model):
        eng = self._drive(*dense_model, policy="static")
        assert eng.summary()["migrated_bytes"] == 0.0

    def test_importance_policy_stats(self, dense_model):
        eng = self._drive(*dense_model, policy="importance")
        s = eng.summary()
        assert s["steps"] == 10
        assert 0.0 <= s["mean_hbm_hit_rate"] <= 1.0
        assert s["modeled_tokens_per_s"] > 0

    def test_migration_budget_respected(self, dense_model):
        model, params = dense_model
        cfg = EngineConfig(max_context=128, hbm_fraction=0.25,
                           policy="importance", attention_sparsity=0.0,
                           migration_budget_frac=0.05, spec=GH200)
        eng = ServingEngine(model, params, cfg)
        rng = np.random.default_rng(1)
        prompts = jnp.asarray(
            rng.integers(0, model.cfg.vocab, (2, 48)), jnp.int32)
        eng.start(prompts)
        budget_pages = max(1, int(0.05 * eng.geo.hbm_pages))
        tok = jnp.array([1, 2], jnp.int32)
        for _ in range(6):
            eng.step(tok)
        pb = eng.geo.page_bytes()
        L, B = eng.geo.num_layers, eng.geo.batch
        for s in eng.stats:
            assert s.m_in <= budget_pages * pb * L * B


class TestFusedParity:
    """`run`/`generate` (lax.scan fused) vs `step` (eager): identical
    program, so logits must be bitwise equal and StepStats identical."""

    def _engine(self, model, params, policy, sparsity, stride):
        eng = ServingEngine(model, params, EngineConfig(
            max_context=128, hbm_fraction=0.25, policy=policy,
            attention_sparsity=sparsity, spec=GH200,
            promote_thresh=0.005, telemetry_stride=stride))
        rng = np.random.default_rng(0)
        prompts = jnp.asarray(
            rng.integers(0, model.cfg.vocab, (2, 32)), jnp.int32)
        eng.start(prompts)
        return eng

    @pytest.mark.parametrize("policy,sparsity", [
        ("static", 0.0), ("importance", 0.0), ("importance", 0.5)])
    def test_run_matches_eager_steps(self, dense_model, policy, sparsity):
        model, params = dense_model
        k = 7
        rng = np.random.default_rng(3)
        tokens = jnp.asarray(
            rng.integers(0, model.cfg.vocab, (k, 2)), jnp.int32)

        eager = self._engine(model, params, policy, sparsity, stride=32)
        eager_logits = np.stack(
            [np.asarray(eager.step(tokens[i])) for i in range(k)])
        # stride 3 also exercises the ragged final chunk (3 + 3 + 1)
        for stride in (32, 3):
            fused = self._engine(model, params, policy, sparsity, stride)
            fused_logits = np.asarray(fused.run(tokens))
            np.testing.assert_array_equal(fused_logits, eager_logits)
            assert fused.stats == eager.stats

    def test_generate_matches_eager_greedy(self, dense_model):
        model, params = dense_model
        eager = self._engine(model, params, "importance", 0.4, stride=32)
        tok = jnp.array([1, 2], jnp.int32)
        want = []
        for _ in range(6):
            tok = jnp.argmax(eager.step(tok), -1).astype(jnp.int32)
            want.append(np.asarray(tok))
        fused = self._engine(model, params, "importance", 0.4, stride=4)
        got = np.asarray(fused.generate(jnp.array([1, 2], jnp.int32), 6))
        np.testing.assert_array_equal(got, np.stack(want))
        assert fused.stats == eager.stats

    def test_step_compiles_once_with_live_migrations(self, dense_model):
        """The fused step (control plane + decode + migration) must not
        retrace as promote/demote counts vary across steps. The prompt
        spills past the HBM pool so promotions actually fire."""
        model, params = dense_model
        eng = ServingEngine(model, params, EngineConfig(
            max_context=512, hbm_fraction=0.25, policy="importance",
            attention_sparsity=0.0, spec=GH200, promote_thresh=1e-4))
        rng = np.random.default_rng(0)
        prompts = jnp.asarray(
            rng.integers(0, model.cfg.vocab, (1, 272)), jnp.int32)
        eng.start(prompts)
        assert int(np.asarray(
            (eng._cache.host_owner >= 0).sum())) > 0   # host tier in use
        tok = jnp.array([1], jnp.int32)
        for _ in range(8):
            tok = jnp.argmax(eng.step(tok), -1).astype(jnp.int32)
        assert eng._step_jit._cache_size() == 1
        assert sum(s.m_in + s.m_out for s in eng.stats) > 0


class TestDevicePlanner:
    def test_promotes_hottest_host_page_into_coldest_slot(self):
        from repro.kvcache.paged import CacheGeometry, prefill_cache
        from repro.serving import control

        geo = CacheGeometry(num_layers=1, batch=1, page_tokens=4,
                            hbm_pages=2, host_pages=4, kv_heads=2,
                            head_dim=8, dtype=jnp.float32)
        rng = np.random.default_rng(0)
        kv = jnp.asarray(rng.standard_normal((1, 1, 16, 2, 8)), jnp.float32)
        cache = prefill_cache(geo, kv, kv, 16)   # pages 0,1 hbm; 2,3 host
        # page 3 (host slot 1) is hot; page 0 (hbm slot 0) is coldest
        importance = jnp.asarray([[[0.01, 0.3, 0.02, 0.9]]], jnp.float32)
        cache = dataclasses.replace(cache, importance=importance)
        plan, n_pro, n_dem = control.plan_migrations(
            cache, budget=1, promote_thresh=0.05)
        assert int(n_pro) == 1 and int(n_dem) == 1
        assert int(plan.pro_src[0]) == 1      # host slot of page 3
        assert int(plan.pro_dst[0]) == 0      # coldest hbm slot
        assert int(plan.pro_logical[0]) == 3
        assert int(plan.dem_src[0]) == 0      # victim hbm slot
        assert int(plan.dem_dst[0]) == 1      # vacated host slot
        assert int(plan.dem_logical[0]) == 0

    def test_no_promotion_below_threshold(self):
        from repro.kvcache.paged import CacheGeometry, prefill_cache
        from repro.serving import control

        geo = CacheGeometry(num_layers=1, batch=1, page_tokens=4,
                            hbm_pages=2, host_pages=4, kv_heads=2,
                            head_dim=8, dtype=jnp.float32)
        kv = jnp.zeros((1, 1, 16, 2, 8), jnp.float32)
        cache = prefill_cache(geo, kv, kv, 16)
        plan, n_pro, n_dem = control.plan_migrations(
            cache, budget=2, promote_thresh=0.5)
        assert int(n_pro) == 0 and int(n_dem) == 0
        assert np.all(np.asarray(plan.pro_layer) == -1)


class TestContinuousBatcher:
    def test_admission_and_completion(self):
        cb = ContinuousBatcher(num_slots=2, total_pages=100)
        cb.submit(Request(rid=1, prompt_len=32, max_new_tokens=3))
        cb.submit(Request(rid=2, prompt_len=32, max_new_tokens=5))
        cb.submit(Request(rid=3, prompt_len=32, max_new_tokens=2))
        # slots: r1, r2 admitted; r3 queued
        active = cb.step()
        assert cb.utilization() == 1.0
        for _ in range(10):
            cb.step()
        assert sorted(r.rid for r in cb.completed) == [1, 2, 3]

    def test_page_capacity_blocks_admission(self):
        cb = ContinuousBatcher(num_slots=4, total_pages=10)
        cb.submit(Request(rid=1, prompt_len=64, max_new_tokens=64))  # 8pg
        cb.submit(Request(rid=2, prompt_len=64, max_new_tokens=64))  # 8pg
        cb.step()
        live = [s.request.rid for s in cb.slots if not s.free]
        assert live == [1]      # r2 waits for pages
        # r1 finishes -> its pages free -> r2 admitted
        for _ in range(70):
            cb.step()
        assert any(r.rid == 2 for r in cb.completed) or \
            any(not s.free and s.request.rid == 2 for s in cb.slots)

    def test_page_accounting_balances(self):
        cb = ContinuousBatcher(num_slots=3, total_pages=50)
        for i in range(6):
            cb.submit(Request(rid=i, prompt_len=16, max_new_tokens=4))
        for _ in range(30):
            cb.step()
        assert cb.free_pages == 50
        assert len(cb.completed) == 6
