"""Paged two-tier KV cache: tables, tier lists, migration consistency."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.kvcache.migrate import MigrationPlan, apply_migrations
from repro.kvcache.paged import CacheGeometry, prefill_cache


def _geo(hbm=2, host=4, layers=2, batch=2):
    return CacheGeometry(num_layers=layers, batch=batch, page_tokens=4,
                         hbm_pages=hbm, host_pages=host, kv_heads=2,
                         head_dim=8, dtype=jnp.float32)


def _filled_cache(geo, tokens=12, seed=0):
    rng = np.random.default_rng(seed)
    L, B = geo.num_layers, geo.batch
    S = tokens
    k = jnp.asarray(rng.standard_normal((L, B, S, geo.kv_heads,
                                         geo.head_dim)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((L, B, S, geo.kv_heads,
                                         geo.head_dim)), jnp.float32)
    return prefill_cache(geo, k, v, S), k, v


def read_token(cache, geo, l, b, tok):
    """Fetch token `tok`'s K vector through the page table."""
    page = tok // geo.page_tokens
    off = tok % geo.page_tokens
    slot = int(cache.page_table[l, b, page])
    assert slot >= 0
    if slot < geo.hbm_pages:
        return np.asarray(cache.k_hbm[l, b, slot, off])
    return np.asarray(cache.k_host[l, b, slot - geo.hbm_pages, off])


class TestPrefillCache:
    def test_tokens_recoverable(self):
        geo = _geo()
        cache, k, v = _filled_cache(geo)
        for l in range(geo.num_layers):
            for b in range(geo.batch):
                for t in range(12):
                    np.testing.assert_array_equal(
                        read_token(cache, geo, l, b, t),
                        np.asarray(k[l, b, t]))

    def test_static_fill_order(self):
        geo = _geo(hbm=2, host=4)
        cache, _, _ = _filled_cache(geo, tokens=12)   # 3 pages
        # first 2 pages in HBM, third spills to host
        assert int(cache.page_table[0, 0, 0]) == 0
        assert int(cache.page_table[0, 0, 1]) == 1
        assert int(cache.page_table[0, 0, 2]) == geo.hbm_pages

    def test_tier_lists_consistency(self):
        geo = _geo()
        cache, _, _ = _filled_cache(geo, tokens=10)  # 2.5 pages
        hl, hv, el, ev = cache.tier_lists()
        # occupied hbm slots are 0 and 1; valid = 4 and 4
        assert hl[0, 0, 0] == 0 and hl[0, 0, 1] == 1
        assert hv[0, 0, 0] == 4 and hv[0, 0, 1] == 4
        # host slot 0 holds page 2 with 2 valid tokens
        assert el[0, 0, 0] == 0 and ev[0, 0, 0] == 2
        # free slots are holes
        assert el[0, 0, 1] == -1 and ev[0, 0, 1] == 0


class TestMigration:
    def test_roundtrip_preserves_data(self):
        geo = _geo()
        cache, k, _ = _filled_cache(geo, tokens=12)
        before = read_token(cache, geo, 0, 0, 1)   # page 0
        plan = MigrationPlan.build(
            4, [], [(0, 0, 0, 2, 0)])  # demote page0: hbm slot0 -> host 2
        cache = apply_migrations(cache, plan)
        assert int(cache.page_table[0, 0, 0]) == geo.hbm_pages + 2
        np.testing.assert_array_equal(read_token(cache, geo, 0, 0, 1),
                                      before)
        plan = MigrationPlan.build(
            4, [(0, 0, 2, 0, 0)], [])  # promote back
        cache = apply_migrations(cache, plan)
        assert int(cache.page_table[0, 0, 0]) == 0
        np.testing.assert_array_equal(read_token(cache, geo, 0, 0, 1),
                                      before)

    def test_empty_plan_noop(self):
        geo = _geo()
        cache, _, _ = _filled_cache(geo)
        plan = MigrationPlan.empty(8)
        cache2 = apply_migrations(cache, plan)
        np.testing.assert_array_equal(np.asarray(cache.page_table),
                                      np.asarray(cache2.page_table))
        np.testing.assert_array_equal(np.asarray(cache.k_hbm),
                                      np.asarray(cache2.k_hbm))

    def test_swap_same_slot_preserves_both_pages(self):
        """A promote whose vacated host slot receives the demoted victim
        (dem_dst == pro_src, pro_dst == dem_src) must preserve BOTH
        pages — regression for the demote-first ordering that clobbered
        the promoted page before it was read."""
        geo = _geo(hbm=2, host=4)
        cache, k, _ = _filled_cache(geo, tokens=12)   # pages 0,1 hbm; 2 host
        plan = MigrationPlan.build(
            2,
            [(0, 0, 0, 0, 2)],    # promote page 2: host slot 0 -> hbm slot 0
            [(0, 0, 0, 0, 0)])    # demote page 0: hbm slot 0 -> host slot 0
        cache = apply_migrations(cache, plan)
        pt = np.asarray(cache.page_table)
        assert pt[0, 0, 2] == 0                      # promoted into hbm
        assert pt[0, 0, 0] == geo.hbm_pages          # demoted into host 0
        ho = np.asarray(cache.hbm_owner)
        eo = np.asarray(cache.host_owner)
        assert ho[0, 0, 0] == 2 and eo[0, 0, 0] == 0
        for t in range(12):
            np.testing.assert_array_equal(read_token(cache, geo, 0, 0, t),
                                          np.asarray(k[0, 0, t]))

    def test_apply_migrations_not_retraced_across_counts(self):
        """Fixed-capacity plans: varying live promote/demote counts must
        reuse one executable (no per-step recompiles)."""
        geo = _geo(hbm=2, host=4)
        cache, _, _ = _filled_cache(geo, tokens=12)
        apply_jit = jax.jit(apply_migrations)
        demotes = [(0, 0, 0, 1, 0), (1, 0, 0, 1, 0), (0, 1, 0, 1, 0)]
        for n in (0, 1, 2, 3):
            apply_jit(cache, MigrationPlan.build(4, [], demotes[:n]))
        assert apply_jit._cache_size() == 1

    @given(st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_random_migration_sequences_consistent(self, seed):
        """After arbitrary valid swaps, page_table and owner maps stay
        mutually consistent and all tokens remain readable."""
        rng = np.random.default_rng(seed)
        geo = _geo(hbm=2, host=4)
        cache, k, _ = _filled_cache(geo, tokens=12, seed=seed)
        for _ in range(4):
            pt = np.asarray(cache.page_table)
            ho = np.asarray(cache.hbm_owner)
            eo = np.asarray(cache.host_owner)
            l = int(rng.integers(0, geo.num_layers))
            b = int(rng.integers(0, geo.batch))
            # pick a random demote (occupied hbm slot -> free host slot)
            occ = np.nonzero(ho[l, b] >= 0)[0]
            free = np.nonzero(eo[l, b] < 0)[0]
            if len(occ) and len(free):
                slot = int(rng.choice(occ))
                plan = MigrationPlan.build(
                    2, [], [(l, b, slot, int(free[0]),
                             int(ho[l, b, slot]))])
                cache = apply_migrations(cache, plan)
        # consistency: every alive logical page readable & owners match
        pt = np.asarray(cache.page_table)
        ho = np.asarray(cache.hbm_owner)
        eo = np.asarray(cache.host_owner)
        for l in range(geo.num_layers):
            for b in range(geo.batch):
                for page in range(3):
                    slot = pt[l, b, page]
                    assert slot >= 0
                    if slot < geo.hbm_pages:
                        assert ho[l, b, slot] == page
                    else:
                        assert eo[l, b, slot - geo.hbm_pages] == page
                for t in range(12):
                    np.testing.assert_array_equal(
                        read_token(cache, geo, l, b, t),
                        np.asarray(k[l, b, t]))


class TestGeometry:
    def test_padding_to_mesh(self):
        geo = CacheGeometry.for_context(
            num_layers=2, batch=1, context=32768, kv_heads=8, head_dim=128,
            hbm_fraction=0.25, pad_to=16)
        assert geo.hbm_pages % 16 == 0
        assert geo.host_pages % 16 == 0
        assert geo.max_tokens >= 32768

    @given(st.integers(64, 100_000), st.floats(0.05, 0.95))
    @settings(max_examples=30, deadline=None)
    def test_capacity_sufficient(self, context, frac):
        geo = CacheGeometry.for_context(
            num_layers=1, batch=1, context=context, kv_heads=2,
            head_dim=16, hbm_fraction=frac, pad_to=16)
        assert geo.max_tokens >= context
