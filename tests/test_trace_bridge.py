"""Live-telemetry -> simulator bridge (repro.serving.trace_bridge).

The load-bearing pin: live static placement and SIMULATED static
placement are the same deterministic rule, so pricing the captured
stream and replaying it through the simulator must agree to float
tolerance — that equality is what makes the reported bound fraction a
number, not a vibe.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.placement.base import HBM, UNALLOC
from repro.core.sa import SAConfig
from repro.core.tiers import GH200
from repro.models.model import Model
from repro.serving import trace_bridge
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.scheduler import Request

STEPS = 24
PROMPT = 272          # spills past the 16-page HBM pool (ctx 512)
SA_CFG = SAConfig(max_evaluations=12, iters_per_level=4, seed=0)


@pytest.fixture(scope="module")
def dense_model():
    cfg = configs.get_smoke("internlm2-1.8b")
    m = Model(cfg)
    return m, m.init(jax.random.key(0))


def _drive(model, params, policy):
    eng = ServingEngine(model, params, EngineConfig(
        max_context=512, hbm_fraction=0.25, policy=policy,
        attention_sparsity=0.5, spec=GH200, promote_thresh=1e-4,
        telemetry_stride=8, trace_telemetry=True))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, model.cfg.vocab, (1, PROMPT)),
                          jnp.int32)
    eng.start(prompts)
    eng.generate(jnp.array([1], jnp.int32), STEPS)
    return eng, trace_bridge.collect(eng)


@pytest.fixture(scope="module")
def static_rec(dense_model):
    return _drive(*dense_model, "static")


@pytest.fixture(scope="module")
def importance_rec(dense_model):
    return _drive(*dense_model, "importance")


class TestRecord:
    def test_shapes_and_codes(self, static_rec):
        eng, rec = static_rec
        L = eng.geo.num_layers
        P = eng.geo.max_pages
        assert rec.access.shape == (STEPS, L, P)
        assert rec.tier.shape == (STEPS, L, P)
        assert rec.moves.shape == (STEPS, 2)
        assert set(np.unique(rec.tier)) <= {UNALLOC, 0, 1}
        # a page is only ever read while it exists
        assert not np.any(rec.access & (rec.tier == UNALLOC))

    def test_pages_exist_monotonically(self, static_rec):
        _, rec = static_rec
        exists = rec.tier != UNALLOC
        assert np.all(exists[1:] >= exists[:-1])

    def test_layer_trace_roundtrip(self, static_rec):
        eng, rec = static_rec
        for layer in range(rec.num_layers):
            tr = trace_bridge.layer_trace(rec, layer)   # .validate()s
            prompt_pages = -(-PROMPT // rec.page_tokens)
            assert np.all(tr.page_born[:prompt_pages] == 0)
            assert tr.prompt_len == PROMPT
            assert tr.decode_len == STEPS
            assert 0.0 < tr.sparsity < 1.0

    def test_migration_counts_match_planner_telemetry(self,
                                                      importance_rec):
        """Tier transitions must recover exactly the promote counts the
        planner reported (batch 1; the final step's moves are
        unobservable by construction)."""
        _, rec = importance_rec
        m_in = np.zeros(rec.num_steps, np.int64)
        for layer in range(rec.num_layers):
            p, _ = trace_bridge.layer_migrations(rec, layer)
            m_in += p
        np.testing.assert_array_equal(m_in[:-1], rec.moves[:-1, 0])
        assert rec.moves.sum() > 0      # the stream actually migrated

    def test_collect_without_capture_raises(self, dense_model):
        model, params = dense_model
        eng = ServingEngine(model, params, EngineConfig(policy="static"))
        with pytest.raises(ValueError, match="trace_telemetry"):
            trace_bridge.collect(eng)

    def test_collect_serve_without_capture_raises(self, dense_model):
        """serve() accepts capture since PR 5; collecting a stream that
        never captured still fails loudly."""
        model, params = dense_model
        eng = ServingEngine(model, params, EngineConfig(policy="static"))
        eng.serve([Request(rid=0, prompt=np.arange(8),
                           max_new_tokens=2)])
        with pytest.raises(ValueError, match="trace_telemetry"):
            trace_bridge.collect_serve(eng)


class TestScoring:
    def test_live_static_equals_simulated_static(self, static_rec):
        """The bridge's self-test: same placement rule, same access
        pattern, same cost model -> same number."""
        _, rec = static_rec
        score = trace_bridge.score_headroom(rec, GH200, oracles=())
        assert score["live_total_s"] > 0
        assert score["headroom_vs_static"] == pytest.approx(1.0,
                                                            rel=1e-9)

    def test_hit_fraction_counts_hbm_reads(self, static_rec):
        _, rec = static_rec
        frac = trace_bridge.hit_fraction(rec)
        assert 0.0 < frac < 1.0
        hits = int((rec.access & (rec.tier == HBM)).sum())
        assert frac == pytest.approx(hits / int(rec.access.sum()))

    def test_dynamic_policy_beats_static_and_bound_holds(
            self, static_rec, importance_rec):
        _, srec = static_rec
        _, irec = importance_rec
        s = trace_bridge.score_headroom(srec, GH200, sa_cfg=SA_CFG)
        i = trace_bridge.score_headroom(irec, GH200, sa_cfg=SA_CFG)
        # the deployable policy converts host reads into HBM hits
        assert i["live_hit_fraction"] > s["live_hit_fraction"]
        assert i["live_total_s"] < s["live_total_s"]
        # the SA oracle lower-bounds (faster-than) both live streams'
        # static baseline, and the bound fraction is a sane ratio
        assert i["sa_total_s"] <= i["static_total_s"] * 1.001
        assert 0.0 < s["bound_fraction"] <= 1.001
        assert s["bound_fraction"] < i["bound_fraction"] <= 1.2
