"""Pallas kernel correctness: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode executes the kernel body on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.paged_attention import paged_attention


def _rand_paged(rng, B, KH, G, HD, P, T, N, dtype):
    q = jnp.asarray(rng.standard_normal((B, KH, G, HD)), dtype)
    kp = jnp.asarray(rng.standard_normal((B, P, T, KH, HD)), dtype)
    vp = jnp.asarray(rng.standard_normal((B, P, T, KH, HD)), dtype)
    pl = jnp.asarray(rng.integers(-1, P, (B, N)), jnp.int32)
    pv = jnp.asarray(rng.integers(0, T + 1, (B, N)), jnp.int32)
    return q, kp, vp, pl, pv


PAGED_SHAPES = [
    # (B, KH, G, HD, P, T, N)
    (1, 1, 1, 64, 4, 16, 4),
    (2, 4, 2, 128, 8, 16, 6),
    (2, 2, 8, 128, 16, 16, 16),   # qwen3-like G=8
    (1, 8, 1, 64, 8, 16, 8),      # zamba2-like MHA
    (3, 2, 5, 128, 8, 16, 5),     # llama4-like G=5
]


class TestPagedAttentionKernel:
    @pytest.mark.parametrize("shape", PAGED_SHAPES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, shape, dtype):
        B, KH, G, HD, P, T, N = shape
        rng = np.random.default_rng(hash(shape) % 2**31)
        q, kp, vp, pl, pv = _rand_paged(rng, B, KH, G, HD, P, T, N, dtype)
        o_r, m_r, l_r, lse_r = ref.paged_attention_ref(q, kp, vp, pl, pv)
        o_k, m_k, l_k, lse_k = paged_attention(q, kp, vp, pl, pv,
                                               interpret=True)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(o_k, np.float32),
                                   np.asarray(o_r, np.float32), atol=tol)
        np.testing.assert_allclose(m_k, m_r, atol=1e-4)
        np.testing.assert_allclose(l_k, l_r, rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(lse_k, lse_r, atol=1e-3)

    def test_all_holes(self):
        """A tier with nothing resident: l == 0, out finite."""
        rng = np.random.default_rng(0)
        q, kp, vp, _, _ = _rand_paged(rng, 2, 2, 2, 64, 4, 16, 4,
                                      jnp.float32)
        pl = jnp.full((2, 4), -1, jnp.int32)
        pv = jnp.zeros((2, 4), jnp.int32)
        o, m, l, lse = paged_attention(q, kp, vp, pl, pv, interpret=True)
        assert np.all(np.asarray(l) == 0.0)
        assert np.all(np.isfinite(np.asarray(o)))

    def test_pool_attention_matches_identity_paged(self):
        """Gather-free SPMD path == paged oracle with identity layout."""
        rng = np.random.default_rng(1)
        B, KH, G, HD, P, T = 2, 4, 2, 64, 8, 16
        q, kp, vp, _, _ = _rand_paged(rng, B, KH, G, HD, P, T, P,
                                      jnp.float32)
        valid = jnp.asarray(rng.integers(0, T + 1, (B, P)), jnp.int32)
        plist = jnp.where(valid > 0, jnp.arange(P, dtype=jnp.int32)[None],
                          jnp.int32(-1))
        o1, m1, l1, lse1 = ref.paged_attention_ref(q, kp, vp, plist, valid)
        o2, m2, l2, lse2 = ref.pool_attention_ref(q, kp, vp, valid)
        np.testing.assert_allclose(o1, o2, atol=1e-5)
        np.testing.assert_allclose(lse1, lse2, atol=1e-4)


class TestTierMerge:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_two_tier_merge_equals_single_pool(self, seed):
        """Splitting pages across two tiers + LSE merge == one big pool."""
        rng = np.random.default_rng(seed)
        B, KH, G, HD, T = 1, 2, 2, 32, 8
        P = 6
        q = jnp.asarray(rng.standard_normal((B, KH, G, HD)), jnp.float32)
        kp = jnp.asarray(rng.standard_normal((B, P, T, KH, HD)), jnp.float32)
        vp = jnp.asarray(rng.standard_normal((B, P, T, KH, HD)), jnp.float32)
        valid = jnp.asarray(rng.integers(1, T + 1, (B, P)), jnp.int32)

        # single pool
        o_all, m_all, l_all, _ = ref.pool_attention_ref(q, kp, vp, valid)

        # split: first 2 pages tier A, rest tier B
        cut = 2
        oa = ref.pool_attention_ref(q, kp[:, :cut], vp[:, :cut],
                                    valid[:, :cut])
        ob = ref.pool_attention_ref(q, kp[:, cut:], vp[:, cut:],
                                    valid[:, cut:])
        merged, lse = ref.merge_partials([oa[:3], ob[:3]])
        np.testing.assert_allclose(np.asarray(merged),
                                   np.asarray(o_all), atol=1e-5)

    def test_merge_associativity(self):
        rng = np.random.default_rng(7)
        B, KH, G, HD, T, P = 1, 1, 1, 16, 16, 9
        q = jnp.asarray(rng.standard_normal((B, KH, G, HD)), jnp.float32)
        kp = jnp.asarray(rng.standard_normal((B, P, T, KH, HD)), jnp.float32)
        vp = jnp.asarray(rng.standard_normal((B, P, T, KH, HD)), jnp.float32)
        valid = jnp.full((B, P), T, jnp.int32)
        parts = [ref.pool_attention_ref(q, kp[:, i:i+3], vp[:, i:i+3],
                                        valid[:, i:i+3])[:3]
                 for i in (0, 3, 6)]
        m1, _ = ref.merge_partials(parts)
        # merge in a different association order
        a, _ = ref.merge_partials(parts[:2])
        # merge_partials needs (out, m, l); recompute m,l for merged pair
        o_all, m_all, l_all, _ = ref.pool_attention_ref(
            q, kp[:, :6], vp[:, :6], valid[:, :6])
        m2, _ = ref.merge_partials([(o_all, m_all, l_all), parts[2]])
        np.testing.assert_allclose(np.asarray(m1), np.asarray(m2),
                                   atol=1e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("B,H,S,D,qb,kb", [
        (1, 1, 128, 64, 64, 64),
        (2, 3, 256, 64, 128, 64),
        (1, 2, 512, 128, 128, 256),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_oracle(self, B, H, S, D, qb, kb, dtype, causal):
        rng = np.random.default_rng(B * 100 + S)
        q = jnp.asarray(rng.standard_normal((B, H, S, D)), dtype)
        k = jnp.asarray(rng.standard_normal((B, H, S, D)), dtype)
        v = jnp.asarray(rng.standard_normal((B, H, S, D)), dtype)
        out = flash_attention_bhsd(q, k, v, causal=causal, q_block=qb,
                                   k_block=kb, interpret=True)
        oref = ref.flash_attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal).transpose(0, 2, 1, 3)
        tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(oref, np.float32), atol=tol)

    def test_flash_jnp_chunked_matches_naive(self):
        from repro.models.layers import flash_attention_jnp, naive_attention
        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.standard_normal((2, 256, 4, 32)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((2, 256, 4, 32)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((2, 256, 4, 32)), jnp.float32)
        a = flash_attention_jnp(q, k, v, causal=True, q_chunk=64, k_chunk=64)
        b = naive_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
