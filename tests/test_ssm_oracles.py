"""Recurrent-family numerics: chunked parallel forms vs sequential
oracles vs one-token decode (Mamba2 SSD, mLSTM, sLSTM)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import ssm, xlstm
from repro.models.config import ModelConfig, SSMConfig, XLSTMConfig
from repro.models.params import init_params


def _ssm_cfg(chunk=8, heads=4, d=64, N=16):
    return ModelConfig(name="t", family="ssm", num_layers=1, d_model=d,
                       num_heads=heads, kv_heads=heads, d_ff=0, vocab=64,
                       head_dim=d // heads, dtype=jnp.float32,
                       param_dtype=jnp.float32,
                       ssm=SSMConfig(state_dim=N, conv_width=4, expand=2,
                                     chunk=chunk))


def _xl_cfg(chunk=8, heads=4, d=64):
    return ModelConfig(name="t", family="xlstm", num_layers=1, d_model=d,
                       num_heads=heads, kv_heads=heads, d_ff=0, vocab=64,
                       head_dim=d // heads, dtype=jnp.float32,
                       param_dtype=jnp.float32,
                       xlstm=XLSTMConfig(slstm_every=2, expand=2,
                                         conv_width=4, chunk=chunk))


class TestMamba2:
    @pytest.mark.parametrize("S,chunk", [(32, 8), (40, 8), (16, 16),
                                         (17, 8)])
    def test_chunked_vs_sequential(self, S, chunk):
        cfg = _ssm_cfg(chunk=chunk)
        params = init_params(ssm.mamba2_schema(cfg, 1), jax.random.key(0),
                             jnp.float32)
        lp = jax.tree.map(lambda a: a[0], params)
        rng = np.random.default_rng(S)
        h = jnp.asarray(rng.standard_normal((2, S, cfg.d_model)),
                        jnp.float32)
        np.testing.assert_allclose(
            np.asarray(ssm.mamba2_forward_layer(h, lp, cfg)),
            np.asarray(ssm.mamba2_forward_layer_ref(h, lp, cfg)),
            atol=1e-4)

    def test_state_handoff(self):
        """forward(return_state) -> decode continues exactly."""
        cfg = _ssm_cfg()
        params = init_params(ssm.mamba2_schema(cfg, 1), jax.random.key(1),
                             jnp.float32)
        lp = jax.tree.map(lambda a: a[0], params)
        rng = np.random.default_rng(0)
        h = jnp.asarray(rng.standard_normal((1, 24, cfg.d_model)),
                        jnp.float32)
        full = ssm.mamba2_forward_layer_ref(h, lp, cfg)
        out16, (s, conv) = ssm.mamba2_forward_layer(h[:, :16], lp, cfg,
                                                    return_state=True)
        for t in range(16, 24):
            y, s, conv = ssm.mamba2_decode_layer(h[:, t], lp, cfg, s, conv)
            np.testing.assert_allclose(np.asarray(y),
                                       np.asarray(full[:, t]), atol=1e-4)

    @given(st.integers(0, 1000))
    @settings(max_examples=10, deadline=None)
    def test_decay_bounded(self, seed):
        """State never blows up: decay factors are in (0, 1]."""
        cfg = _ssm_cfg()
        params = init_params(ssm.mamba2_schema(cfg, 1),
                             jax.random.key(seed), jnp.float32)
        lp = jax.tree.map(lambda a: a[0], params)
        rng = np.random.default_rng(seed)
        h = jnp.asarray(rng.standard_normal((1, 16, cfg.d_model)) * 3,
                        jnp.float32)
        y = ssm.mamba2_forward_layer(h, lp, cfg)
        assert np.isfinite(np.asarray(y)).all()


class TestMLSTM:
    @pytest.mark.parametrize("S,chunk", [(32, 8), (24, 8), (16, 16)])
    def test_chunked_vs_sequential(self, S, chunk):
        cfg = _xl_cfg(chunk=chunk)
        params = init_params(xlstm.mlstm_schema(cfg, 1), jax.random.key(0),
                             jnp.float32)
        lp = jax.tree.map(lambda a: a[0], params)
        rng = np.random.default_rng(S)
        h = jnp.asarray(rng.standard_normal((2, S, cfg.d_model)),
                        jnp.float32)
        np.testing.assert_allclose(
            np.asarray(xlstm.mlstm_forward_layer(h, lp, cfg)),
            np.asarray(xlstm.mlstm_forward_layer_ref(h, lp, cfg)),
            atol=1e-4)

    def test_decode_matches_forward(self):
        cfg = _xl_cfg()
        params = init_params(xlstm.mlstm_schema(cfg, 1), jax.random.key(2),
                             jnp.float32)
        lp = jax.tree.map(lambda a: a[0], params)
        rng = np.random.default_rng(1)
        S = 16
        h = jnp.asarray(rng.standard_normal((2, S, cfg.d_model)),
                        jnp.float32)
        full = xlstm.mlstm_forward_layer_ref(h, lp, cfg)
        inner = cfg.xlstm.expand * cfg.d_model
        H, P = cfg.num_heads, inner // cfg.num_heads
        state = (jnp.zeros((2, H, P, P)), jnp.zeros((2, H, P)),
                 jnp.full((2, H), -1e30),
                 jnp.zeros((2, cfg.xlstm.conv_width - 1, inner)))
        for t in range(S):
            y, state = xlstm.mlstm_decode_layer(h[:, t], lp, cfg, state)
            np.testing.assert_allclose(np.asarray(y),
                                       np.asarray(full[:, t]), atol=1e-4)

    def test_large_gates_stable(self):
        """Exponential input gates with extreme pre-activations must not
        overflow (the stabilizer m_t recurrence)."""
        cfg = _xl_cfg()
        params = init_params(xlstm.mlstm_schema(cfg, 1), jax.random.key(3),
                             jnp.float32)
        lp = jax.tree.map(lambda a: a[0], params)
        lp = dict(lp)
        lp["bi"] = lp["bi"] + 60.0    # huge input gate bias
        rng = np.random.default_rng(2)
        h = jnp.asarray(rng.standard_normal((1, 16, cfg.d_model)),
                        jnp.float32)
        y = xlstm.mlstm_forward_layer(h, lp, cfg)
        assert np.isfinite(np.asarray(y)).all()
        y_ref = xlstm.mlstm_forward_layer_ref(h, lp, cfg)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-3)


class TestSLSTM:
    def test_decode_matches_forward(self):
        cfg = _xl_cfg()
        params = init_params(xlstm.slstm_schema(cfg, 1), jax.random.key(4),
                             jnp.float32)
        lp = jax.tree.map(lambda a: a[0], params)
        rng = np.random.default_rng(3)
        S = 12
        h = jnp.asarray(rng.standard_normal((2, S, cfg.d_model)),
                        jnp.float32)
        full = xlstm.slstm_forward_layer(h, lp, cfg)
        H, P = cfg.num_heads, cfg.d_model // cfg.num_heads
        z = jnp.zeros((2, H, P))
        state = (z, z, jnp.full((2, H, P), -1e30), z)
        for t in range(S):
            y, state = xlstm.slstm_decode_layer(h[:, t], lp, cfg, state)
            np.testing.assert_allclose(np.asarray(y),
                                       np.asarray(full[:, t]), atol=1e-4)
