"""Workload-plane property suite (benchmarks/workloads.py).

The determinism contract under test: a `WorkloadSpec` (seed included)
IS the stream — two `generate()` calls produce bitwise-identical
arrival times, lengths, tiers, prompt tokens, and sampling keys. Plus
the distributional invariants: arrivals sorted and strictly positive,
lengths >= 1 and page-snapped when asked, tier names from the spec's
mix, and the truncated-Zipf tail sampler within KS tolerance of the
exact law it inverts (the CDF is exposed for exactly this test).

Property tests are hypothesis-optional (tests/_hypothesis_compat);
deterministic smoke companions keep the coverage alive without it.
"""

import os
import sys

import numpy as np

from _hypothesis_compat import given, settings, st

sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                os.pardir, "benchmarks"))
import workloads as wl                                   # noqa: E402


def _spec(**kw):
    base = dict(seed=5, n_requests=48, rate_rps=40.0, max_prompt=64,
                max_new=12, vocab=128)
    base.update(kw)
    return wl.WorkloadSpec(**base)


def _assert_bitwise_equal(a: wl.Workload, b: wl.Workload) -> None:
    assert a.arrival_s.tobytes() == b.arrival_s.tobytes()
    assert a.prompt_len.tobytes() == b.prompt_len.tobytes()
    assert a.max_new.tobytes() == b.max_new.tobytes()
    assert a.tier == b.tier
    assert len(a.prompts) == len(b.prompts)
    assert all(x.tobytes() == y.tobytes()
               for x, y in zip(a.prompts, b.prompts))
    assert a.stream_seed == b.stream_seed
    assert a.sampling == b.sampling


# --------------------------------------------------------------------------- #
# determinism: the seed IS the stream
# --------------------------------------------------------------------------- #

class TestDeterminism:
    def test_same_seed_bitwise_identical_every_arrival(self):
        for arrival in wl.ARRIVALS:
            spec = _spec(arrival=arrival, temperature=0.8)
            _assert_bitwise_equal(wl.generate(spec), wl.generate(spec))

    def test_different_seed_different_stream(self):
        a = wl.generate(_spec(seed=1))
        b = wl.generate(_spec(seed=2))
        assert a.arrival_s.tobytes() != b.arrival_s.tobytes()
        assert a.stream_seed != b.stream_seed

    def test_mixed_stream_deterministic(self):
        a = wl.mixed_stream(7, 24, vocab=64)
        b = wl.mixed_stream(7, 24, vocab=64)
        _assert_bitwise_equal(a, b)

    def test_requests_fresh_objects_with_stamps(self):
        """requests() materialises fresh Request objects each call (the
        engine mutates them) carrying the stream's arrival offsets and
        tiers; time_scale stretches the clock, open_loop=False drops
        it."""
        w = wl.generate(_spec(seed=9))
        r1, r2 = w.requests(), w.requests()
        assert [r.rid for r in r1] == [r.rid for r in r2]
        r1[0].output.append(1)
        assert not r2[0].output
        for i, r in enumerate(r1):
            assert r.arrival_s == float(w.arrival_s[i])
            assert r.tier == w.tier[i]
            assert r.prompt_len == int(w.prompt_len[i])
        half = w.requests(time_scale=0.5)
        assert all(abs(h.arrival_s - r.arrival_s * 0.5) < 1e-12
                   for h, r in zip(half, r1))
        closed = w.requests(open_loop=False)
        assert all(r.arrival_s == 0.0 for r in closed)

    def test_sampled_stream_contract(self):
        w = wl.generate(_spec(temperature=0.7, top_k=20))
        kw = w.serve_kwargs()
        assert kw["seed"] == w.stream_seed
        assert kw["sampling"].temperature == 0.7
        assert kw["sampling"].top_k == 20


# --------------------------------------------------------------------------- #
# structural invariants
# --------------------------------------------------------------------------- #

class TestInvariants:
    def test_arrivals_sorted_and_positive(self):
        for arrival in wl.ARRIVALS:
            w = wl.generate(_spec(arrival=arrival))
            assert (np.diff(w.arrival_s) >= 0).all(), arrival
            assert (w.arrival_s > 0).all(), arrival

    def test_lengths_bounded(self):
        w = wl.generate(_spec(seed=13))
        assert (w.prompt_len >= 1).all()
        assert (w.prompt_len <= w.spec.max_prompt).all()
        assert (w.max_new >= 1).all()
        assert (w.max_new <= w.spec.max_new).all()
        assert all(len(p) == n
                   for p, n in zip(w.prompts, w.prompt_len))
        assert all((p >= 0).all() and (p < w.spec.vocab).all()
                   for p in w.prompts)

    def test_snap_frac_one_page_aligns_everything(self):
        w = wl.generate(_spec(seed=3, snap_frac=1.0, page_tokens=16))
        aligned = (w.prompt_len % 16 == 0) | \
            (w.prompt_len == w.spec.max_prompt)
        assert aligned.all(), w.prompt_len

    def test_tiers_from_mix(self):
        w = wl.generate(_spec(seed=21, n_requests=400))
        names = [t for t, _ in w.spec.tiers]
        assert set(w.tier) <= set(names)
        # the dominant tier dominates (loose: no exact-frequency pin)
        counts = {t: w.tier.count(t) for t in names}
        assert counts["interactive"] > counts["batch"]

    def test_merge_sorts_and_preserves_rows(self):
        a = wl.generate(_spec(seed=1, n_requests=10))
        b = wl.generate(_spec(seed=2, n_requests=6, arrival="bursty"))
        m = wl.merge([a, b])
        assert m.n == 16
        assert (np.diff(m.arrival_s) >= 0).all()
        # every (length, prompt) row survives the shuffle
        assert sorted(m.prompt_len) == sorted(
            list(a.prompt_len) + list(b.prompt_len))
        assert all(len(p) == n
                   for p, n in zip(m.prompts, m.prompt_len))

    def test_bursty_is_burstier_than_poisson(self):
        """On-off modulation shows up as higher gap dispersion than the
        exponential stream's at the same mean rate."""
        po = wl.generate(_spec(seed=17, n_requests=600))
        bu = wl.generate(_spec(seed=17, n_requests=600,
                               arrival="bursty"))
        cv = lambda g: np.std(g) / np.mean(g)          # noqa: E731
        assert cv(np.diff(bu.arrival_s)) > cv(np.diff(po.arrival_s))


# --------------------------------------------------------------------------- #
# the Zipf tail sampler vs the exact law it inverts
# --------------------------------------------------------------------------- #

class TestZipf:
    def test_cdf_is_a_cdf(self):
        cdf = wl.zipf_cdf(1.3, 512)
        assert cdf.shape == (512,)
        assert (np.diff(cdf) > 0).all()
        assert abs(cdf[-1] - 1.0) < 1e-12

    def test_ks_within_tolerance(self):
        """Large-n empirical CDF of `sample_zipf` vs the exact
        truncated-Zipf CDF: the KS statistic stays under the 1%
        critical value (the sampler is exact inverse-CDF, so the only
        deviation is sampling noise)."""
        n, support, alpha = 20_000, 256, 1.3
        rng = np.random.default_rng(0)
        draws = wl.sample_zipf(rng, alpha, support, n)
        assert draws.min() >= 1 and draws.max() <= support
        cdf = wl.zipf_cdf(alpha, support)
        emp = np.searchsorted(np.sort(draws),
                              np.arange(1, support + 1),
                              side="right") / n
        ks = np.abs(emp - cdf).max()
        assert ks < 1.63 / np.sqrt(n), ks          # KS alpha=0.01

    def test_heavier_alpha_shortens_tail(self):
        rng = np.random.default_rng(1)
        light = wl.sample_zipf(rng, 1.1, 256, 4000)
        rng = np.random.default_rng(1)
        heavy = wl.sample_zipf(rng, 2.5, 256, 4000)
        assert heavy.mean() < light.mean()


# --------------------------------------------------------------------------- #
# hypothesis-driven generalisations of the above
# --------------------------------------------------------------------------- #

@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from(wl.ARRIVALS))
def test_property_seed_is_the_stream(seed, arrival):
    """Any (seed, arrival process): generation is bitwise reproducible
    and the structural invariants hold."""
    spec = _spec(seed=seed, n_requests=24, arrival=arrival)
    a, b = wl.generate(spec), wl.generate(spec)
    _assert_bitwise_equal(a, b)
    assert (np.diff(a.arrival_s) >= 0).all()
    assert (a.arrival_s > 0).all()
    assert (a.prompt_len >= 1).all()
    assert (a.prompt_len <= spec.max_prompt).all()
    assert all(len(p) == n for p, n in zip(a.prompts, a.prompt_len))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.floats(1.05, 3.0, allow_nan=False))
def test_property_zipf_support(seed, alpha):
    rng = np.random.default_rng(seed)
    draws = wl.sample_zipf(rng, alpha, 128, 500)
    assert draws.min() >= 1 and draws.max() <= 128
