"""Async migration (PR 8): the double-buffered plan/commit split with
one-step-ahead KV prefetch (EXPERIMENTS.md §Async-migration).

Pins:

  * the two-phase commit API (`stage_plan` + `commit_staged`) is
    bitwise identical to `apply_migrations` AND to an independent
    numpy reference executor, over random caches and random plans —
    the split is invisible to every inline call site;
  * the overlap serve pipeline changes WHEN pages move, not what
    attention computes: on an HBM-resident stream (where inline and
    overlap placements coincide) every registered policy emits
    BITWISE the same tokens and terminal statuses as the inline
    engine, on ONE executable per mode; and under real HBM pressure
    the staged pipeline still commits migrations;
  * `revalidate_plan` masks exactly the rows whose sources or
    destinations the interim step invalidated, and keeps index-paired
    swap rows paired;
  * `mask_plan_lanes` drops every row of a stale (rebound) lane;
  * `throttle_plan` over the staged buffer never commits more rows
    than the fault cap — including cap 0, the fallback-to-static
    mode, where plans keep staging and nothing lands.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro import configs
from repro.core.tiers import GH200
from repro.kvcache.migrate import (
    MigrationPlan, apply_migrations, commit_staged, stage_plan,
)
from repro.kvcache.paged import CacheGeometry, init_cache
from repro.models.model import Model
from repro.serving import control
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.faults import FaultPlane, MigrationFault, throttle_plan
from repro.serving.policies import policy_names
from repro.serving.scheduler import Request


@pytest.fixture(scope="module")
def dense_model():
    cfg = configs.get_smoke("internlm2-1.8b")
    m = Model(cfg)
    return m, m.init(jax.random.key(0))


def _geo():
    return CacheGeometry(num_layers=2, batch=2, page_tokens=4,
                         hbm_pages=4, host_pages=6, kv_heads=2,
                         head_dim=8, dtype=jnp.float32)


def _rand_cache(geo, seed):
    """A cache with every pool/map filled with recognizable noise."""
    rng = np.random.default_rng(seed)
    cache = init_cache(geo)

    def noise(x):
        return jnp.asarray(
            rng.standard_normal(x.shape).astype(np.float32)).astype(x.dtype)

    def owners(x, pages):
        del pages
        return jnp.asarray(
            rng.integers(-1, geo.max_pages, x.shape).astype(np.int32))

    return dataclasses.replace(
        cache,
        k_hbm=noise(cache.k_hbm), v_hbm=noise(cache.v_hbm),
        k_host=noise(cache.k_host), v_host=noise(cache.v_host),
        hbm_owner=owners(cache.hbm_owner, geo.hbm_pages),
        host_owner=owners(cache.host_owner, geo.host_pages),
        page_table=jnp.asarray(rng.integers(
            -1, geo.max_pages, cache.page_table.shape).astype(np.int32)))


def _rand_plan(geo, cap, seed):
    """A random plan with collision-free scatters: at most one row per
    (layer, batch) coordinate, sentinel rows interleaved, ~70% of live
    rows full swaps (demote paired at the same index)."""
    rng = np.random.default_rng(seed)
    arrs = np.full((10, cap), -1, np.int32)
    coords = [(l, b) for l in range(geo.num_layers)
              for b in range(geo.batch)]
    rng.shuffle(coords)
    rows = rng.permutation(cap)[:min(len(coords), cap)]
    for i, (l, b) in zip(rows, coords):
        pro_log = int(rng.integers(0, geo.max_pages))
        arrs[0:5, i] = (l, b, int(rng.integers(0, geo.host_pages)),
                        int(rng.integers(0, geo.hbm_pages)), pro_log)
        if rng.random() < 0.7:
            dem_log = (pro_log + 1) % geo.max_pages
            arrs[5:10, i] = (l, b, arrs[3, i], arrs[2, i], dem_log)
    return MigrationPlan(*[jnp.asarray(a) for a in arrs])


def _ref_apply(cache, plan):
    """Independent numpy executor: gather-everything-first, then
    scatter; owner clears before sets; -1 rows are no-ops."""
    c = jax.tree.map(np.array, cache)
    p = jax.tree.map(np.array, plan)
    hbm_pages = c.k_hbm.shape[2]
    M = p.pro_layer.shape[0]
    staged = []
    for i in range(M):
        dem = pro = None
        if p.dem_layer[i] >= 0:
            l, b, s = p.dem_layer[i], p.dem_batch[i], p.dem_src[i]
            dem = (c.k_hbm[l, b, s].copy(), c.v_hbm[l, b, s].copy())
        if p.pro_layer[i] >= 0:
            l, b, s = p.pro_layer[i], p.pro_batch[i], p.pro_src[i]
            pro = (c.k_host[l, b, s].copy(), c.v_host[l, b, s].copy())
        staged.append((dem, pro))
    for i, (dem, pro) in enumerate(staged):
        if dem is not None:
            l, b = p.dem_layer[i], p.dem_batch[i]
            c.k_host[l, b, p.dem_dst[i]] = dem[0]
            c.v_host[l, b, p.dem_dst[i]] = dem[1]
        if pro is not None:
            l, b = p.pro_layer[i], p.pro_batch[i]
            c.k_hbm[l, b, p.pro_dst[i]] = pro[0]
            c.v_hbm[l, b, p.pro_dst[i]] = pro[1]
    for i in range(M):                      # clears land FIRST
        if p.dem_layer[i] >= 0:
            c.hbm_owner[p.dem_layer[i], p.dem_batch[i], p.dem_src[i]] = -1
    for i in range(M):
        if p.pro_layer[i] >= 0:
            c.hbm_owner[p.pro_layer[i], p.pro_batch[i],
                        p.pro_dst[i]] = p.pro_logical[i]
    for i in range(M):
        if p.pro_layer[i] >= 0:
            c.host_owner[p.pro_layer[i], p.pro_batch[i],
                         p.pro_src[i]] = -1
    for i in range(M):
        if p.dem_layer[i] >= 0:
            c.host_owner[p.dem_layer[i], p.dem_batch[i],
                         p.dem_dst[i]] = p.dem_logical[i]
    for i in range(M):
        if p.dem_layer[i] >= 0:
            c.page_table[p.dem_layer[i], p.dem_batch[i],
                         p.dem_logical[i]] = p.dem_dst[i] + hbm_pages
    for i in range(M):
        if p.pro_layer[i] >= 0:
            c.page_table[p.pro_layer[i], p.pro_batch[i],
                         p.pro_logical[i]] = p.pro_dst[i]
    return c


def _assert_caches_equal(a, b):
    for name in ("k_hbm", "v_hbm", "k_host", "v_host", "page_table",
                 "hbm_owner", "host_owner", "length", "importance"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
            err_msg=name)


# --------------------------------------------------------------------------- #
# the two-phase commit API (unit level)
# --------------------------------------------------------------------------- #

class TestTwoPhaseCommit:
    def _check(self, seed):
        geo = _geo()
        cache = _rand_cache(geo, seed)
        plan = _rand_plan(geo, cap=6, seed=seed + 1)
        out = apply_migrations(cache, plan)
        # split API == fused API, bitwise
        split = commit_staged(cache, plan, stage_plan(cache, plan))
        _assert_caches_equal(out, split)
        # both == the independent numpy reference
        _assert_caches_equal(out, _ref_apply(cache, plan))

    def test_matches_reference_over_seeds(self):
        """Deterministic seed sweep (keeps coverage alive without
        hypothesis): two-phase == apply_migrations == numpy reference
        over random caches and random (sentinel-interleaved) plans."""
        for seed in range(8):
            self._check(seed)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_matches_reference_property(self, seed):
        """Hypothesis-optional widening of the same property."""
        self._check(seed)

    def test_empty_plan_is_identity_with_distinct_buffers(self):
        geo = _geo()
        cache = _rand_cache(geo, 0)
        empty = MigrationPlan.empty(6)
        _assert_caches_equal(cache, apply_migrations(cache, empty))
        # the overlap serve loop DONATES the empty plan as the initial
        # scan carry: ten aliases of one buffer would be rejected by
        # XLA ("attempt to donate the same buffer twice")
        leaves = jax.tree.leaves(empty)
        assert len(leaves) == 10
        ptrs = {x.unsafe_buffer_pointer() for x in leaves}
        assert len(ptrs) == 10

    def test_swap_reads_prepromotion_page(self):
        """The hazard staging exists for: dem_dst == pro_src. The
        demoted page must land in the host slot the promotion vacated
        WITHOUT clobbering the promoted page's trip to HBM."""
        geo = _geo()
        cache = _rand_cache(geo, 3)
        plan = MigrationPlan.build(4, [(0, 0, 2, 1, 5)],
                                   [(0, 0, 1, 2, 6)])
        before_host = np.asarray(cache.k_host[0, 0, 2]).copy()
        before_hbm = np.asarray(cache.k_hbm[0, 0, 1]).copy()
        out = apply_migrations(cache, plan)
        np.testing.assert_array_equal(
            np.asarray(out.k_hbm[0, 0, 1]), before_host)
        np.testing.assert_array_equal(
            np.asarray(out.k_host[0, 0, 2]), before_hbm)
        assert int(out.hbm_owner[0, 0, 1]) == 5
        assert int(out.host_owner[0, 0, 2]) == 6


# --------------------------------------------------------------------------- #
# hazard masking (revalidate_plan / mask_plan_lanes / throttle)
# --------------------------------------------------------------------------- #

class TestHazardMasking:
    def _cache_with_owners(self, geo, ho, eo):
        cache = init_cache(geo)
        return dataclasses.replace(cache, hbm_owner=jnp.asarray(ho),
                                   host_owner=jnp.asarray(eo))

    def test_revalidate_masks_exactly_the_hazards(self):
        geo = _geo()
        L, B = geo.num_layers, geo.batch
        ho = np.full((L, B, geo.hbm_pages), -1, np.int32)
        eo = np.full((L, B, geo.host_pages), -1, np.int32)
        # row 0: valid swap — source still owns logical 5, victim still
        # owns logical 7
        eo[0, 0, 2] = 5
        ho[0, 0, 1] = 7
        # row 1: valid promote-only — source owns 4, dst slot free
        eo[1, 1, 3] = 4
        # row 2: STALE SOURCE — the interim step moved logical 8 away
        eo[0, 1, 1] = 9
        # row 3: promote-only whose dst the interim step OCCUPIED
        eo[1, 0, 0] = 2
        ho[1, 0, 2] = 6
        cache = self._cache_with_owners(geo, ho, eo)
        plan = MigrationPlan(
            pro_layer=jnp.asarray([0, 1, 0, 1, -1], jnp.int32),
            pro_batch=jnp.asarray([0, 1, 1, 0, -1], jnp.int32),
            pro_src=jnp.asarray([2, 3, 1, 0, -1], jnp.int32),
            pro_dst=jnp.asarray([1, 0, 3, 2, -1], jnp.int32),
            pro_logical=jnp.asarray([5, 4, 8, 2, -1], jnp.int32),
            dem_layer=jnp.asarray([0, -1, 0, -1, -1], jnp.int32),
            dem_batch=jnp.asarray([0, -1, 1, -1, -1], jnp.int32),
            dem_src=jnp.asarray([1, -1, 3, -1, -1], jnp.int32),
            dem_dst=jnp.asarray([2, -1, 1, -1, -1], jnp.int32),
            dem_logical=jnp.asarray([7, -1, 3, -1, -1], jnp.int32))
        rv = control.revalidate_plan(plan, cache)
        np.testing.assert_array_equal(
            np.asarray(rv.pro_layer >= 0), [True, True, False, False,
                                            False])
        # demote rows masked with the SAME keep mask (paired swaps)
        np.testing.assert_array_equal(
            np.asarray(rv.dem_layer >= 0), [True, False, False, False,
                                            False])
        # surviving rows are untouched
        assert int(rv.pro_src[0]) == 2 and int(rv.dem_dst[0]) == 2
        assert int(rv.pro_dst[1]) == 0

    def test_revalidate_masks_swap_whose_victim_moved(self):
        """A swap row whose DEMOTE side went stale (the victim slot no
        longer holds the expected logical) must drop whole — promoting
        onto it would clobber an unknown tenant."""
        geo = _geo()
        ho = np.full((geo.num_layers, geo.batch, geo.hbm_pages), -1,
                     np.int32)
        eo = np.full((geo.num_layers, geo.batch, geo.host_pages), -1,
                     np.int32)
        eo[0, 0, 2] = 5          # source fine
        ho[0, 0, 1] = 3          # victim changed: plan expects 7
        cache = self._cache_with_owners(geo, ho, eo)
        plan = MigrationPlan.build(4, [(0, 0, 2, 1, 5)],
                                   [(0, 0, 1, 2, 7)])
        rv = control.revalidate_plan(plan, cache)
        assert not (np.asarray(rv.pro_layer) >= 0).any()
        assert not (np.asarray(rv.dem_layer) >= 0).any()

    def test_mask_plan_lanes_drops_stale_lane_rows(self):
        geo = _geo()
        plan = MigrationPlan(
            pro_layer=jnp.asarray([0, 0, 1, -1], jnp.int32),
            pro_batch=jnp.asarray([0, 1, 1, -1], jnp.int32),
            pro_src=jnp.asarray([1, 2, 3, -1], jnp.int32),
            pro_dst=jnp.asarray([0, 1, 2, -1], jnp.int32),
            pro_logical=jnp.asarray([4, 5, 6, -1], jnp.int32),
            dem_layer=jnp.asarray([0, 0, -1, -1], jnp.int32),
            dem_batch=jnp.asarray([0, 1, -1, -1], jnp.int32),
            dem_src=jnp.asarray([0, 1, -1, -1], jnp.int32),
            dem_dst=jnp.asarray([1, 2, -1, -1], jnp.int32),
            dem_logical=jnp.asarray([7, 8, -1, -1], jnp.int32))
        stale = jnp.asarray([False, True], bool)
        masked = control.mask_plan_lanes(plan, stale)
        np.testing.assert_array_equal(
            np.asarray(masked.pro_layer >= 0),
            [True, False, False, False])
        np.testing.assert_array_equal(
            np.asarray(masked.dem_layer >= 0),
            [True, False, False, False])
        del geo

    def test_throttle_after_revalidate_respects_cap(self):
        """The overlap commit order is revalidate -> throttle: for any
        cap the committed row count never exceeds it, and cap 0 (the
        static-fallback data value) commits nothing."""
        geo = _geo()
        cache = _rand_cache(geo, 11)
        staged = _rand_plan(geo, cap=6, seed=12)
        rv = control.revalidate_plan(staged, cache)
        live = int(np.asarray(rv.pro_layer >= 0).sum())
        for cap in (0, 1, 2, 100):
            t = throttle_plan(rv, jnp.int32(cap))
            n_pro, n_dem = t.row_counts()
            assert int(n_pro) <= cap
            assert int(n_pro) == min(cap, live)
            assert int(n_dem) <= int(n_pro)


# --------------------------------------------------------------------------- #
# the overlap serve pipeline (stream level)
# --------------------------------------------------------------------------- #

def _serve_cfg(policy, **kw):
    sparsity = 0.5 if policy == "quest" else 0.0
    return EngineConfig(max_context=128, hbm_fraction=0.25,
                        policy=policy, attention_sparsity=sparsity,
                        spec=GH200, promote_thresh=0.005,
                        telemetry_stride=4, prefill_chunk=16, **kw)


def _stream(model, n=4, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, model.cfg.vocab,
                                        (16 + 8 * (i % 3),)),
                    max_new_tokens=5) for i in range(n)]


class TestOverlapServe:
    @pytest.mark.parametrize("policy", sorted(policy_names()))
    def test_tokens_and_statuses_match_inline(self, dense_model, policy):
        """The staged pipeline shifts WHEN pages move, never what the
        model computes. On this HBM-resident stream (no spill, so both
        modes hold identical placements throughout) that makes tokens
        and terminal statuses bitwise mode-invariant for every
        registered policy, on one executable per mode — the machinery
        pin: carry threading, lane masking, revalidation, and the
        commit itself perturb nothing. (Under real HBM pressure the
        modes' interim placements differ and the per-tier LSE merge
        may associate floating point differently — semantics, pools
        read, and statuses stay equivalent; bitwise equality is pinned
        where placements coincide.)"""
        model, params = dense_model

        def run(overlap):
            eng = ServingEngine(
                model, params,
                _serve_cfg(policy, overlap_migrations=overlap))
            rep = eng.serve(_stream(model), num_slots=2, seed=0)
            assert eng._serve_jit._cache_size() == 1
            return ({r.rid: list(r.output) for r in rep.completed},
                    rep.statuses)

        toks_i, stat_i = run(False)
        toks_o, stat_o = run(True)
        assert toks_o == toks_i
        assert stat_o == stat_i

    def test_pipeline_commits_under_pressure(self, dense_model):
        """Under real HBM pressure the lagged pipeline must actually
        MOVE pages (a pipeline that stages forever and commits nothing
        would pass every bitwise test), stay within one executable,
        and complete every request ok. The sparse read mask keeps the
        plan-ahead oracle active."""
        model, params = dense_model
        rng = np.random.default_rng(5)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, model.cfg.vocab,
                                            (272 + 16 * (i % 2),)),
                        max_new_tokens=8) for i in range(3)]
        cfg = EngineConfig(max_context=512, hbm_fraction=0.25,
                           policy="importance", attention_sparsity=0.5,
                           spec=GH200, promote_thresh=1e-4,
                           telemetry_stride=8, prefill_chunk=16,
                           overlap_migrations=True)
        eng = ServingEngine(model, params, cfg)
        rep = eng.serve(reqs, num_slots=2, seed=0)
        assert all(s == "ok" for s in rep.statuses.values())
        assert sum(s.m_in + s.m_out for s in eng.stats) > 0
        assert eng._serve_jit._cache_size() == 1

    def test_staged_commits_never_exceed_fault_cap(self, dense_model):
        """Chaos contract, overlap half: a partial-commit window caps
        the STAGED buffer's landing rows per step (visible as migrated
        bytes <= cap * page_bytes), and a full-drop window is
        fallback-to-static — plans stage, nothing commits."""
        model, params = dense_model
        cfg = EngineConfig(max_context=512, hbm_fraction=0.25,
                           policy="importance", attention_sparsity=0.5,
                           spec=GH200, promote_thresh=1e-4,
                           telemetry_stride=8, prefill_chunk=16,
                           overlap_migrations=True)
        eng = ServingEngine(model, params, cfg)
        rng = np.random.default_rng(5)

        def reqs():
            return [Request(rid=i,
                            prompt=rng.integers(0, model.cfg.vocab,
                                                (272 + 16 * (i % 2),)),
                            max_new_tokens=8) for i in range(3)]

        plane = FaultPlane(migration=(
            MigrationFault(start=0, stop=10_000, commit_frac=0.1),))
        eng.serve(reqs(), num_slots=2, seed=0, faults=plane)
        cap_rows = control.plan_capacity(eng.geo,
                                         cfg.migration_budget_frac)
        cap = int(np.ceil(0.1 * cap_rows))
        pb = eng.geo.page_bytes()
        assert any(s.m_in + s.m_out > 0 for s in eng.stats)
        for s in eng.stats:
            assert s.m_in <= cap * pb
            assert s.m_out <= cap * pb
        # full drop == static fallback on the staged buffer
        plane0 = FaultPlane(migration=(
            MigrationFault(start=0, stop=10_000, commit_frac=0.0),))
        eng.serve(reqs(), num_slots=2, seed=0, faults=plane0)
        assert sum(s.m_in + s.m_out for s in eng.stats) == 0
        assert eng._serve_jit._cache_size() == 1

    def test_measured_payback_emits_event_and_serves(self, dense_model):
        """measured_payback recalibrates cost_aware from a measured
        migration microbenchmark; the event carries the measurement and
        the stream still completes identically (thresholds shift
        placement economics, not logits)."""
        model, params = dense_model
        cfg = _serve_cfg("cost_aware", overlap_migrations=True,
                         measured_payback=True)
        eng = ServingEngine(model, params, cfg)
        rep = eng.serve(_stream(model), num_slots=2, seed=0)
        ev = [e for e in rep.events if e["kind"] == "payback_measured"]
        assert len(ev) == 1
        assert ev[0]["bytes"] > 0 and ev[0]["rows"] > 0
        assert all(s == "ok" for s in rep.statuses.values())

        ref = ServingEngine(model, params, _serve_cfg("cost_aware"))
        ref_rep = ref.serve(_stream(model), num_slots=2, seed=0)
        assert {r.rid: list(r.output) for r in rep.completed} == \
            {r.rid: list(r.output) for r in ref_rep.completed}
