"""End-to-end behaviour tests: the paper's experiment pipeline and the
full train->checkpoint->restore->serve loop on one host."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.experiment import Workload, run_all
from repro.core.sa import SAConfig
from repro.core.tiers import GH200, TPU_V5E
from repro.core.traces import synthetic_trace
from repro.models.model import Model


class TestPaperPipeline:
    """Miniature of the paper's evaluation: five strategies, one trace,
    the ordering and magnitude claims hold."""

    @pytest.fixture(scope="class")
    def results(self):
        tr = synthetic_trace(prompt_len=4096, decode_len=300,
                             sparsity=0.75, variation=0.3, seed=0)
        wl = Workload.llama31_8b()
        total = (tr.prompt_len + tr.decode_len) \
            * wl.bytes_per_token_layer * wl.num_layers
        return run_all(tr, GH200, wl, 0.25 * total,
                       sa_cfg=SAConfig(max_evaluations=60, seed=0))

    def test_strategy_ordering(self, results):
        # static is the slowest of the placement strategies
        assert results["static"].total_latency_s >= \
            results["reactive"].total_latency_s * 0.99
        assert results["static"].total_latency_s >= \
            results["quest"].total_latency_s * 0.99
        assert results["static"].total_latency_s >= \
            results["sa"].total_latency_s

    def test_sa_speedup_in_paper_band(self, results):
        """SA-guided consistently 2-8x static on clustered traces
        (paper: 4-5x typical, 5.87x max; exact value depends on trace)."""
        speedup = results["sa"].speedup_over(results["static"])
        assert 2.0 < speedup < 10.0

    def test_hit_rates_ordered(self, results):
        assert results["unlimited"].hbm_hit_rate == 1.0
        assert results["sa"].hbm_hit_rate >= \
            results["static"].hbm_hit_rate

    def test_aggregation_can_beat_hbm_only(self, results):
        """The paper's core premise: aggregated two-tier bandwidth can
        approach (even exceed) the HBM-only ideal when the hot set is
        split well. SA must land within 2x of unlimited."""
        assert results["sa"].total_latency_s <= \
            2.0 * results["unlimited"].total_latency_s


class TestTrainServeLoop:
    def test_full_lifecycle(self, tmp_path):
        from repro.checkpoint.manager import CheckpointManager
        from repro.data.pipeline import DataConfig, SyntheticCorpus
        from repro.serving.engine import EngineConfig, ServingEngine
        from repro.training.train_step import (
            init_train_state, make_train_step)

        cfg = configs.get_smoke("internlm2-1.8b")
        model = Model(cfg)
        state = init_train_state(model, jax.random.key(0))
        step = jax.jit(make_train_step(model, lr=5e-3))
        corpus = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=32,
                                            global_batch=4))
        mgr = CheckpointManager(str(tmp_path / "ck"), keep=2)

        losses = []
        for i in range(6):
            state, m = step(state, {"tokens": jnp.asarray(
                corpus.batch(0, i)["tokens"])})
            losses.append(float(m["loss"]))
        mgr.save(6, state, blocking=True)

        # simulate crash: restore into fresh process state
        restored, start = mgr.restore_or_init(state, lambda: None)
        assert start == 6
        state2, m2 = step(restored, {"tokens": jnp.asarray(
            corpus.batch(0, 6)["tokens"])})
        assert np.isfinite(float(m2["loss"]))

        # serve the trained weights with the placement engine
        eng = ServingEngine(model, restored.params, EngineConfig(
            max_context=96, hbm_fraction=0.3, policy="importance",
            attention_sparsity=0.4, spec=GH200))
        prompts = jnp.asarray(corpus.batch(0, 7)["tokens"][:, :16])
        eng.start(prompts)
        tok = jnp.argmax(eng.step(jnp.array([1, 1, 1, 1])), -1)
        for _ in range(4):
            tok = jnp.argmax(eng.step(tok.astype(jnp.int32)), -1)
        s = eng.summary()
        assert s["steps"] == 5
        assert s["modeled_tokens_per_s"] > 0


class TestTPUSpecScenario:
    def test_placement_matters_more_on_tpu_ratio(self):
        """v5e's HBM:link ratio (~26x) is harsher than GH200 (~10x):
        bad placement hurts MORE, i.e. static/sa gap grows."""
        tr = synthetic_trace(prompt_len=2048, decode_len=150,
                             sparsity=0.75, variation=0.2, seed=1)
        wl = Workload(bytes_per_token_layer=2 * 8 * 128 * 2, num_layers=4)
        total = (tr.prompt_len + tr.decode_len) \
            * wl.bytes_per_token_layer * wl.num_layers
        gaps = {}
        for spec in (GH200, TPU_V5E):
            res = run_all(tr, spec, wl, 0.25 * total,
                          strategies=("static", "sa"),
                          sa_cfg=SAConfig(max_evaluations=40, seed=2))
            gaps[spec.name] = res["sa"].speedup_over(res["static"])
        assert gaps["tpu_v5e"] > gaps["gh200"]
