"""Chaos suite: the fault-injection plane + graceful degradation of the
fused serve loop (repro.serving.faults, EXPERIMENTS.md
§Fault-injection).

The contract under test: with injected tier-degradation,
migration-fault, pool-shrink, and NaN-lane schedules, `serve()`
completes WITHOUT raising, every request ends in exactly one terminal
status, fault-free requests' tokens are bitwise identical to a clean
run, and the zero-retrace / one-serve-executable pins hold with the
fault channel compiled in (fault params are data, not shape).

Plus the scheduler-side robustness satellites: per-request rejection
(duplicate rid, infeasible footprint), deadlines and cancellation, and
a hypothesis-optional property test that the page pool + bindings
ledger stay invariant under random admit/reject/complete/resize
interleavings.
"""

import os
import sys

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                os.pardir, "benchmarks"))
import workloads as wl                                   # noqa: E402
from repro import configs
from repro.core.latency_model import degraded_spec
from repro.core.placement.cost_aware import (
    hysteresis_thresholds, payback_threshold,
)
from repro.core.tiers import GH200, TPU_V5E
from repro.kvcache.migrate import MigrationPlan
from repro.models.model import Model
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.faults import (
    NO_FAULT_CAP, FaultPlane, MigrationFault, PoisonFault, PoolFault,
    TierFault, throttle_plan,
)
from repro.serving.scheduler import (
    TERMINAL_STATUSES, ContinuousBatcher, Request,
)


@pytest.fixture(scope="module")
def dense_model():
    cfg = configs.get_smoke("internlm2-1.8b")
    m = Model(cfg)
    return m, m.init(jax.random.key(0))


def _cfg(policy="importance", **kw):
    return EngineConfig(max_context=128, hbm_fraction=0.25, policy=policy,
                        attention_sparsity=0.0, spec=GH200,
                        promote_thresh=0.005, telemetry_stride=4,
                        prefill_chunk=16, **kw)


def _mk_requests(vocab, n=4, seed=3, budget=6):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, vocab, (16 + 8 * (i % 2),)),
                    max_new_tokens=budget) for i in range(n)]


# --------------------------------------------------------------------------- #
# the fault plane itself (pure data, no model needed)
# --------------------------------------------------------------------------- #

class TestFaultPlane:
    def test_schedule_is_deterministic(self):
        a = FaultPlane.random(7, steps=64, rids=[0, 1, 2])
        b = FaultPlane.random(7, steps=64, rids=[0, 1, 2])
        assert a == b
        c = FaultPlane.random(8, steps=64, rids=[0, 1, 2])
        assert a != c

    def test_spec_at_composes_windows(self):
        plane = FaultPlane(tier=(
            TierFault(start=0, stop=10, link_scale=0.5),
            TierFault(start=5, stop=10, dram_scale=0.5)))
        assert plane.spec_at(20, GH200) == GH200        # outside windows
        s = plane.spec_at(2, GH200)
        assert s.link_bw == GH200.link_bw * 0.5
        assert s.dram_bw == GH200.dram_bw
        s2 = plane.spec_at(7, GH200)                    # overlap composes
        assert s2.link_bw == GH200.link_bw * 0.5
        assert s2.dram_bw == GH200.dram_bw * 0.5
        assert s2.bw_ratio > GH200.bw_ratio             # harsher host tier

    def test_commit_caps_window_and_sentinel(self):
        plane = FaultPlane(migration=(
            MigrationFault(start=6, stop=10, commit_frac=0.5),))
        caps = plane.commit_caps(4, 8, budget_rows=10)  # chunk [4, 12)
        assert caps.shape == (8,)
        assert (caps[:2] == NO_FAULT_CAP).all()         # steps 4-5 clean
        assert (caps[2:6] == 5).all()                   # steps 6-9 capped
        assert (caps[6:] == NO_FAULT_CAP).all()         # steps 10-11 clean

    def test_poison_steps_targets_bound_lane_only(self):
        plane = FaultPlane(poison=(PoisonFault(rid=7, step=5),))
        rids = np.array([3, 7, -1], np.int32)
        mask = plane.poison_steps(4, 4, rids)           # chunk [4, 8)
        assert mask.shape == (4, 3)
        assert not mask[:, 0].any() and not mask[:, 2].any()
        assert not mask[0, 1] and mask[1:, 1].all()     # from step 5 on
        # the rid not bound this chunk -> nothing poisoned
        assert not plane.poison_steps(4, 4,
                                      np.array([3, 4], np.int32)).any()

    def test_throttle_plan_masks_paired_rows(self):
        plan = MigrationPlan.build(4, [(0, 0, 1, 2, 3), (0, 0, 4, 5, 6),
                                       (1, 0, 7, 8, 9)],
                                   [(0, 0, 2, 1, 3), (0, 0, 5, 4, 6)])
        t = throttle_plan(plan, 1)
        assert int((np.asarray(t.pro_layer) >= 0).sum()) == 1
        # demote rows are masked with the SAME row mask (index-paired)
        assert int((np.asarray(t.dem_layer) >= 0).sum()) == 1
        full = throttle_plan(plan, NO_FAULT_CAP)
        assert int((np.asarray(full.pro_layer) >= 0).sum()) == 3
        none = throttle_plan(plan, 0)
        assert not (np.asarray(none.pro_layer) >= 0).any()
        assert not (np.asarray(none.dem_layer) >= 0).any()

    def test_degraded_spec_scales_bandwidths_only(self):
        d = degraded_spec(GH200, link_scale=0.25, dram_scale=0.5)
        assert d.link_bw == GH200.link_bw * 0.25
        assert d.dram_bw == GH200.dram_bw * 0.5
        assert d.hbm_bw == GH200.hbm_bw
        assert d.hbm_capacity == GH200.hbm_capacity
        with pytest.raises(ValueError):
            degraded_spec(GH200, link_scale=0.0)

    def test_hysteresis_thresholds_track_link_degradation(self):
        """Recalibration direction follows the read bottleneck. GH200's
        read path is DRAM-bound (link 900 > dram 500): degrading the
        link inflates the one-time move cost faster than the per-read
        gain, so the payback bar RISES. TPU_V5E is already link-bound
        (32 < 150): the same fault inflates the per-read gain faster —
        host reads become ruinous — so promotion pays back sooner and
        the bar FALLS. Both directions are what the cost_aware policy
        must apply mid-stream."""
        for spec in (GH200, TPU_V5E):
            t_pro, t_dem = hysteresis_thresholds(spec, 10.0)
            assert t_pro == payback_threshold(spec, 10.0)
            assert 0 < t_dem < t_pro
        g_pro, _ = hysteresis_thresholds(GH200, 10.0)
        g_worse, _ = hysteresis_thresholds(
            degraded_spec(GH200, link_scale=0.1), 10.0)
        assert g_worse > g_pro
        v_pro, _ = hysteresis_thresholds(TPU_V5E, 10.0)
        v_worse, _ = hysteresis_thresholds(
            degraded_spec(TPU_V5E, link_scale=0.1), 10.0)
        assert v_worse < v_pro


# --------------------------------------------------------------------------- #
# the serve loop under injected fault schedules (the tentpole contract)
# --------------------------------------------------------------------------- #

class TestChaosServe:
    def _clean(self, dense_model, policy="importance", cfg_kw=None,
               **serve_kw):
        model, params = dense_model
        eng = ServingEngine(model, params, _cfg(policy, **(cfg_kw or {})))
        reqs = _mk_requests(model.cfg.vocab)
        report = eng.serve(reqs, num_slots=2, seed=0, **serve_kw)
        return eng, report

    @pytest.mark.parametrize("overlap", [False, True],
                             ids=["inline", "overlap"])
    def test_full_fault_schedule_degrades_gracefully(self, dense_model,
                                                     overlap):
        """All four fault kinds at once: no raise, statuses exhaustive,
        fault-free lanes bitwise identical, ONE executable.

        overlap=True runs the same schedule through the async-migration
        pipeline: caps throttle the one-step-lagged staged buffer and
        fallback-to-static masks its commits, so the PR 6 graceful-
        degradation contract must hold verbatim in both modes."""
        model, params = dense_model
        eng, clean = self._clean(
            dense_model, cfg_kw={"overlap_migrations": overlap})
        clean_out = {r.rid: list(r.output) for r in clean}
        assert all(r.status == "ok" for r in clean)

        plane = FaultPlane(
            tier=(TierFault(start=2, stop=10, link_scale=0.1,
                            dram_scale=0.5),),
            migration=(MigrationFault(start=0, stop=24,
                                      commit_frac=0.0),),
            pool=(PoolFault(step=4, delta=-2),),
            poison=(PoisonFault(rid=1, step=6),))
        report = eng.serve(_mk_requests(model.cfg.vocab), num_slots=2,
                           seed=0, faults=plane)
        statuses = report.statuses
        assert set(statuses) == {0, 1, 2, 3}
        assert all(s in TERMINAL_STATUSES for s in statuses.values())
        assert statuses[1] == "failed"
        assert report.completed[0] is not None    # stream kept serving
        faulted_out = {r.rid: list(r.output) for r in report.completed}
        for rid, toks in clean_out.items():
            if rid == 1:
                continue
            assert faulted_out[rid] == toks, rid  # bitwise unaffected
        # fault params are data, not shape: clean + faulted runs share
        # ONE serve executable (the zero-retrace pin under injection)
        assert eng._serve_jit._cache_size() == 1
        kinds = {e["kind"] for e in report.events}
        assert {"tier_degradation", "migration_fault", "pool_resize",
                "logit_poison"} <= kinds

    def test_poisoned_lane_quarantined_tokens_truncated(self, dense_model):
        """The poisoned request keeps its pre-poison tokens, ends
        "failed" with a typed error, and its pages are reclaimed (a
        queued successor still gets served)."""
        model, params = dense_model
        eng = ServingEngine(model, params, _cfg())
        reqs = _mk_requests(model.cfg.vocab, n=5, budget=8)
        plane = FaultPlane(poison=(PoisonFault(rid=0, step=2),))
        report = eng.serve(reqs, num_slots=2, seed=0, faults=plane)
        bad = next(r for r in report.completed if r.rid == 0)
        assert bad.status == "failed"
        assert bad.error.code == "poisoned_logits"
        assert len(bad.output) < 8                # truncated, not full
        others = [r for r in report.completed if r.rid != 0]
        assert all(r.status == "ok" and len(r.output) == 8
                   for r in others)               # lane was reclaimed

    def test_migration_fault_drops_commits(self, dense_model):
        """A full-drop window zeroes committed migrations in telemetry
        (the priced placement is the committed one), tokens unchanged."""
        model, params = dense_model
        eng, clean = self._clean(dense_model)
        clean_out = {r.rid: list(r.output) for r in clean}
        clean_moves = sum(s.m_in + s.m_out for s in eng.stats)
        plane = FaultPlane(migration=(
            MigrationFault(start=0, stop=10_000, commit_frac=0.0),))
        report = eng.serve(_mk_requests(model.cfg.vocab), num_slots=2,
                           seed=0, faults=plane)
        assert {r.rid: list(r.output)
                for r in report.completed} == clean_out
        faulted_moves = sum(s.m_in + s.m_out for s in eng.stats)
        assert faulted_moves == 0
        assert faulted_moves <= clean_moves
        assert eng._serve_jit._cache_size() == 1

    def test_tier_fault_reprices_and_recalibrates(self, dense_model):
        """A degraded window makes the SAME traffic cost more, and with
        the cost_aware policy the payback thresholds recalibrate (the
        event log shows it); a harsh enough ratio trips the fallback.
        hbm_scale degrades too: the small stream may be fully
        HBM-resident, and pricing must reflect whichever tier the
        reads actually hit."""
        model, params = dense_model
        eng, clean = self._clean(dense_model, policy="cost_aware")
        clean_total = sum(s.modeled_latency_s for s in eng.stats)
        plane = FaultPlane(tier=(
            TierFault(start=0, stop=10_000, hbm_scale=0.5,
                      link_scale=0.01),))
        report = eng.serve(_mk_requests(model.cfg.vocab), num_slots=2,
                           seed=0, faults=plane)
        degraded_total = sum(s.modeled_latency_s for s in eng.stats)
        assert degraded_total > clean_total
        kinds = [e["kind"] for e in report.events]
        assert "payback_recalibration" in kinds
        # link x0.01 (hbm x0.5) pushes bw_ratio ~28x past base: fallback
        fb = [e for e in report.events
              if e["kind"] == "policy_fallback"]
        assert fb and fb[0]["reason"] == "tier_ratio"
        assert all(s in TERMINAL_STATUSES
                   for s in report.statuses.values())
        assert eng._serve_jit._cache_size() == 1

    def test_commit_fault_streak_falls_back_to_static(self, dense_model):
        """Persistent full-drop windows trip the consecutive-commit
        fallback; the stream still completes every request."""
        model, params = dense_model
        eng = ServingEngine(model, params, _cfg(
            fallback_commit_faults=2))
        plane = FaultPlane(migration=(
            MigrationFault(start=0, stop=10_000, commit_frac=0.0),))
        report = eng.serve(_mk_requests(model.cfg.vocab, budget=10),
                           num_slots=2, seed=0, faults=plane)
        fb = [e for e in report.events if e["kind"] == "policy_fallback"]
        assert fb and fb[0]["reason"] == "commit_faults"
        assert all(s == "ok" for s in report.statuses.values())

    def test_pool_shrink_wave_no_deadlock(self, dense_model):
        """A shrink below a queued request's footprint rejects it
        (typed) instead of deadlocking; the rest complete; a recovery
        delta lets later admissions proceed."""
        model, params = dense_model
        eng = ServingEngine(model, params, _cfg())
        reqs = _mk_requests(model.cfg.vocab, n=6, budget=8)
        # total pool = 2 lanes * 8 pages (ctx 128 / page 16 = 8); each
        # request needs 2-3 pages. Shrink to nearly nothing mid-stream,
        # recover later.
        plane = FaultPlane(pool=(PoolFault(step=4, delta=-14),
                                 PoolFault(step=24, delta=10)))
        report = eng.serve(reqs, num_slots=2, seed=0, faults=plane)
        statuses = report.statuses
        assert len(statuses) == 6
        assert all(s in TERMINAL_STATUSES for s in statuses.values())
        assert any(s == "ok" for s in statuses.values())
        for r in report.rejected:
            assert r.error is not None and r.error.code in (
                "infeasible_pages", "admission_stalled")

    def test_random_seeded_plane_always_terminates(self, dense_model):
        """FaultPlane.random schedules across seeds: serve never
        raises, statuses stay exhaustive, executable stays at one."""
        model, params = dense_model
        eng = ServingEngine(model, params, _cfg())
        for seed in range(3):
            reqs = _mk_requests(model.cfg.vocab, n=4, budget=6)
            plane = FaultPlane.random(
                seed, steps=48, rids=[r.rid for r in reqs])
            report = eng.serve(reqs, num_slots=2, seed=0, faults=plane)
            statuses = report.statuses
            assert len(statuses) == 4, (seed, statuses)
            assert all(s in TERMINAL_STATUSES
                       for s in statuses.values()), (seed, statuses)
        assert eng._serve_jit._cache_size() == 1


# --------------------------------------------------------------------------- #
# deadline / cancellation / rejection semantics
# --------------------------------------------------------------------------- #

class TestDegradationSemantics:
    def test_deadline_times_out_live_request(self, dense_model):
        """deadline_s=0 expires at the first boundary: the request ends
        "timeout", pages release, neighbors are untouched."""
        model, params = dense_model
        eng = ServingEngine(model, params, _cfg())
        reqs = _mk_requests(model.cfg.vocab, n=3, budget=64)
        reqs[1].deadline_s = 0.0
        report = eng.serve(reqs, num_slots=2, seed=0)
        statuses = report.statuses
        assert statuses[1] == "timeout"
        victim = next(r for r in report.completed + report.rejected
                      if r.rid == 1)
        assert victim.error.code == "deadline_exceeded"
        assert statuses[0] == "ok" and statuses[2] == "ok"

    def test_precancelled_request_reaped(self, dense_model):
        """cancel() before serving starts: the request ends
        "cancelled" at the first boundary without blocking the rest."""
        model, params = dense_model
        eng = ServingEngine(model, params, _cfg())
        reqs = _mk_requests(model.cfg.vocab, n=3, budget=32)
        report_holder = {}
        # submit resets cancel_requested, so cancel must land after
        # submit: patch in via a tiny subclass hook is overkill — use
        # deadline-free cancel through the batcher the engine exposes
        class Cancelling(ServingEngine):
            def _admit_lane(self, req, hs):
                super()._admit_lane(req, hs)
                if req.rid == 1:
                    req.cancel()
        eng = Cancelling(model, params, _cfg())
        report = eng.serve(reqs, num_slots=2, seed=0)
        statuses = report.statuses
        assert statuses[1] == "cancelled"
        assert statuses[0] == "ok" and statuses[2] == "ok"
        del report_holder

    def test_duplicate_rid_rejected(self, dense_model):
        model, params = dense_model
        rng = np.random.default_rng(2)
        eng = ServingEngine(model, params, _cfg())
        a = Request(rid=5, prompt=rng.integers(0, model.cfg.vocab, (16,)),
                    max_new_tokens=3)
        b = Request(rid=5, prompt=rng.integers(0, model.cfg.vocab, (16,)),
                    max_new_tokens=3)
        report = eng.serve([a, b], num_slots=2, seed=0)
        assert a.status == "ok" and len(a.output) == 3
        assert b.status == "rejected"
        assert b.error.code == "duplicate_rid"

    def test_oversized_footprint_rejected_midstream(self, dense_model):
        """A request whose page footprint exceeds the whole pool is
        rejected at submit — it never crashes the stream after other
        requests have run (the old engine.py:688 RuntimeError)."""
        model, params = dense_model
        rng = np.random.default_rng(4)
        eng = ServingEngine(model, params, _cfg())
        good = [Request(rid=i,
                        prompt=rng.integers(0, model.cfg.vocab, (16,)),
                        max_new_tokens=4) for i in range(2)]
        # 3 pages needed vs a 2-page pool; context-feasible (
        # 32+16 <= 128) so it reaches the scheduler's pool check
        big = Request(rid=9, prompt=rng.integers(0, model.cfg.vocab,
                                                 (32,)),
                      max_new_tokens=16)
        report = eng.serve(good + [big], num_slots=2, seed=0,
                           total_pages=2)
        assert big.status == "rejected"
        assert big.error.code == "infeasible_pages"
        assert all(r.status == "ok" and len(r.output) == 4
                   for r in report.completed)


# --------------------------------------------------------------------------- #
# property test: pool + ledger invariants under random interleavings
# --------------------------------------------------------------------------- #

def _check_invariants(b: ContinuousBatcher) -> None:
    """The accounting that must hold after EVERY operation."""
    reserved = sum(s.request.pages_needed for s in b.slots
                   if s.request is not None)
    # conservation: reserved + free == pool (free may be negative
    # after a shrink; reserved pages stay reserved)
    assert reserved + b.free_pages == b.total_pages, \
        (reserved, b.free_pages, b.total_pages)
    # ledger: open rows correspond 1:1 with live slots, closed rows
    # never resurrect
    open_rows = [r for r in b.bindings if r["released_step"] < 0]
    live_rids = sorted(s.request.rid for s in b.slots
                       if s.request is not None)
    assert sorted(r["rid"] for r in open_rows) == live_rids
    for row in b.bindings:
        if row["released_step"] >= 0:
            assert row["released_step"] >= row["admitted_step"]
    # terminal requests hold terminal statuses; nothing live does
    for r in b.completed:
        assert r.status in TERMINAL_STATUSES
    for r in b.rejected:
        assert r.status in TERMINAL_STATUSES and r.status != "ok"
    for s in b.slots:
        if s.request is not None:
            assert s.request.status == "pending"


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 7)),
                min_size=1, max_size=60),
       st.integers(0, 2**32 - 1))
def test_pool_and_ledger_invariants_hold(ops, seed):
    """free_pages accounting + the bindings ledger stay invariant under
    random admit/reject/fail/cancel/complete/resize interleavings."""
    rng = np.random.default_rng(seed)
    b = ContinuousBatcher(num_slots=3, total_pages=12, page_tokens=16,
                          max_skips=2)
    next_rid = [0]

    def submit(arg):
        # footprints from tiny to pool-busting; occasional duplicate
        dup = arg == 7 and (b.queue or b.live_requests())
        if dup:
            pool = [q.rid for q in b.queue] + \
                [r.rid for r in b.live_requests()]
            rid = pool[arg % len(pool)]
        else:
            rid = next_rid[0]
            next_rid[0] += 1
        b.submit(Request(rid=rid, prompt_len=16 * (1 + arg % 4),
                         max_new_tokens=8))

    def admit(arg):
        b.admit()

    def complete(arg):
        live = b.live_requests()
        if live:
            status = TERMINAL_STATUSES[arg % len(TERMINAL_STATUSES)]
            if status == "rejected":     # not a complete() status
                status = "failed"
            b.complete(live[arg % len(live)], status)

    def drop(arg):
        if b.queue:
            q = list(b.queue)[arg % len(b.queue)]
            b.drop_queued(q, "cancelled" if arg % 2 else "timeout",
                          "chaos")

    def resize(arg):
        b.resize_pool(int(rng.integers(-6, 7)))

    def tick(arg):
        b.step_idx += 1

    actions = [submit, admit, complete, drop, resize, tick]
    for op, arg in ops:
        actions[op](arg)
        _check_invariants(b)
    # drain: everything still live/queued can always be retired
    while b.queue:
        b.drop_queued(b.queue[0], "cancelled", "drain")
        _check_invariants(b)
    for r in list(b.live_requests()):
        b.complete(r, "ok")
        _check_invariants(b)
    assert b.free_pages == b.total_pages


def _drive_workload_stream(seed, arrival, slots):
    """Drive a workload-plane stream through the batcher protocol with
    a seeded interleaving of submit / admit / complete / SLO-shed /
    cancel / timeout / resize, checking `_check_invariants` after
    every operation. Returns the drained batcher + request count."""
    stream = wl.generate(wl.WorkloadSpec(
        seed=seed, n_requests=20, arrival=arrival, rate_rps=50.0,
        max_prompt=64, max_new=8, vocab=64))
    pending = stream.requests()
    rng = np.random.default_rng(seed)
    b = ContinuousBatcher(num_slots=slots, total_pages=8 * slots,
                          page_tokens=16, max_skips=2)
    while pending or b.queue or b.live_requests():
        # next arrival burst, in stream order (arrivals are sorted)
        for _ in range(int(rng.integers(0, 3))):
            if pending:
                assert b.submit(pending.pop(0))
                _check_invariants(b)
        b.admit()
        _check_invariants(b)
        act = int(rng.integers(0, 6))
        live = b.live_requests()
        queued = list(b.queue)
        if act == 0 and live:
            b.complete(live[int(rng.integers(len(live)))], "ok")
        elif act == 1 and live:
            b.complete(live[int(rng.integers(len(live)))], "timeout")
        elif act == 2 and queued:                   # SLO admission shed
            b.drop_queued(queued[int(rng.integers(len(queued)))],
                          "rejected", "slo_shed",
                          "projected TTFT over target")
        elif act == 3 and queued:
            b.drop_queued(queued[int(rng.integers(len(queued)))],
                          ("cancelled", "timeout")[int(rng.integers(2))],
                          "chaos")
        elif act == 4:
            # keep the pool above the max footprint (5 pages at
            # prompt<=64 + 8 new, 16-token pages) so the drain loop
            # cannot stall on a shrunken pool with no completions left
            delta = int(rng.integers(-2, 3))
            if b.total_pages + delta >= 8:
                b.resize_pool(delta)
        elif not pending and live:                  # guarantee progress
            b.complete(live[0], "ok")
        b.step_idx += 1
        _check_invariants(b)
    return b, stream.n


def _assert_drained_exhaustive(b, n):
    retired = b.completed + b.rejected
    # every submitted request retired EXACTLY once, terminal status
    rids = sorted(r.rid for r in retired)
    assert rids == list(range(n)), rids
    assert all(r.status in TERMINAL_STATUSES for r in retired)
    assert all(r.status != "ok" for r in b.rejected)
    # ledger fully closed, rid-unique among rows open at any instant
    # (checked per-op by _check_invariants); pool conserved
    assert all(row["released_step"] >= 0 for row in b.bindings)
    assert b.free_pages == b.total_pages


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.sampled_from(wl.ARRIVALS),
       st.integers(2, 4))
def test_scheduler_invariants_under_workload_traffic(seed, arrival,
                                                     slots):
    """The tentpole property under GENERATED traffic: for any workload
    seed, arrival process, and slot count, random interleavings of
    submit/admit/complete/SLO-shed/cancel/timeout/resize keep
    `reserved + free == total` and the bindings ledger consistent
    after every operation, and every request drains to exactly one
    terminal status."""
    b, n = _drive_workload_stream(seed, arrival, slots)
    _assert_drained_exhaustive(b, n)


def test_scheduler_workload_stream_smoke_without_hypothesis():
    """Deterministic companions of the property above (one seed per
    arrival process) so the coverage survives without hypothesis."""
    for i, arrival in enumerate(wl.ARRIVALS):
        b, n = _drive_workload_stream(1000 + i, arrival, slots=2 + i)
        _assert_drained_exhaustive(b, n)


def test_pool_ledger_smoke_without_hypothesis():
    """Deterministic mini-version of the property test so the invariant
    coverage survives images without hypothesis installed."""
    b = ContinuousBatcher(num_slots=2, total_pages=8, page_tokens=16,
                          max_skips=2)
    reqs = [Request(rid=i, prompt_len=32, max_new_tokens=16)
            for i in range(4)]
    for r in reqs:
        assert b.submit(r)
    _check_invariants(b)
    assert len(b.admit()) == 2                    # 3 pages each, pool 8
    _check_invariants(b)
    b.resize_pool(-5)                             # free: 2 -> -3
    _check_invariants(b)
    assert b.free_pages < 0
    assert not b.admit()                          # stalled, not crashed
    b.complete(reqs[0], "failed")
    _check_invariants(b)
    b.complete(reqs[1], "ok")
    _check_invariants(b)
    b.resize_pool(5)
    assert len(b.admit()) == 2                    # recovery admits both
    _check_invariants(b)
    for r in (reqs[2], reqs[3]):
        b.complete(r, "ok")
    _check_invariants(b)
    assert b.free_pages == b.total_pages == 8
    assert {r.rid: r.status for r in b.completed} == \
        {0: "failed", 1: "ok", 2: "ok", 3: "ok"}
