"""Placement policies + behavioral simulator invariants (paper §IV)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.experiment import Workload, make_sim, run_strategy
from repro.core.placement import POLICIES, SAGuided
from repro.core.sa import SAConfig
from repro.core.tiers import GH200
from repro.core.traces import synthetic_trace

WL = Workload(bytes_per_token_layer=2 * 8 * 128 * 2, num_layers=4)


def small_trace(sparsity=0.7, variation=0.3, seed=0):
    return synthetic_trace(prompt_len=2048, decode_len=200, page_tokens=16,
                           sparsity=sparsity, variation=variation, seed=seed)


def budget_for(tr, frac=0.25):
    total = (tr.prompt_len + tr.decode_len) * WL.bytes_per_token_layer \
        * WL.num_layers
    return frac * total


class TestCapacityInvariant:
    @pytest.mark.parametrize("policy_name",
                             ["static", "reactive", "quest", "belady",
                              "cost_aware"])
    def test_hbm_never_exceeds_budget(self, policy_name):
        tr = small_trace()
        policy = POLICIES[policy_name]()
        sim = make_sim(tr, GH200, policy, WL, budget_for(tr))
        budget = sim.hbm_budget_pages
        # instrument: check after every step via monkeypatched loop
        orig = sim._apply_migrations

        def checked(promote, demote):
            r = orig(promote, demote)
            assert sim.hbm_used <= budget
            assert (sim.placement == 0).sum() == sim.hbm_used
            return r

        sim._apply_migrations = checked
        sim.run()
        assert sim.hbm_used <= budget

    @given(st.integers(1, 40), st.floats(0.0, 1.0))
    @settings(max_examples=15, deadline=None)
    def test_sa_policy_capacity(self, window, ratio):
        tr = small_trace(seed=3)
        sim = make_sim(tr, GH200, SAGuided(window, ratio), WL,
                       budget_for(tr))
        sim.run()
        assert sim.hbm_used <= sim.hbm_budget_pages


class TestStrategyOrdering:
    def test_unlimited_hit_rate_is_one(self):
        tr = small_trace()
        r = run_strategy("unlimited", tr, GH200, WL, budget_for(tr))
        assert r.hbm_hit_rate == pytest.approx(1.0)

    def test_static_never_migrates(self):
        tr = small_trace()
        r = run_strategy("static", tr, GH200, WL, budget_for(tr))
        assert r.migrated_bytes == 0.0

    def test_oracles_beat_static(self):
        """The paper's core claim, in miniature: foresight-guided
        placement beats static on a clustered trace."""
        tr = small_trace(sparsity=0.75)
        b = budget_for(tr)
        static = run_strategy("static", tr, GH200, WL, b)
        quest = run_strategy("quest", tr, GH200, WL, b)
        sa = run_strategy("sa", tr, GH200, WL, b,
                          sa_cfg=SAConfig(max_evaluations=40, seed=0))
        assert quest.total_latency_s < static.total_latency_s
        assert sa.total_latency_s < static.total_latency_s

    def test_hit_rate_conservation(self):
        tr = small_trace()
        r = run_strategy("reactive", tr, GH200, WL, budget_for(tr))
        assert 0.0 <= r.hbm_hit_rate <= 1.0
        assert r.read_bytes_hbm + r.read_bytes_dram > 0

    def test_gap_narrows_at_high_sparsity(self):
        """Fig. 3 shape: SA/reactive ratio smaller at 0.9 than at 0.6."""
        b = None
        ratios = {}
        for sp in (0.6, 0.9):
            tr = small_trace(sparsity=sp)
            b = budget_for(tr)
            reactive = run_strategy("reactive", tr, GH200, WL, b)
            sa = run_strategy("sa", tr, GH200, WL, b,
                              sa_cfg=SAConfig(max_evaluations=30, seed=1))
            ratios[sp] = reactive.total_latency_s / sa.total_latency_s
        assert ratios[0.9] < ratios[0.6]


class TestSAOptimizer:
    def test_anneal_converges_on_toy(self):
        from repro.core.sa import anneal
        # convex bowl with optimum at W=16, R=0.5
        calls = []

        def obj(w, r):
            calls.append((w, r))
            return (w - 16) ** 2 + 40 * (r - 0.5) ** 2 + 1.0

        res = anneal(obj, init=(4, 0.0),
                     cfg=SAConfig(max_evaluations=200, seed=0))
        w, r = res.best_state
        assert abs(w - 16) <= 4
        assert abs(r - 0.5) <= 0.2
        assert res.evaluations <= 200

    def test_proposal_attribution_present(self):
        from repro.core.sa import anneal
        res = anneal(lambda w, r: abs(w - 10) + abs(r - 0.3),
                     cfg=SAConfig(max_evaluations=100, seed=2))
        assert set(res.accept_attribution) == {"dW", "dR", "dWdR"}

    def test_memoization(self):
        from repro.core.sa import anneal
        seen = set()
        uniq = []

        def obj(w, r):
            key = (w, round(r, 3))
            assert key not in seen, "objective re-evaluated"
            seen.add(key)
            return float(abs(w - 8)) + abs(r - 0.5)

        anneal(obj, cfg=SAConfig(max_evaluations=60, seed=3))


class TestTraces:
    @given(st.floats(0.3, 0.95), st.floats(0.0, 1.0), st.integers(0, 5))
    @settings(max_examples=10, deadline=None)
    def test_no_access_before_birth(self, sp, var, seed):
        tr = synthetic_trace(prompt_len=512, decode_len=64,
                             sparsity=sp, variation=var, seed=seed)
        tr.validate()   # raises on violation

    def test_sparsity_targets_realized(self):
        tr = synthetic_trace(prompt_len=4096, decode_len=128, sparsity=0.8,
                             variation=0.2, seed=0)
        assert abs(tr.sparsity - 0.8) < 0.1

    def test_trace_from_scores(self):
        from repro.core.traces import trace_from_scores
        rng = np.random.default_rng(0)
        scores = rng.random((32, 1024)) * (rng.random((32, 1024)) < 0.2)
        tr = trace_from_scores(scores, prompt_len=1000, sparsity=0.7)
        tr.validate()
        assert tr.num_steps == 32
