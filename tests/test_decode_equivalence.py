"""The serving stack's core fidelity property: prefill + paged two-tier
decode reproduces the teacher-forced forward EXACTLY (both tiers in
play, fresh-page allocation on page boundaries, position handling)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.model import Model

B = 2
PREFILL = 16   # page-aligned on purpose: forces fresh-page allocation
DECODE = 4


def _f32(cfg):
    return dataclasses.replace(cfg, dtype=jnp.float32,
                               param_dtype=jnp.float32)


def _run(arch, hbm_fraction):
    rng = np.random.default_rng(42)
    cfg = _f32(configs.get_smoke(arch))
    if cfg.family == "moe":
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab, (B, PREFILL + DECODE)), jnp.int32)
    extra = {}
    if cfg.family == "vlm":
        extra["patch_embeds"] = jnp.asarray(rng.standard_normal(
            (B, cfg.frontend.num_embeddings, cfg.d_model)) * 0.05,
            jnp.float32)
    if cfg.family == "encdec":
        extra["frame_embeds"] = jnp.asarray(rng.standard_normal(
            (B, cfg.frontend.num_embeddings, cfg.d_model)) * 0.05,
            jnp.float32)
    full = model.forward(params, tokens, extra=extra, remat=False)
    if isinstance(full, tuple):
        full = full[0]
    off = cfg.frontend.num_embeddings if cfg.family == "vlm" else 0
    geo = model.cache_geometry(B, 64, hbm_fraction=hbm_fraction,
                               pad_to=1)
    lg, state = model.prefill(params, tokens[:, :PREFILL], geo, extra=extra)
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(full[:, off + PREFILL - 1]), atol=2e-3)
    for t in range(DECODE):
        lg, state = model.decode_step(params, state, tokens[:, PREFILL + t])
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full[:, off + PREFILL + t]),
            atol=2e-3)


DECODE_ARCHS = [a for a in configs.all_arch_names()
                if configs.get_smoke(a).family != "xlstm"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward_two_tiers(arch):
    _run(arch, hbm_fraction=0.3)   # both tiers populated


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "zamba2-1.2b"])
def test_decode_matches_forward_hbm_only(arch):
    _run(arch, hbm_fraction=1.0)   # everything fits in HBM


def test_xlstm_decode_matches_forward():
    rng = np.random.default_rng(0)
    cfg = _f32(configs.get_smoke("xlstm-125m"))
    model = Model(cfg)
    params = model.init(jax.random.key(2))
    S = 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    full = model.forward(params, tokens, remat=False)
    state = model.init_decode_state(B)
    for t in range(S):
        lg, state = model.decode_step(params, state, tokens[:, t])
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, t]),
                                   atol=2e-3)


def test_migration_preserves_decode_exactness():
    """Promote/demote pages mid-decode; logits must be unchanged
    (placement is a performance decision, never a semantic one)."""
    from repro.kvcache.migrate import MigrationPlan, apply_migrations
    rng = np.random.default_rng(9)
    cfg = _f32(configs.get_smoke("internlm2-1.8b"))
    model = Model(cfg)
    params = model.init(jax.random.key(3))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, PREFILL + 2)),
                         jnp.int32)
    full = model.forward(params, tokens, remat=False)
    geo = model.cache_geometry(B, 64, hbm_fraction=0.5, pad_to=1)
    _, cache = model.prefill(params, tokens[:, :PREFILL], geo)

    # demote logical page 0 (hbm slot 0) to a free host slot, for every
    # layer and batch entry
    moves = []
    eo = np.asarray(cache.host_owner)
    for l in range(cache.k_hbm.shape[0]):
        for b in range(B):
            free = np.nonzero(eo[l, b] < 0)[0]
            moves.append((l, b, 0, int(free[0]), 0))
    plan = MigrationPlan.build(len(moves), [], moves)
    cache = apply_migrations(cache, plan)

    # control plane must now choose write slots explicitly (the static
    # logical==slot assumption no longer holds after migration)
    def free_slots(cache):
        """Engine-style: reuse the existing mapping when the token's
        logical page is already allocated, else pick a free slot."""
        pt = np.asarray(cache.page_table)
        ho = np.asarray(cache.hbm_owner)
        eo = np.asarray(cache.host_owner)
        T = cache.k_hbm.shape[3]
        logical = int(np.asarray(cache.length)[0]) // T
        L, Bn = ho.shape[0], ho.shape[1]
        ws = np.zeros((L, Bn), np.int32)
        for l in range(L):
            for b in range(Bn):
                if pt[l, b, logical] >= 0:
                    ws[l, b] = pt[l, b, logical]
                    continue
                fh = np.nonzero(ho[l, b] < 0)[0]
                if len(fh):
                    ws[l, b] = fh[0]
                else:
                    fe = np.nonzero(eo[l, b] < 0)[0]
                    ws[l, b] = ho.shape[2] + fe[0]
        return jnp.asarray(ws)

    lg, cache = model.decode_step(params, cache, tokens[:, PREFILL],
                                  write_slot=free_slots(cache))
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(full[:, PREFILL]), atol=2e-3)

    # now promote it back INTO A FREE SLOT and decode again
    ho = np.asarray(cache.hbm_owner)
    eo = np.asarray(cache.host_owner)
    moves = []
    for l in range(cache.k_hbm.shape[0]):
        for b in range(B):
            src = np.nonzero(eo[l, b] == 0)[0]
            free_h = np.nonzero(ho[l, b] < 0)[0]
            moves.append((l, b, int(src[0]), int(free_h[0]), 0))
    plan = MigrationPlan.build(len(moves), moves, [])
    cache = apply_migrations(cache, plan)
    lg, cache = model.decode_step(params, cache, tokens[:, PREFILL + 1],
                                  write_slot=free_slots(cache))
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(full[:, PREFILL + 1]), atol=2e-3)
