"""Unit tests for the mesh sharding rules (repro.launch.shardings).

The rules read only `mesh.axis_names` + `mesh.shape`, so an
`AbstractMesh` (axis names + sizes, no devices) exercises every
divisibility branch on the 1-device tier-1 CI legs — including mesh
shapes (16x16, pods) far bigger than any test host.
"""

from types import SimpleNamespace

import jax
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.launch import shardings as shd


def am(**sizes):
    return AbstractMesh(tuple(sizes.items()))


def geo_stub(*, kv_heads=2, hbm_pages=16, host_pages=16, batch=4,
             num_layers=2, max_pages=8):
    return SimpleNamespace(kv_heads=kv_heads, head_dim=16,
                           hbm_pages=hbm_pages, host_pages=host_pages,
                           batch=batch, num_layers=num_layers,
                           max_pages=max_pages)


# --------------------------------------------------------------------- #
# batch_axes: the widest-divisible-suffix rule (ISSUE 7 satellite fix)
# --------------------------------------------------------------------- #

def test_batch_axes_full_divisibility_uses_every_axis():
    assert shd.batch_axes(am(pod=2, data=4, model=2), 16) == \
        ("pod", "data")


def test_batch_axes_falls_back_to_data_not_replication():
    # batch 4 divides data=4 but not pod*data=8: the pre-fix code
    # replicated everywhere; now it shards over data alone
    assert shd.batch_axes(am(pod=2, data=4, model=2), 4) == ("data",)


def test_batch_axes_indivisible_batch_replicates():
    assert shd.batch_axes(am(pod=2, data=4, model=2), 3) == ()
    assert shd.batch_axes(am(data=2, model=2), 1) == ()


def test_batch_axes_none_trusts_caller():
    assert shd.batch_axes(am(pod=2, data=4, model=2)) == ("pod", "data")
    assert shd.batch_axes(am(data=2, model=2)) == ("data",)


def test_batch_axes_data_mesh():
    assert shd.batch_axes(am(data=2, model=2), 4) == ("data",)
    assert shd.batch_axes(am(data=2, model=2), 3) == ()


# --------------------------------------------------------------------- #
# parameter + kv-pool rules
# --------------------------------------------------------------------- #

def test_param_pspec_model_axis_picks_priority_dim():
    spec = shd.param_pspec(("embed", "mlp"), (64, 128),
                           am(data=2, model=2), mode="serve")
    assert spec == P(None, "model")        # mlp outranks embed


def test_param_pspec_skips_indivisible_dims():
    # mlp=130 not divisible by 4: model falls through to embed
    spec = shd.param_pspec(("embed", "mlp"), (64, 130),
                           am(data=2, model=4), mode="serve")
    assert spec == P("model", None)


def test_param_pspec_train_adds_fsdp_serve_does_not():
    train = shd.param_pspec(("embed", "mlp"), (64, 128),
                            am(data=2, model=2), mode="train")
    serve = shd.param_pspec(("embed", "mlp"), (64, 128),
                            am(data=2, model=2), mode="serve")
    assert train == P("data", "model")
    assert serve == P(None, "model")


def test_kv_shard_axis_prefers_heads_then_pages():
    mesh = am(data=2, model=2)
    assert shd._kv_shard_axis(geo_stub(kv_heads=2), mesh) == "kv_heads"
    assert shd._kv_shard_axis(geo_stub(kv_heads=3), mesh) == "pages"
    assert shd._kv_shard_axis(
        geo_stub(kv_heads=3, hbm_pages=15), mesh) == "none"


def test_cache_shardings_specs():
    mesh = am(data=2, model=2)
    cs = shd.cache_shardings(geo_stub(kv_heads=2, batch=4), mesh)
    assert cs.k_hbm.spec == P(None, ("data",), None, None, "model", None)
    assert cs.hbm_owner.spec == P(None, ("data",), None)
    assert cs.page_table.spec == P(None, ("data",), None)
    assert cs.length.spec == P(("data",))
    # page-sharded fallback: model axis moves from kv_heads to pages
    cs = shd.cache_shardings(geo_stub(kv_heads=3, batch=4), mesh)
    assert cs.k_hbm.spec == P(None, ("data",), "model", None, None, None)
    assert cs.hbm_owner.spec == P(None, ("data",), "model")


# --------------------------------------------------------------------- #
# serve-loop bundles
# --------------------------------------------------------------------- #

def test_policy_state_shardings_by_leaf_shape():
    mesh = am(data=2, model=2)
    geo = geo_stub(batch=4, num_layers=2, max_pages=8)
    state = {
        "last": jax.ShapeDtypeStruct((2, 4, 8), "int32"),    # [L, B, P]
        "lane": jax.ShapeDtypeStruct((4,), "int32"),         # [B]
        "bar": jax.ShapeDtypeStruct((), "float32"),          # scalar
    }
    sh = shd.policy_state_shardings(state, geo, mesh)
    assert sh["last"].spec == P(None, ("data",), None)
    assert sh["lane"].spec == P(("data",))
    assert sh["bar"].spec == P()
    assert shd.policy_state_shardings((), geo, mesh) == ()


def test_serve_shardings_bundle():
    mesh = am(data=2, model=2)
    sh = shd.serve_shardings(geo_stub(batch=4), mesh)
    assert sh["lane"].spec == P(("data",))
    assert sh["lane_kv"].spec == P(("data",), None)
    assert sh["step_lane"].spec == P(None, ("data",))
    assert sh["rep"].spec == P()
    assert sh["cache"].k_hbm.spec[4] == "model"


def test_serve_shardings_indivisible_lanes_replicate():
    sh = shd.serve_shardings(geo_stub(batch=3), am(data=2, model=2))
    assert sh["lane"].spec == P(())
    assert sh["cache"].length.spec == P(())


def test_real_trivial_mesh_accepted():
    # the concrete Mesh path (mesh.shape OrderedDict) on 1 device
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = shd.serve_shardings(geo_stub(batch=2), mesh)
    assert sh["lane"].spec == P(("data",))
    assert shd.batch_axes(mesh, 2) == ("data",)


def test_abstract_and_concrete_sizes_agree():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.launch.mesh import mesh_axis_sizes
    assert mesh_axis_sizes(mesh) == {"data": 1, "model": 1}
    assert mesh_axis_sizes(am(data=2, model=2)) == \
        {"data": 2, "model": 2}


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
