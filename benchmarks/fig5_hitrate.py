"""Paper Fig. 5: HBM hit rates per strategy at 60% attention sparsity.

`derived` column = HBM hit rate in [0, 1]; us_per_call = per-token
simulated latency.
"""

from __future__ import annotations

from benchmarks.common import (
    SA_CFG, STRATEGIES, kv_budget, make_trace, workload,
)
from repro.core.experiment import run_strategy
from repro.core.tiers import GH200


def run(print_csv: bool = True):
    wl = workload()
    tr = make_trace(sparsity=0.6, seed=2)
    budget = kv_budget(tr, wl)
    rows = []
    for name in STRATEGIES:
        res = run_strategy(name, tr, GH200, wl, budget, sa_cfg=SA_CFG)
        us_tok = res.total_latency_s / tr.decode_len * 1e6
        rows.append((f"fig5/hitrate/{res.policy}", us_tok,
                     res.hbm_hit_rate))
    if print_csv:
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived:.3f}")
    return rows


if __name__ == "__main__":
    run()
