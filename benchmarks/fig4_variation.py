"""Paper Fig. 4: normalized tokens/s vs Unlimited HBM under LOW and HIGH
token-importance variation, at 60% attention sparsity.

The paper synthesizes low/high-variation traces; our trace generator's
`variation` knob is exactly that axis (AR(1) drift rate of the
importance process).
"""

from __future__ import annotations

from benchmarks.common import (
    SA_CFG, STRATEGIES, kv_budget, make_trace, workload,
)
from repro.core.experiment import run_strategy
from repro.core.tiers import GH200

VARIATIONS = {"low": 0.05, "high": 0.8}
SPARSITY = 0.6


def run(print_csv: bool = True):
    wl = workload()
    rows = []
    for label, var in VARIATIONS.items():
        tr = make_trace(sparsity=SPARSITY, variation=var, seed=1)
        budget = kv_budget(tr, wl)
        unlimited = run_strategy("unlimited", tr, GH200, wl, budget)
        for name in STRATEGIES:
            res = (unlimited if name == "unlimited" else
                   run_strategy(name, tr, GH200, wl, budget, sa_cfg=SA_CFG))
            norm = unlimited.total_latency_s / res.total_latency_s
            us_tok = res.total_latency_s / tr.decode_len * 1e6
            rows.append((f"fig4/variation={label}/{res.policy}",
                         us_tok, norm))
    if print_csv:
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived:.3f}")
    return rows


if __name__ == "__main__":
    run()
