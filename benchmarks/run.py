"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (semantics of `derived` differ
per figure and are documented in each module).

  fig3  — normalized tokens/s vs Static across attention sparsity
  fig4  — normalized tokens/s vs Unlimited-HBM, low/high importance
          variation
  fig5  — HBM hit rates at 60% sparsity
  bound — SA upper bound headline (max speedup, W/R convergence,
          accepted-move attribution) + beyond-paper policies + TPU tiers
  engine— live two-tier serving engine (real paged cache) under the
          same Eq.(1)-(5) accounting
  perf  — wall-clock decode steps/s: fused (lax.scan) vs eager vs the
          pre-fusion host-loop baseline; writes BENCH_engine.json

Roofline numbers come from the dry-run (python -m repro.launch.dryrun,
then python -m repro.launch.roofline), not from this harness — they are
compile-time artifacts, not wall-time measurements.
"""

from __future__ import annotations

import sys


def main() -> None:
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    from benchmarks import (fig3_sparsity, fig4_variation, fig5_hitrate,
                            live_engine, perf_engine, upper_bound)
    suites = {
        "fig3": fig3_sparsity.run,
        "fig4": fig4_variation.run,
        "fig5": fig5_hitrate.run,
        "bound": upper_bound.run,
        "engine": live_engine.run,
        "perf": perf_engine.run,
    }
    if which != "all":
        suites[which]()
        return
    for name, fn in suites.items():
        print(f"# --- {name} ---")
        fn()


if __name__ == '__main__':
    main()
