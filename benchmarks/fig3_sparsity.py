"""Paper Fig. 3: normalized tokens/s vs Static Placement across
attention-sparsity levels, for all five strategies (+ our two extras).

CSV schema: name,us_per_call,derived  where `derived` is the normalized
tokens/s (static = 1.0) and us_per_call is the simulated per-token
latency of the strategy.
"""

from __future__ import annotations

from benchmarks.common import (
    EXTRA_STRATEGIES, SA_CFG, STRATEGIES, kv_budget, make_trace, workload,
)
from repro.core.experiment import run_strategy
from repro.core.tiers import GH200

SPARSITIES = (0.4, 0.6, 0.8, 0.9)


def run(print_csv: bool = True):
    wl = workload()
    rows = []
    for sp in SPARSITIES:
        tr = make_trace(sparsity=sp)
        budget = kv_budget(tr, wl)
        static = run_strategy("static", tr, GH200, wl, budget)
        for name in STRATEGIES + EXTRA_STRATEGIES:
            if name == "static":
                res = static
            else:
                res = run_strategy(name, tr, GH200, wl, budget,
                                   sa_cfg=SA_CFG)
            norm = static.total_latency_s / res.total_latency_s
            us_tok = res.total_latency_s / tr.decode_len * 1e6
            rows.append((f"fig3/sparsity={sp:.1f}/{res.policy}",
                         us_tok, norm))
    if print_csv:
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived:.3f}")
    return rows


if __name__ == "__main__":
    run()
