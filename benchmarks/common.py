"""Shared benchmark configuration — the paper's evaluation setup.

Workload (Section IV-A): LLaMA-3.1-8B, ~30k-token prompts, 10k decoded
tokens, GH200 memory system. We simulate at 16-token-page granularity
with a reduced decode length (2k steps) — relative throughputs are
stable in decode length (verified: <2% drift 1k->4k steps) and the SA
search stays tractable on 1 CPU core.

HBM KV budget: the paper constructs a regime where the KV cache exceeds
the HBM budget; we use budget = 25% of final KV bytes.
"""

from __future__ import annotations

import time

from repro.core.experiment import Workload
from repro.core.sa import SAConfig
from repro.core.traces import synthetic_trace

PROMPT = 30_000
DECODE = 2_000
BUDGET_FRAC = 0.25
SA_CFG = SAConfig(max_evaluations=80, iters_per_level=15, seed=0)
STRATEGIES = ("unlimited", "static", "reactive", "quest", "sa")
EXTRA_STRATEGIES = ("belady", "cost_aware")


def workload():
    return Workload.llama31_8b()


def make_trace(sparsity: float, variation: float = 0.3, seed: int = 0):
    return synthetic_trace(prompt_len=PROMPT, decode_len=DECODE,
                           page_tokens=16, sparsity=sparsity,
                           variation=variation, seed=seed)


def kv_budget(trace, wl) -> float:
    total = (trace.prompt_len + trace.decode_len) \
        * wl.bytes_per_token_layer * wl.num_layers
    return BUDGET_FRAC * total


def timed(fn):
    t0 = time.time()
    out = fn()
    return out, time.time() - t0
