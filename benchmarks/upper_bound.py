"""Headline claim: the SA-guided upper bound vs Static Placement
("up to 5.87x ... consistently 4-5x") + the SA optimizer's own
behaviour (W/R convergence, accepted-move attribution), and the
beyond-paper oracles (Belady) + the deployable no-foresight policy
(cost-aware hysteresis) relative to the bound.

`derived` = speedup over static placement.
"""

from __future__ import annotations

from benchmarks.common import SA_CFG, kv_budget, make_trace, workload
from repro.core.experiment import run_strategy, tune_sa
from repro.core.tiers import GH200, TPU_V5E


def run(print_csv: bool = True):
    wl = workload()
    rows = []
    best = 0.0
    for seed, sp in [(0, 0.7), (1, 0.75), (2, 0.8), (3, 0.85)]:
        tr = make_trace(sparsity=sp, variation=0.25, seed=seed)
        budget = kv_budget(tr, wl)
        static = run_strategy("static", tr, GH200, wl, budget)
        for name in ("sa", "belady", "cost_aware"):
            res = run_strategy(name, tr, GH200, wl, budget, sa_cfg=SA_CFG)
            speed = static.total_latency_s / res.total_latency_s
            if name == "sa":
                best = max(best, speed)
            us_tok = res.total_latency_s / tr.decode_len * 1e6
            rows.append((f"bound/sp={sp:.2f}/{res.policy}", us_tok, speed))
    rows.append(("bound/max_sa_speedup_vs_static", 0.0, best))

    # SA optimizer internals on one operating point
    tr = make_trace(sparsity=0.75, variation=0.25, seed=0)
    sa_res = tune_sa(tr, GH200, wl, kv_budget(tr, wl), cfg=SA_CFG)
    w, r = sa_res.best_state
    rows.append(("bound/sa_best_W", 0.0, float(w)))
    rows.append(("bound/sa_best_R", 0.0, float(r)))
    rows.append(("bound/sa_evaluations", 0.0, float(sa_res.evaluations)))
    att = sa_res.accept_attribution
    rows.append(("bound/sa_accepted_dW", 0.0, float(att["dW"])))
    rows.append(("bound/sa_accepted_dR", 0.0, float(att["dR"])))
    rows.append(("bound/sa_accepted_dWdR", 0.0, float(att["dWdR"])))

    # TPU-v5e tier ratios (hardware adaptation: harsher HBM:link ratio)
    tr = make_trace(sparsity=0.75, variation=0.25, seed=0)
    budget = kv_budget(tr, wl)
    static = run_strategy("static", tr, TPU_V5E, wl, budget)
    sa = run_strategy("sa", tr, TPU_V5E, wl, budget, sa_cfg=SA_CFG)
    rows.append(("bound/tpu_v5e_sa_vs_static",
                 sa.total_latency_s / tr.decode_len * 1e6,
                 static.total_latency_s / sa.total_latency_s))

    if print_csv:
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived:.3f}")
    return rows


if __name__ == "__main__":
    run()
