"""Live serving-engine benchmark: the placement policies running over
the REAL two-tier paged KV cache (not the behavioral simulator), priced
by the same Eq.(1)-(5) model. Connects the simulator results to the
deployed system: importance-EMA placement vs static, with Quest-style
attention sparsity on and off.

`derived` = modeled tokens/s (higher is better); us_per_call = wall
time per engine step on this CPU host (not the modeled latency).

Decode runs through the fused hot path (`ServingEngine.generate`:
lax.scan over telemetry_stride steps per dispatch); the wall-clock
fused-vs-eager comparison lives in benchmarks/perf_engine.py.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.tiers import GH200
from repro.models.model import Model
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.scheduler import Request


def run(print_csv: bool = True, steps: int = 24):
    cfg = configs.get_smoke("internlm2-1.8b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (2, 64)), jnp.int32)

    rows = []
    for policy in ("static", "importance"):
        for sparsity in (0.0, 0.6):
            eng = ServingEngine(model, params, EngineConfig(
                max_context=256, hbm_fraction=0.25, policy=policy,
                attention_sparsity=sparsity, spec=GH200,
                promote_thresh=0.005, telemetry_stride=steps))
            eng.start(prompts)
            tok = jnp.array([1, 2], jnp.int32)
            t0 = time.time()
            out = eng.generate(tok, steps)
            jax.block_until_ready(out)
            wall_us = (time.time() - t0) / steps * 1e6
            s = eng.summary()
            rows.append((
                f"engine/{policy}/sparsity={sparsity:.1f}",
                wall_us, s["modeled_tokens_per_s"]))
            rows.append((
                f"engine/{policy}/sparsity={sparsity:.1f}/hit_rate",
                0.0, s["mean_hbm_hit_rate"]))

    # continuous batching: a mixed-length stream through serve()
    eng = ServingEngine(model, params, EngineConfig(
        max_context=256, hbm_fraction=0.25, policy="importance",
        attention_sparsity=0.0, spec=GH200, promote_thresh=0.005,
        telemetry_stride=8))
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, (32 + 16 * (i % 3),)),
                    max_new_tokens=8 + 4 * (i % 3)) for i in range(6)]
    t0 = time.time()
    done = eng.serve(reqs, num_slots=2, seed=0)
    jax.block_until_ready(eng.state.length)
    total = sum(len(r.output) for r in done)
    wall_us = (time.time() - t0) / max(total, 1) * 1e6
    s = eng.summary()
    # summary()'s modeled_tokens_per_s counts STEPS; a multi-slot step
    # emits one token per active lane, so price tokens explicitly
    modeled_tps = total / s["modeled_total_s"]
    rows.append(("engine/serve/stream", wall_us, modeled_tps))
    rows.append(("engine/serve/hit_rate", 0.0, s["mean_hbm_hit_rate"]))
    if done.ttft:
        rows.append(("engine/serve/ttft_p50", done.ttft["p50"] * 1e6,
                     done.ttft["p50"]))

    if print_csv:
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived:.3f}")
    return rows


if __name__ == "__main__":
    run()
