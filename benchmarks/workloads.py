"""Seeded workload plane: realistic open-loop traffic for the serve loop.

Serving results are only as honest as the traffic behind them. A
uniform closed-loop stream (same prompt length, greedy, all submitted
at t=0) hides exactly the effects the paper's heterogeneous-memory
placement is about: queueing under bursts, heavy-tailed prompt
footprints competing for device pages, and latency SLOs that goodput
is scored against. This module generates that traffic DETERMINISTICALLY
from one integer seed — two instantiations of the same `WorkloadSpec`
produce bitwise-identical arrival times, lengths, tiers, prompt tokens
and sampling keys (tests/test_workloads.py pins this), so every
benchmark row and property test replays exactly.

Pieces (EXPERIMENTS.md §Workloads):

  * arrival processes — homogeneous Poisson (exponential gaps), bursty
    on-off and diurnal sinusoid, the latter two via Lewis-Shedler
    thinning against the rate envelope's maximum;
  * length samplers — lognormal body mixed with a truncated-Zipf tail
    (`zipf_frac`), a fraction snapped UP to page boundaries
    (`snap_frac`, chunked-ingest prompts that exactly fill KV pages —
    the cache-geometry edge case);
  * priority tiers — weighted draw over `TierSpec`s; `SLOPolicy` maps
    tier names to TTFT/TPOT targets (`repro.serving.slo`);
  * sampled traffic — a per-stream `SamplingConfig` plus a drawn
    `stream_seed` for `serve(seed=...)`, so non-greedy streams are as
    reproducible as greedy ones.

`Workload.requests()` materialises `repro.serving.scheduler.Request`
objects with `arrival_s` stamped for the engine's open-loop driver:
`ServingEngine.serve` submits each one at the first chunk boundary
whose wall clock passes its offset. The arrival pattern is pure data —
all three processes drive ONE serve executable (zero retraces), which
the bench CI gate pins (`perf_engine --goodput-sweep --ci`).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.sampling import SamplingConfig
from repro.serving.scheduler import Request

#: the default priority mix: mostly interactive chat, some standard
#: API traffic, a batch tail that tolerates queueing
DEFAULT_TIERS = (("interactive", 0.6), ("standard", 0.3), ("batch", 0.1))

ARRIVALS = ("poisson", "bursty", "diurnal")


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One priority tier: its name (the `Request.tier` /
    `SLOPolicy.targets` key) and its share of the traffic."""

    name: str
    weight: float


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Everything that defines a stream; hashable, seed included —
    the spec IS the workload identity."""

    seed: int = 0
    n_requests: int = 32
    #: arrival process ("poisson" | "bursty" | "diurnal") + mean rate
    arrival: str = "poisson"
    rate_rps: float = 50.0
    #: bursty on-off envelope: rate*burst_factor for the first
    #: `on_fraction` of every `period_s`, rate*off_level otherwise
    burst_factor: float = 4.0
    on_fraction: float = 0.25
    off_level: float = 0.25
    period_s: float = 1.0
    #: diurnal envelope: rate * (1 + amp * sin(2*pi*t/period_s))
    diurnal_amp: float = 0.8
    #: prompt lengths: lognormal(mu, sigma) body mixed with a
    #: truncated Zipf(alpha) tail over [1, max_prompt]
    len_mu: float = 3.0
    len_sigma: float = 0.8
    zipf_alpha: float = 1.3
    zipf_frac: float = 0.25
    min_prompt: int = 1
    max_prompt: int = 96
    #: fraction of prompts snapped UP to a page boundary
    page_tokens: int = 16
    snap_frac: float = 0.25
    #: decode budgets: lognormal clipped to [1, max_new]
    out_mu: float = 2.2
    out_sigma: float = 0.6
    max_new: int = 24
    #: priority mix ((name, weight), ...)
    tiers: Tuple[Tuple[str, float], ...] = DEFAULT_TIERS
    #: sampled (non-greedy) traffic knobs; temperature 0 = greedy
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    #: vocabulary the prompt tokens are drawn from (match the model)
    vocab: int = 256

    def __post_init__(self):
        assert self.arrival in ARRIVALS, self.arrival
        assert self.n_requests >= 1 and self.rate_rps > 0
        assert 0.0 <= self.zipf_frac <= 1.0
        assert 0.0 <= self.snap_frac <= 1.0
        assert abs(sum(w for _, w in self.tiers) - 1.0) < 1e-9, \
            "tier weights must sum to 1"


# ---------------------------------------------------------------------------
# samplers


def zipf_cdf(alpha: float, support: int) -> np.ndarray:
    """CDF of the truncated Zipf(alpha) law over ranks 1..support.
    Exposed so the KS property test scores `sample_zipf` against the
    exact distribution it inverts."""
    pmf = np.arange(1, support + 1, dtype=np.float64) ** (-alpha)
    pmf /= pmf.sum()
    return np.cumsum(pmf)


def sample_zipf(rng: np.random.Generator, alpha: float, support: int,
                size: int) -> np.ndarray:
    """Truncated-Zipf draw by inverse CDF (searchsorted): exact, no
    rejection, one uniform per sample — bitwise reproducible."""
    cdf = zipf_cdf(alpha, support)
    u = rng.random(size)
    return np.searchsorted(cdf, u, side="left") + 1


def _thin(rng: np.random.Generator, lam: Callable[[float], float],
          lam_max: float, n: int) -> np.ndarray:
    """Lewis-Shedler thinning: draw a homogeneous Poisson stream at
    `lam_max` and keep each point with probability lam(t)/lam_max —
    an exact sampler for any bounded-rate inhomogeneous process."""
    out = np.empty(n, np.float64)
    got, t = 0, 0.0
    while got < n:
        t += rng.exponential(1.0 / lam_max)
        if rng.random() * lam_max <= lam(t):
            out[got] = t
            got += 1
    return out


def _arrivals(rng: np.random.Generator, spec: WorkloadSpec) -> np.ndarray:
    n, rate = spec.n_requests, spec.rate_rps
    if spec.arrival == "poisson":
        return np.cumsum(rng.exponential(1.0 / rate, n))
    if spec.arrival == "bursty":
        hi = rate * spec.burst_factor
        lo = rate * spec.off_level

        def lam(t: float) -> float:
            phase = (t % spec.period_s) / spec.period_s
            return hi if phase < spec.on_fraction else lo

        return _thin(rng, lam, hi, n)
    # diurnal sinusoid; amp < 1 keeps the rate positive
    amp = min(spec.diurnal_amp, 0.999)

    def lam(t: float) -> float:
        return rate * (1.0 + amp * np.sin(2.0 * np.pi * t / spec.period_s))

    return _thin(rng, lam, rate * (1.0 + amp), n)


def _lengths(rng: np.random.Generator, spec: WorkloadSpec) -> np.ndarray:
    """Lognormal body + Zipf tail, page-boundary snapping."""
    n = spec.n_requests
    body = rng.lognormal(spec.len_mu, spec.len_sigma, n)
    tail = sample_zipf(rng, spec.zipf_alpha, spec.max_prompt, n)
    use_tail = rng.random(n) < spec.zipf_frac
    out = np.where(use_tail, tail, np.rint(body))
    out = np.clip(out, spec.min_prompt, spec.max_prompt).astype(np.int64)
    snap = rng.random(n) < spec.snap_frac
    pt = max(1, spec.page_tokens)
    snapped = np.minimum(-(-out // pt) * pt, spec.max_prompt)
    return np.maximum(np.where(snap, snapped, out), 1)


# ---------------------------------------------------------------------------
# the generated stream


@dataclasses.dataclass
class Workload:
    """A materialised stream: parallel per-request arrays plus the
    stream-level sampling contract. `requests()` builds fresh
    `Request` objects each call (the engine mutates them)."""

    spec: WorkloadSpec
    arrival_s: np.ndarray          # [n] float64, sorted ascending
    prompt_len: np.ndarray         # [n] int64, >= 1
    max_new: np.ndarray            # [n] int64, >= 1
    tier: List[str]                # [n]
    prompts: List[np.ndarray]      # [n] int32 token rows
    stream_seed: int               # for serve(seed=...)
    sampling: SamplingConfig

    @property
    def n(self) -> int:
        return len(self.prompts)

    def requests(self, *, start_rid: int = 0, time_scale: float = 1.0,
                 open_loop: bool = True) -> List[Request]:
        """Materialise the stream. `time_scale` stretches/compresses
        the arrival clock (0.1 = 10x faster replay); `open_loop=False`
        drops the arrival stamps entirely (everything submits at t=0,
        the closed-loop baseline)."""
        out = []
        for i in range(self.n):
            out.append(Request(
                rid=start_rid + i,
                prompt=self.prompts[i],
                max_new_tokens=int(self.max_new[i]),
                arrival_s=float(self.arrival_s[i]) * time_scale
                if open_loop else 0.0,
                tier=self.tier[i]))
        return out

    def serve_kwargs(self) -> dict:
        """The stream's sampling contract for `ServingEngine.serve`."""
        return {"seed": self.stream_seed, "sampling": self.sampling}


def generate(spec: WorkloadSpec) -> Workload:
    """One seed -> one stream, bitwise. Draw ORDER is part of the
    contract (arrivals, prompt lengths, decode budgets, tiers, stream
    seed, prompt tokens): changing it changes every downstream
    benchmark row, so treat it like a wire format."""
    rng = np.random.default_rng(spec.seed)
    arrival = _arrivals(rng, spec)
    plen = _lengths(rng, spec)
    decode = np.clip(np.rint(rng.lognormal(spec.out_mu, spec.out_sigma,
                                           spec.n_requests)),
                     1, spec.max_new).astype(np.int64)
    names = [t[0] for t in spec.tiers]
    weights = np.asarray([t[1] for t in spec.tiers], np.float64)
    tier_ix = rng.choice(len(names), size=spec.n_requests,
                         p=weights / weights.sum())
    stream_seed = int(rng.integers(0, 2**31 - 1))
    prompts = [rng.integers(0, spec.vocab, int(n)).astype(np.int32)
               for n in plen]
    return Workload(
        spec=spec, arrival_s=arrival, prompt_len=plen, max_new=decode,
        tier=[names[i] for i in tier_ix], prompts=prompts,
        stream_seed=stream_seed,
        sampling=SamplingConfig(temperature=spec.temperature,
                                top_k=spec.top_k, top_p=spec.top_p))


def merge(parts: Sequence[Workload],
          stream_seed: Optional[int] = None) -> Workload:
    """Superpose streams into one arrival-sorted stream (e.g. the
    bench's mixed Poisson+bursty row). Sampling contract comes from
    the first part; pass `stream_seed` to override."""
    assert parts, "merge needs at least one workload"
    arrival = np.concatenate([w.arrival_s for w in parts])
    order = np.argsort(arrival, kind="stable")
    plen = np.concatenate([w.prompt_len for w in parts])[order]
    decode = np.concatenate([w.max_new for w in parts])[order]
    tiers = np.asarray(sum((w.tier for w in parts), []))[order]
    prompts = [p for w in parts for p in w.prompts]
    return Workload(
        spec=parts[0].spec, arrival_s=arrival[order], prompt_len=plen,
        max_new=decode, tier=list(tiers),
        prompts=[prompts[i] for i in order],
        stream_seed=parts[0].stream_seed
        if stream_seed is None else stream_seed,
        sampling=parts[0].sampling)


def mixed_stream(seed: int, n_requests: int, **overrides) -> Workload:
    """The bench's canonical mixed stream: half Poisson, half bursty,
    superposed — steady load with burst waves on top."""
    half = max(1, n_requests // 2)
    a = generate(WorkloadSpec(seed=seed, n_requests=half,
                              arrival="poisson", **overrides))
    b = generate(WorkloadSpec(seed=seed + 1, n_requests=n_requests - half,
                              arrival="bursty", **overrides))
    return merge([a, b], stream_seed=a.stream_seed)


def drive(engine, workload: Workload, *, time_scale: float = 1.0,
          **serve_kwargs):
    """Open-loop load driver sugar: submit the stream against its
    wall-clock arrival offsets with its own sampling contract."""
    reqs = workload.requests(time_scale=time_scale)
    kw = workload.serve_kwargs()
    kw.update(serve_kwargs)
    return engine.serve(reqs, **kw)
