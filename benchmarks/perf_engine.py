"""Wall-clock decode throughput for the live serving engine.

Three drive modes over the SAME model and two-tier paged cache:

  host   — replica of the pre-fusion engine step: per-step host
           round-trips of page_table/owners/importance, nested Python
           [L, B] loops for write-slot choice and migration planning,
           and a `MigrationPlan` whose capacity varies with the step's
           promote/demote count (so `apply_migrations` recompiles for
           nearly every distinct count). This is the baseline the fused
           hot path was built to kill — kept here, not in the engine,
           so the win stays measurable PR over PR.
  eager  — `ServingEngine.step`: the whole step (vectorized control
           plane + decode + fixed-capacity migration) is ONE jitted
           call, but the host dispatches and syncs telemetry per token.
  fused  — `ServingEngine.generate`: `lax.scan` over telemetry_stride
           steps per dispatch, cache donated, one telemetry readback
           per chunk.

A fourth mode measures the headline serving API:

  serve  — `ServingEngine.serve`: a mixed-length request stream through
           the same fused chunks with per-slot active masking, on-device
           sampling, and chunk-boundary admission/reclaim.

Writes BENCH_engine.json (see EXPERIMENTS.md §Perf-suite). The headline
is fused/host steps-per-second; fused executable counts are asserted to
stay at one compile per scan length (zero migration-driven retraces).

Run:  PYTHONPATH=src python benchmarks/perf_engine.py
CI:   PYTHONPATH=src python benchmarks/perf_engine.py --ci
      (reduced geometry; additionally asserts fused >= eager steps/s)
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.tiers import GH200
from repro.kvcache.migrate import MigrationPlan, apply_migrations
from repro.models.model import Model
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.scheduler import Request

STEPS = 64          # multiple of STRIDE: scan lengths compile once in warmup
STRIDE = 32
HOST_STEPS = 8          # the host baseline is too slow for more


# --------------------------------------------------------------------------- #
# seed-style host-side control plane (verbatim behavior of the old engine)
# --------------------------------------------------------------------------- #

class HostLoopEngine(ServingEngine):
    """Pre-fusion reference: host control plane, unfused data plane."""

    def step(self, token):
        from repro.serving.engine import _set_cache
        write_slot = self._host_control_plane(self._cache)
        logits, state = self.model.decode_step(
            self.params, self.state, token, write_slot=write_slot)
        self.state = state
        plan, n_pro, n_dem = self._host_plan_migrations(self._cache)
        # read traffic is priced on post-decode, PRE-migration residency,
        # matching the fused engine's accounting
        self._record_host(n_pro, n_dem)
        if plan is not None:
            self.state = _set_cache(
                self.state, apply_migrations(self._cache, plan))
        return logits

    def _host_control_plane(self, cache):
        geo = self.geo
        length = int(np.asarray(cache.length)[0])
        T = geo.page_tokens
        logical = min(length // T, geo.max_pages - 1)
        pt = np.asarray(cache.page_table)
        ho = np.asarray(cache.hbm_owner)
        eo = np.asarray(cache.host_owner)
        L, B = pt.shape[0], pt.shape[1]
        ws = np.zeros((L, B), np.int32)
        for l in range(L):
            for b in range(B):
                if pt[l, b, logical] >= 0:
                    ws[l, b] = pt[l, b, logical]
                else:
                    free_h = np.nonzero(ho[l, b] < 0)[0]
                    if len(free_h):
                        ws[l, b] = free_h[0]
                    else:
                        free_e = np.nonzero(eo[l, b] < 0)[0]
                        ws[l, b] = geo.hbm_pages + (
                            free_e[0] if len(free_e) else geo.host_pages - 1)
        return jnp.asarray(ws)

    def _host_plan_migrations(self, cache):
        imp = np.asarray(cache.importance)
        ho = np.asarray(cache.hbm_owner)
        eo = np.asarray(cache.host_owner)
        L, B = ho.shape[0], ho.shape[1]
        budget = max(1, int(self.cfg.migration_budget_frac
                            * self.geo.hbm_pages))
        promotes, demotes = [], []
        for l in range(L):
            for b in range(B):
                host_pages = np.nonzero(eo[l, b] >= 0)[0]
                if not len(host_pages):
                    continue
                host_logical = eo[l, b, host_pages]
                host_imp = imp[l, b, host_logical]
                order = np.argsort(-host_imp, kind="stable")
                hot = [(host_pages[i], host_logical[i], host_imp[i])
                       for i in order[:budget]
                       if host_imp[i] > self.cfg.promote_thresh]
                if not hot:
                    continue
                hbm_pages = np.nonzero(ho[l, b] >= 0)[0]
                hbm_logical = ho[l, b, hbm_pages]
                hbm_imp = imp[l, b, hbm_logical]
                cold_order = np.argsort(hbm_imp, kind="stable")
                free = np.nonzero(ho[l, b] < 0)[0].tolist()
                ci = 0
                for src, logical, h_imp in hot:
                    if free:
                        dst = free.pop(0)
                    elif ci < len(cold_order):
                        victim = cold_order[ci]
                        if hbm_imp[victim] >= h_imp:
                            break
                        vslot = hbm_pages[victim]
                        demotes.append((l, b, vslot, src,
                                        hbm_logical[victim]))
                        dst = vslot
                        ci += 1
                    else:
                        break
                    promotes.append((l, b, src, dst, logical))
        if not promotes and not demotes:
            return None, 0, 0
        # the step-varying capacity that forced per-step recompiles
        cap = max(len(promotes), len(demotes), 1)
        plan = MigrationPlan.build(cap, promotes, demotes)
        return plan, len(promotes), len(demotes)

    def _record_host(self, n_pro, n_dem):
        cache = self._cache
        h_pages = int(np.asarray((cache.hbm_owner >= 0).sum()))
        e_pages = int(np.asarray((cache.host_owner >= 0).sum()))
        self._record(np.asarray([[h_pages, e_pages, n_pro, n_dem]]))


# --------------------------------------------------------------------------- #

def _engine(model, params, policy, klass=ServingEngine, batch=2):
    eng = klass(model, params, EngineConfig(
        max_context=512, hbm_fraction=0.25, policy=policy,
        attention_sparsity=0.0, spec=GH200, promote_thresh=1e-4,
        telemetry_stride=STRIDE))
    rng = np.random.default_rng(0)
    # the prompt spills past the HBM pool so migrations actually fire
    prompts = jnp.asarray(rng.integers(0, model.cfg.vocab, (batch, 272)),
                          jnp.int32)
    eng.start(prompts)
    return eng


def _time_steps(eng, steps):
    tok = jnp.array([1, 2], jnp.int32)
    tok = jnp.argmax(eng.step(tok), -1).astype(jnp.int32)   # compile
    t0 = time.perf_counter()
    for _ in range(steps):
        tok = jnp.argmax(eng.step(tok), -1).astype(jnp.int32)
    jax.block_until_ready(tok)
    return steps / (time.perf_counter() - t0)


def _time_fused(eng, steps):
    eng.generate(jnp.array([1, 2], jnp.int32), STRIDE)      # compile
    tok = jnp.array([3, 4], jnp.int32)
    t0 = time.perf_counter()
    out = eng.generate(tok, steps)
    jax.block_until_ready(out)
    return steps / (time.perf_counter() - t0)


def _time_serve(model, params, *, stride, max_context, n_requests=6):
    """Mixed-length request stream through `serve`; returns (tokens/s,
    serve-chunk executable count)."""
    eng = ServingEngine(model, params, EngineConfig(
        max_context=max_context, hbm_fraction=0.25, policy="importance",
        attention_sparsity=0.0, spec=GH200, promote_thresh=1e-4,
        telemetry_stride=stride))
    rng = np.random.default_rng(0)
    def mk():
        return [Request(rid=i,
                        prompt=rng.integers(0, model.cfg.vocab,
                                            (32 + 16 * (i % 3),)),
                        max_new_tokens=stride // 2 + 4 * (i % 3))
                for i in range(n_requests)]
    eng.serve(mk(), num_slots=2, seed=0)                    # compile
    reqs = mk()
    t0 = time.perf_counter()
    done = eng.serve(reqs, num_slots=2, seed=1)
    total = sum(len(r.output) for r in done)
    return total / (time.perf_counter() - t0), \
        eng._serve_jit._cache_size()


def run(print_csv: bool = True, steps: int = STEPS, ci: bool = False):
    cfg = configs.get_smoke("internlm2-1.8b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    host_steps = 2 if ci else HOST_STEPS
    if ci:                     # reduced geometry for the CI smoke job
        steps = min(steps, 2 * STRIDE)

    result = {"steps": steps, "stride": STRIDE, "ci": ci, "rows": {}}
    rows = []
    for policy in ("static", "importance"):
        host_sps = _time_steps(
            _engine(model, params, policy, HostLoopEngine), host_steps)
        eager_eng = _engine(model, params, policy)
        eager_sps = _time_steps(eager_eng, steps)
        fused_eng = _engine(model, params, policy)
        fused_sps = _time_fused(fused_eng, steps)
        # zero migration-driven retraces: one executable for the eager
        # step, one per distinct scan length for the fused loop
        assert eager_eng._step_jit._cache_size() == 1, \
            eager_eng._step_jit._cache_size()
        assert fused_eng._gen_jit._cache_size() == 1, \
            fused_eng._gen_jit._cache_size()
        if ci:
            # wall-clock gate with a noise margin: shared CI runners
            # jitter single-digit percents; a real fusion regression
            # (lost scan, per-step dispatch) costs far more than 10%
            assert fused_sps >= 0.9 * eager_sps, \
                (f"fused regressed below eager: "
                 f"{fused_sps:.1f} < {eager_sps:.1f} steps/s")
        result["rows"][policy] = {
            "host_steps_per_s": host_sps,
            "eager_steps_per_s": eager_sps,
            "fused_steps_per_s": fused_sps,
            "fused_speedup_vs_host": fused_sps / host_sps,
            "fused_speedup_vs_eager": fused_sps / eager_sps,
            "eager_step_executables": eager_eng._step_jit._cache_size(),
            "fused_gen_executables": fused_eng._gen_jit._cache_size(),
        }
        for mode, sps in (("host", host_sps), ("eager", eager_sps),
                          ("fused", fused_sps)):
            rows.append((f"perf/{policy}/{mode}", 1e6 / sps, sps))
        rows.append((f"perf/{policy}/fused_vs_host", 0.0,
                     fused_sps / host_sps))

    serve_tps, serve_exes = _time_serve(
        model, params, stride=8 if ci else STRIDE,
        max_context=128 if ci else 512, n_requests=4 if ci else 6)
    assert serve_exes == 1, serve_exes     # zero retraces across stream
    result["rows"]["serve"] = {
        "tokens_per_s": serve_tps,
        "serve_chunk_executables": serve_exes,
    }
    rows.append(("perf/serve/stream", 1e6 / serve_tps, serve_tps))

    with open("BENCH_engine.json", "w") as f:
        json.dump(result, f, indent=2)
    if print_csv:
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived:.3f}")
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=STEPS)
    ap.add_argument("--ci", action="store_true",
                    help="reduced geometry + fused>=eager gate (CI smoke)")
    args = ap.parse_args()
    run(steps=args.steps, ci=args.ci)
