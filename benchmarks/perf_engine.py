"""Wall-clock decode throughput for the live serving engine.

Three drive modes over the SAME model and two-tier paged cache:

  host   — replica of the pre-fusion engine step: per-step host
           round-trips of page_table/owners/importance, nested Python
           [L, B] loops for write-slot choice and migration planning,
           and a `MigrationPlan` whose capacity varies with the step's
           promote/demote count (so `apply_migrations` recompiles for
           nearly every distinct count). This is the baseline the fused
           hot path was built to kill — kept here, not in the engine,
           so the win stays measurable PR over PR.
  eager  — `ServingEngine.step`: the whole step (vectorized control
           plane + decode + fixed-capacity migration) is ONE jitted
           call, but the host dispatches and syncs telemetry per token.
  fused  — `ServingEngine.generate`: `lax.scan` over telemetry_stride
           steps per dispatch, cache donated, one telemetry readback
           per chunk.

A fourth mode measures the headline serving API:

  serve  — `ServingEngine.serve`: a mixed-length request stream through
           the same fused chunks of MIXED prefill+decode steps (chunked
           prefill inside the loop), with per-slot active masking,
           on-device sampling, and chunk-boundary admission/reclaim.
           The stream spans >= 3 distinct page-rounded prompt lengths
           and the serve chunk must stay at ONE executable — admissions
           no longer compile per prompt length. TTFT/TPOT percentiles
           from the ServeReport land in BENCH_engine.json.

A fifth comparison isolates what chunked prefill bought:

  eager-admission — `EagerAdmissionEngine` replicates PR 2's admission
           (a blocking whole-prompt batch-1 forward per request, one
           compile per page-rounded prompt length, `insert_lane` copy).
           A long prompt of a FRESH page-rounded length admitted
           mid-stream shows the TTFT gap: the baseline stalls every
           decode lane behind the prompt forward (plus its compile);
           the chunked engine overlaps prefill slices with decode.

A sixth mode sweeps the POLICY PLANE (EXPERIMENTS.md §Policy-plane):

  policy-sweep — every registered device policy drives the same fused
           generate stream with `trace_telemetry` on; the simulator
           bridge (`repro.serving.trace_bridge`) scores each stream's
           achieved placement against the SA upper bound and the
           Belady oracle replayed on the SAME access pattern. Per
           policy: wall-clock steps/s, HBM hit fraction,
           fraction-of-SA-upper-bound, headroom vs static — plus the
           one-executable-per-policy assert (swapping policies swaps a
           traced function, never the architecture).

A seventh scores the bound where the serving traffic is
(EXPERIMENTS.md §Serve-trace):

  serve-sweep — every registered policy drives the same mixed
           continuous-batching `serve` stream with `trace_telemetry`
           on; the bridge stitches per-REQUEST traces across lane
           reuse (`collect_serve`/`attribute`) and `score_serve`
           reports the AGGREGATE stream's hit/bound fractions plus
           each request's attributed fractions — the paper's headroom
           under realistic multi-request load, not just isolated
           decode. Asserted per policy: ONE serve executable with
           capture on (telemetry adds zero retraces).

An eighth leg is the robustness smoke (EXPERIMENTS.md
§Fault-injection):

  chaos  — the SAME engine serves the same request stream clean, then
           under a seeded `FaultPlane` (tier degradation + migration
           drop + pool shrink + one poisoned lane). Asserted: serve()
           never raises, every request ends in a terminal status, the
           poisoned request ends `failed`, every fault-free request's
           tokens are BITWISE identical to its clean-run tokens, and
           the serve-chunk executable count stays at ONE across both
           runs — faults are data, not shape.

A ninth leg is the scaling surface (EXPERIMENTS.md §Mesh-sharding):

  mesh-sweep — the serve stream on host-device meshes of increasing
           size: pure data-parallel points (data=n, model=1) with lanes
           scaled to devices, plus one tensor-parallel point at the top
           count. Records wall tokens/s + TTFT/TPOT p50 per point into
           rows["mesh_sweep"]; the CI mesh leg additionally asserts ONE
           serve executable per mesh (sharding never forks the cache).
           Forced host devices share physical cores, so the curve is
           descriptive data, never a speedup gate.

A tenth leg measures what the async pipeline bought (EXPERIMENTS.md
§Async-migration):

  overlap-sweep — the contended serve-sweep stream (ctx 512 geometry,
           272/288-token prompts spilling the 16-page HBM pool, Quest
           sparsity 0.5) served inline (`overlap_migrations=False`,
           the PR 7 commit-in-step path) then overlapped (the
           double-buffered plan/commit split: step N commits the plan
           staged at N-1 concurrently with decode and plans N+1 off
           this step's read set). Records tokens/s, aggregate HBM hit
           fraction, migrated bytes, and executable counts per mode,
           plus a cost_aware + `measured_payback` leg whose
           bound_fraction is compared against the PR 5 modeled-payback
           baseline. The CI gate: overlap throughput >= 0.9x inline
           (the pipeline must never COST wall-clock; forced-host CPU
           devices can't show the real win, so the gate is a
           no-regression bound with the standard noise margin), hit
           fractions equal within +-0.01 (one step of staging lag must
           not change WHERE reads land), and ONE executable per mode.

An eleventh leg scores the stream the paper's SLOs actually see
(EXPERIMENTS.md §Workloads):

  goodput-sweep — seeded open-loop traffic from the workload plane
           (`benchmarks/workloads.py`): per policy, three
           single-pattern streams (Poisson / bursty on-off / diurnal)
           drive the SAME engine to pin ONE serve executable across
           arrival patterns (arrivals are pure data), then a mixed
           Poisson+bursty sampled stream is served with SLO-aware
           admission and scored into a goodput-under-SLO curve
           (`trace_bridge.goodput_curve`): fraction of submitted
           requests completed within per-tier targets at each target
           scale, judged on the MODELED per-request latency (Eq.
           (1)-(5) via `score_serve` request_scores — CPU wall clocks
           cannot see what placement bought, the modeled TPOT can)
           against the stream's live SA bound_fraction. Records
           rows["goodput"]; the CI gate: every request terminal, one
           executable per policy across all four streams, and
           importance mean goodput over the curve >= static at equal
           targets (the TPOT target is derived once from the static
           stream's modeled median, so both policies face the same
           contract).

Writes BENCH_engine.json (see EXPERIMENTS.md §Perf-suite; the file is
stamped with `schema_version` + the producing `commit` so trajectory
tooling can parse it). The headline is fused/host steps-per-second;
fused executable counts are asserted to stay at one compile per scan
length (zero migration-driven or admission-driven retraces).

Run:  PYTHONPATH=src python benchmarks/perf_engine.py
      PYTHONPATH=src python benchmarks/perf_engine.py --policy-sweep
      (generate + serve policy sweeps only, full geometry)
      PYTHONPATH=src python benchmarks/perf_engine.py --overlap-sweep
      (inline vs overlapped serve only, appended into rows["overlap"])
      XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python benchmarks/perf_engine.py --mesh-sweep
      (scaling sweep only, appended into rows["mesh_sweep"])
      PYTHONPATH=src python benchmarks/perf_engine.py --goodput-sweep
      (workload-plane goodput-under-SLO curves per policy, appended
      into rows["goodput"])
CI:   PYTHONPATH=src python benchmarks/perf_engine.py --ci
      (reduced geometry; additionally asserts fused >= eager steps/s,
      chunked-admission TTFT < eager-admission TTFT for the mid-stream
      long prompt, one executable per device policy — serve telemetry
      included — importance hit fraction >= static in the policy
      sweep, per-policy aggregate + per-request hit/bound fractions
      present in the serve sweep, the single-request serve bridge
      bitwise equal to the generate bridge, the chaos smoke's
      graceful-degradation contract above, and the overlap gate:
      overlapped serve >= 0.9x inline tokens/s at hit fractions equal
      within +-0.01, one executable per mode)
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.sa import SAConfig
from repro.core.tiers import GH200
from repro.kvcache.migrate import MigrationPlan, apply_migrations
from repro.kvcache.paged import prefill_cache
from repro.models.model import Model
from repro.serving import control, trace_bridge
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.faults import (
    FaultPlane, MigrationFault, PoisonFault, PoolFault, TierFault,
)
from repro.serving.policies import policy_names
from repro.serving.scheduler import Request, TERMINAL_STATUSES
from repro.serving.slo import SLOPolicy

STEPS = 64          # multiple of STRIDE: scan lengths compile once in warmup
STRIDE = 32
HOST_STEPS = 8          # the host baseline is too slow for more

#: BENCH_engine.json layout version. Bump when keys move or change
#: meaning; trajectory tooling keys off this + the `commit` stamp.
#: v2: added serve_policy_sweep (aggregate + per-request fractions)
#: and the schema_version/commit provenance stamp itself.
#: v3: added the chaos smoke row (terminal-status counts, fault-event
#: count, bitwise-unaffected pin) from the fault-injection plane.
#: v4: added rows["mesh_sweep"] (`--mesh-sweep`: wall tokens/s +
#: TTFT/TPOT p50 per device count over host-device meshes, plus one
#: tensor-parallel point; EXPERIMENTS.md §Mesh-sharding).
#: v5: added rows["overlap"] (`--overlap-sweep`: inline vs overlapped
#: serve tokens/s + hit fraction + migrated bytes on the contended
#: stream, plus the cost_aware measured-payback bound_fraction vs the
#: PR 5 modeled baseline; EXPERIMENTS.md §Async-migration).
#: v6: added rows["goodput"] (`--goodput-sweep`: per-policy
#: goodput-under-SLO curves on the workload plane's seeded mixed
#: Poisson+bursty stream — modeled-latency goodput per target scale,
#: live SA bound_fraction, per-arrival-pattern terminal-status and
#: shed counts, TTFT decomposition percentiles, EOS-stop counts;
#: EXPERIMENTS.md §Workloads).
BENCH_SCHEMA_VERSION = 6

#: PR 5 serve-sweep cost_aware aggregate bound_fraction on the ci
#: stream with MODELED payback (the number measured recalibration has
#: to beat; see EXPERIMENTS.md §Async-migration).
PR5_COST_AWARE_BOUND = 0.7271


def _git_commit() -> str:
    """Best-effort producing-commit stamp for BENCH_engine.json."""
    import subprocess
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)),
            timeout=10).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def _stamp(result: dict) -> dict:
    """Stamp schema version + producing commit onto a result dict."""
    result["schema_version"] = BENCH_SCHEMA_VERSION
    result["commit"] = _git_commit()
    return result


# --------------------------------------------------------------------------- #
# seed-style host-side control plane (verbatim behavior of the old engine)
# --------------------------------------------------------------------------- #

class HostLoopEngine(ServingEngine):
    """Pre-fusion reference: host control plane, unfused data plane."""

    def step(self, token):
        from repro.serving.engine import _set_cache
        write_slot = self._host_control_plane(self._cache)
        logits, state = self.model.decode_step(
            self.params, self.state, token, write_slot=write_slot)
        self.state = state
        plan, n_pro, n_dem = self._host_plan_migrations(self._cache)
        # read traffic is priced on post-decode, PRE-migration residency,
        # matching the fused engine's accounting
        self._record_host(n_pro, n_dem)
        if plan is not None:
            self.state = _set_cache(
                self.state, apply_migrations(self._cache, plan))
        return logits

    def _host_control_plane(self, cache):
        geo = self.geo
        length = int(np.asarray(cache.length)[0])
        T = geo.page_tokens
        logical = min(length // T, geo.max_pages - 1)
        pt = np.asarray(cache.page_table)
        ho = np.asarray(cache.hbm_owner)
        eo = np.asarray(cache.host_owner)
        L, B = pt.shape[0], pt.shape[1]
        ws = np.zeros((L, B), np.int32)
        for l in range(L):
            for b in range(B):
                if pt[l, b, logical] >= 0:
                    ws[l, b] = pt[l, b, logical]
                else:
                    free_h = np.nonzero(ho[l, b] < 0)[0]
                    if len(free_h):
                        ws[l, b] = free_h[0]
                    else:
                        free_e = np.nonzero(eo[l, b] < 0)[0]
                        ws[l, b] = geo.hbm_pages + (
                            free_e[0] if len(free_e) else geo.host_pages - 1)
        return jnp.asarray(ws)

    def _host_plan_migrations(self, cache):
        imp = np.asarray(cache.importance)
        ho = np.asarray(cache.hbm_owner)
        eo = np.asarray(cache.host_owner)
        L, B = ho.shape[0], ho.shape[1]
        budget = max(1, int(self.cfg.migration_budget_frac
                            * self.geo.hbm_pages))
        promotes, demotes = [], []
        for l in range(L):
            for b in range(B):
                host_pages = np.nonzero(eo[l, b] >= 0)[0]
                if not len(host_pages):
                    continue
                host_logical = eo[l, b, host_pages]
                host_imp = imp[l, b, host_logical]
                order = np.argsort(-host_imp, kind="stable")
                hot = [(host_pages[i], host_logical[i], host_imp[i])
                       for i in order[:budget]
                       if host_imp[i] > self.cfg.promote_thresh]
                if not hot:
                    continue
                hbm_pages = np.nonzero(ho[l, b] >= 0)[0]
                hbm_logical = ho[l, b, hbm_pages]
                hbm_imp = imp[l, b, hbm_logical]
                cold_order = np.argsort(hbm_imp, kind="stable")
                free = np.nonzero(ho[l, b] < 0)[0].tolist()
                ci = 0
                for src, logical, h_imp in hot:
                    if free:
                        dst = free.pop(0)
                    elif ci < len(cold_order):
                        victim = cold_order[ci]
                        if hbm_imp[victim] >= h_imp:
                            break
                        vslot = hbm_pages[victim]
                        demotes.append((l, b, vslot, src,
                                        hbm_logical[victim]))
                        dst = vslot
                        ci += 1
                    else:
                        break
                    promotes.append((l, b, src, dst, logical))
        if not promotes and not demotes:
            return None, 0, 0
        # the step-varying capacity that forced per-step recompiles
        cap = max(len(promotes), len(demotes), 1)
        plan = MigrationPlan.build(cap, promotes, demotes)
        return plan, len(promotes), len(demotes)

    def _record_host(self, n_pro, n_dem):
        cache = self._cache
        h_pages = int(np.asarray((cache.hbm_owner >= 0).sum()))
        e_pages = int(np.asarray((cache.host_owner >= 0).sum()))
        self._record((np.asarray([[h_pages, e_pages, n_pro, n_dem]]),))


# --------------------------------------------------------------------------- #
# PR 2-style eager admission (the serialization chunked prefill removed)
# --------------------------------------------------------------------------- #

class EagerAdmissionEngine(ServingEngine):
    """Eager-admission baseline: admission prefills the WHOLE prompt on
    the spot with a batch-1 `model.forward` (compiling once per
    page-rounded prompt length), binds it via `control.insert_lane`,
    and samples the first token on host — PR 2's retired admission
    path, kept faithful here (like `HostLoopEngine`) so the chunked-
    prefill TTFT win stays measurable PR over PR."""

    def _admit_lane(self, req, hs):
        geo = self.geo
        S = req.prompt_len
        pad = (-S) % geo.page_tokens
        prompt = jnp.asarray(np.asarray(req.prompt),
                             jnp.int32).reshape(1, -1)
        if pad:
            prompt = jnp.pad(prompt, ((0, 0), (0, pad)))
        logits, (k, v) = self.model.forward(self.params, prompt,
                                            collect_kv=True)
        lane_cache = prefill_cache(dataclasses.replace(geo, batch=1),
                                   k, v, S)
        if not hasattr(self, "_insert_jit"):
            self._insert_jit = jax.jit(control.insert_lane,
                                       donate_argnums=(0,))
        lane = req.lane
        self.state = self._insert_jit(self.state, lane_cache,
                                      jnp.int32(lane))
        rkey = jax.random.fold_in(hs["root"], req.rid)
        rkey, sub = jax.random.split(rkey)
        tok0 = int(self._sampler(logits[0, S - 1][None], sub[None])[0])
        req.output.append(tok0)
        req.generated = 1
        req.prefilled = S              # device sees a decode-ready lane
        req.first_token_at = time.time()
        req.phase = "decoding"
        hs["prompt_buf"][lane, :] = 0
        hs["token"][lane] = tok0
        hs["keys"][lane] = np.array(rkey)
        done = (req.generated >= req.max_new_tokens
                or (self.cfg.eos_id is not None
                    and tok0 == self.cfg.eos_id))
        if done:
            mask = np.arange(geo.batch) == lane
            self.state = self._release_jit(self.state, jnp.asarray(mask))
            self.batcher.complete(req)     # lane -> -1: serve() skips it


# --------------------------------------------------------------------------- #

def _engine(model, params, policy, klass=ServingEngine, batch=2):
    eng = klass(model, params, EngineConfig(
        max_context=512, hbm_fraction=0.25, policy=policy,
        attention_sparsity=0.0, spec=GH200, promote_thresh=1e-4,
        telemetry_stride=STRIDE))
    rng = np.random.default_rng(0)
    # the prompt spills past the HBM pool so migrations actually fire
    prompts = jnp.asarray(rng.integers(0, model.cfg.vocab, (batch, 272)),
                          jnp.int32)
    eng.start(prompts)
    return eng


def _time_steps(eng, steps):
    tok = jnp.array([1, 2], jnp.int32)
    tok = jnp.argmax(eng.step(tok), -1).astype(jnp.int32)   # compile
    t0 = time.perf_counter()
    for _ in range(steps):
        tok = jnp.argmax(eng.step(tok), -1).astype(jnp.int32)
    jax.block_until_ready(tok)
    return steps / (time.perf_counter() - t0)


def _time_fused(eng, steps):
    eng.generate(jnp.array([1, 2], jnp.int32), STRIDE)      # compile
    tok = jnp.array([3, 4], jnp.int32)
    t0 = time.perf_counter()
    out = eng.generate(tok, steps)
    jax.block_until_ready(out)
    return steps / (time.perf_counter() - t0)


def _time_serve(model, params, *, stride, max_context, n_requests=6):
    """Mixed-length request stream through `serve`; prompts span three
    distinct page-rounded lengths (2/3/4 pages), which under eager
    admission cost three separate prefill compiles — the chunked loop
    must hold ONE serve-chunk executable across the whole stream.
    Returns (tokens/s, serve-chunk executable count, ServeReport)."""
    eng = ServingEngine(model, params, EngineConfig(
        max_context=max_context, hbm_fraction=0.25, policy="importance",
        attention_sparsity=0.0, spec=GH200, promote_thresh=1e-4,
        telemetry_stride=stride, prefill_chunk=16))
    rng = np.random.default_rng(0)
    def mk():
        return [Request(rid=i,
                        prompt=rng.integers(0, model.cfg.vocab,
                                            (32 + 16 * (i % 3),)),
                        max_new_tokens=stride // 2 + 4 * (i % 3))
                for i in range(n_requests)]
    eng.serve(mk(), num_slots=2, seed=0)                    # compile
    reqs = mk()
    t0 = time.perf_counter()
    report = eng.serve(reqs, num_slots=2, seed=1)
    total = sum(len(r.output) for r in report)
    return total / (time.perf_counter() - t0), \
        eng._serve_jit._cache_size(), report


def _ttft_long_prompt(model, params, klass, *, stride, max_context,
                      long_len):
    """TTFT of a long prompt admitted MID-STREAM behind short requests.

    The warmup stream covers the short lengths only, so the timed
    stream's long prompt arrives with a fresh page-rounded length —
    under eager admission that is a blocking compile + whole-prompt
    forward at the admission boundary; under chunked prefill it is just
    more slices through the already-compiled mixed-step executable.
    Returns the long request's TTFT in seconds."""
    eng = klass(model, params, EngineConfig(
        max_context=max_context, hbm_fraction=0.25, policy="importance",
        attention_sparsity=0.0, spec=GH200, promote_thresh=1e-4,
        telemetry_stride=stride, prefill_chunk=16))
    rng = np.random.default_rng(1)

    def mk(with_long):
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, model.cfg.vocab,
                                            (32 + 16 * (i % 2),)),
                        max_new_tokens=stride + 2)
                for i in range(4)]
        if with_long:
            reqs.append(Request(
                rid=99, prompt=rng.integers(0, model.cfg.vocab,
                                            (long_len,)),
                max_new_tokens=4))
        return reqs

    eng.serve(mk(False), num_slots=2, seed=0)               # warmup
    report = eng.serve(mk(True), num_slots=2, seed=1)
    long_req = next(r for r in report if r.rid == 99)
    assert long_req.started_step > 0, "long prompt was not mid-stream"
    return long_req.first_token_at - long_req.submitted_at


def _policy_sweep(model, params, *, steps, ci):
    """Every registered device policy over the same fused generate
    stream, scored live against the simulator bounds (see module doc).

    The stream decodes batch 1 with a prompt that spills past the HBM
    pool and Quest sparsity 0.5, so placement actually matters: the
    read set concentrates on the top-importance pages and a policy
    that promotes them converts host reads into HBM hits. Returns
    {policy: {steps_per_s, hit_fraction, bound_fraction, ...}}.
    """
    sa_cfg = SAConfig(max_evaluations=12 if ci else 40,
                      iters_per_level=4 if ci else 10, seed=0)
    # fused generate compiles once per DISTINCT chunk length; round up
    # so a ragged tail chunk can't trip the one-executable assert on a
    # legitimate --steps value
    steps = -(-steps // STRIDE) * STRIDE
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, model.cfg.vocab, (1, 272)),
                          jnp.int32)
    sweep = {}
    for name in policy_names():
        eng = ServingEngine(model, params, EngineConfig(
            max_context=512, hbm_fraction=0.25, policy=name,
            attention_sparsity=0.5, spec=GH200, promote_thresh=1e-4,
            telemetry_stride=STRIDE, trace_telemetry=True))
        eng.start(prompts)
        eng.generate(jnp.array([1], jnp.int32), STRIDE)     # compile
        eng.start(prompts)                                  # fresh stream
        t0 = time.perf_counter()
        out = eng.generate(jnp.array([1], jnp.int32), steps)
        jax.block_until_ready(out)
        sps = steps / (time.perf_counter() - t0)
        # one executable per policy: policy-state values change every
        # step, plan shapes and policy code never do
        exes = eng._gen_jit._cache_size()
        assert exes == 1, (name, exes)
        rec = trace_bridge.collect(eng)
        score = trace_bridge.score_headroom(rec, GH200, sa_cfg=sa_cfg)
        sweep[name] = {
            "steps_per_s": sps,
            "hit_fraction": score["live_hit_fraction"],
            "bound_fraction": score["bound_fraction"],
            "headroom_vs_static": score["headroom_vs_static"],
            "live_total_s": score["live_total_s"],
            "sa_total_s": score["sa_total_s"],
            "belady_total_s": score["belady_total_s"],
            "static_total_s": score["static_total_s"],
            "gen_executables": exes,
        }
    if ci:
        # the whole point of dynamic placement, gated: the deployable
        # policy must convert masked reads into HBM hits vs never
        # migrating (equality allowed — a capacity-bound degenerate
        # geometry can't be beaten)
        assert sweep["importance"]["hit_fraction"] >= \
            sweep["static"]["hit_fraction"], sweep
    return sweep


def _serve_policy_sweep(model, params, *, ci):
    """Every registered device policy over the SAME mixed
    continuous-batching serve stream, with per-request attribution
    (see module doc / EXPERIMENTS.md §Serve-trace).

    The stream's 272/288-token prompts spill past the 16-page per-lane
    HBM pool (ctx 512) and Quest sparsity concentrates the decode read
    set, so placement matters under lane churn: requests are admitted,
    complete, and hand lanes to queued successors while the capture
    runs. Returns {policy: {aggregate: {...}, requests: {rid: {...}},
    serve_executables}}.
    """
    sa_cfg = SAConfig(max_evaluations=8 if ci else 24,
                      iters_per_level=3 if ci else 8, seed=0)
    rng = np.random.default_rng(0)
    n_requests = 3 if ci else 6
    prompts = [rng.integers(0, model.cfg.vocab, (272 + 16 * (i % 2),))
               for i in range(n_requests)]

    def mk():
        return [Request(rid=i, prompt=p, max_new_tokens=6 + 2 * (i % 2))
                for i, p in enumerate(prompts)]

    sweep = {}
    for name in policy_names():
        eng = ServingEngine(model, params, EngineConfig(
            max_context=512, hbm_fraction=0.25, policy=name,
            attention_sparsity=0.5, spec=GH200, promote_thresh=1e-4,
            telemetry_stride=8, prefill_chunk=16,
            trace_telemetry=True))
        report = eng.serve(mk(), num_slots=2, seed=0)
        # serve telemetry adds ZERO retraces: one mixed-step executable
        # per policy, capture on, across admission/reclaim/lane reuse
        exes = eng._serve_jit._cache_size()
        assert exes == 1, (name, exes)
        rec = trace_bridge.collect_serve(eng)
        score = trace_bridge.score_serve(rec, GH200, sa_cfg=sa_cfg,
                                         report=report)
        sweep[name] = {
            "aggregate": score["aggregate"],
            "requests": {str(rid): sc
                         for rid, sc in score["requests"].items()},
            "serve_executables": exes,
        }
        if ci:
            agg = score["aggregate"]
            assert agg["live_total_s"] > 0 and "bound_fraction" in agg, \
                (name, agg)
            assert len(score["requests"]) == n_requests, (name, score)
            for sc in score["requests"].values():
                assert {"hit_fraction", "bound_fraction"} <= set(sc), sc
    return sweep


def _overlap_sweep(model, params, *, ci):
    """Inline vs overlapped serve on the contended mixed stream
    (module doc leg ten / EXPERIMENTS.md §Async-migration).

    Same stream shape as `_serve_policy_sweep`: 272/288-token prompts
    spill the 16-page per-lane HBM pool (ctx 512) and Quest sparsity
    0.5 concentrates the decode read set, so the pipeline actually
    stages, revalidates, and commits plans while decode runs. The
    importance policy drives both modes; a third leg reruns cost_aware
    with `measured_payback` to price promotion paybacks off the
    measured link instead of the modeled one.

    CI gates: overlapped tokens/s >= 0.9x inline (the split must never
    COST wall-clock; CPU host devices serialize the copy with compute,
    so the real overlap win is not measurable here and the gate is a
    no-regression bound), hit fractions equal within +-0.01 (one step
    of staging lag must not change where reads land), one executable
    per mode, and the measured-payback cost_aware bound_fraction at
    least the PR 5 modeled baseline.
    """
    sa_cfg = SAConfig(max_evaluations=8 if ci else 24,
                      iters_per_level=3 if ci else 8, seed=0)
    rng = np.random.default_rng(0)
    n_requests = 3 if ci else 4
    prompts = [rng.integers(0, model.cfg.vocab, (272 + 16 * (i % 2),))
               for i in range(n_requests)]

    # decodes are LONG (~50 steps) on purpose: the pipeline's one step
    # of staging lag costs one extra host-read step per promotion, a
    # transient that the +-0.01 hit-fraction gate can only absorb once
    # the steady state dominates the stream
    def mk():
        return [Request(rid=i, prompt=p,
                        max_new_tokens=48 + 4 * (i % 2))
                for i, p in enumerate(prompts)]

    def run_mode(policy, overlap, measured=False):
        eng = ServingEngine(model, params, EngineConfig(
            max_context=512, hbm_fraction=0.25, policy=policy,
            attention_sparsity=0.5, spec=GH200, promote_thresh=1e-4,
            telemetry_stride=8, prefill_chunk=16, trace_telemetry=True,
            overlap_migrations=overlap, measured_payback=measured))
        eng.serve(mk(), num_slots=2, seed=0)                # compile
        t0 = time.perf_counter()
        report = eng.serve(mk(), num_slots=2, seed=0)
        wall = time.perf_counter() - t0
        exes = eng._serve_jit._cache_size()
        assert exes == 1, (policy, overlap, exes)
        rec = trace_bridge.collect_serve(eng)
        score = trace_bridge.score_serve(rec, GH200, sa_cfg=sa_cfg,
                                         report=report)
        agg = score["aggregate"]
        total = sum(len(r.output) for r in report)
        row = {
            "tokens_per_s": total / wall,
            "hit_fraction": agg["live_hit_fraction"],
            "bound_fraction": agg["bound_fraction"],
            "migrated_bytes": int(sum(s.m_in + s.m_out
                                      for s in eng.stats)),
            "serve_chunk_executables": exes,
        }
        if measured:
            row["payback_events"] = [
                e for e in report.events
                if e["kind"] == "payback_measured"]
        return row

    sweep = {
        "inline": run_mode("importance", overlap=False),
        "overlap": run_mode("importance", overlap=True),
        "cost_aware_measured": run_mode("cost_aware", overlap=True,
                                        measured=True),
        "pr5_cost_aware_bound_baseline": PR5_COST_AWARE_BOUND,
    }
    if ci:
        inline, over = sweep["inline"], sweep["overlap"]
        assert over["tokens_per_s"] >= 0.9 * inline["tokens_per_s"], \
            (f"overlap regressed below inline: "
             f"{over['tokens_per_s']:.1f} < {inline['tokens_per_s']:.1f}"
             f" tokens/s")
        assert abs(over["hit_fraction"] - inline["hit_fraction"]) \
            <= 0.01, (over["hit_fraction"], inline["hit_fraction"])
        # one step of lag + hazard masking loses at most a trickle of
        # commits; the pipeline must still MOVE pages
        assert over["migrated_bytes"] > 0, over
        ca = sweep["cost_aware_measured"]
        assert ca["payback_events"], "measured payback never measured"
        assert ca["bound_fraction"] >= PR5_COST_AWARE_BOUND, \
            (ca["bound_fraction"], PR5_COST_AWARE_BOUND)
    return sweep


def _assert_serve_bridge_matches_generate(model, params):
    """CI pin: a single-request serve stream's stitched trace is
    BITWISE the generate bridge's record (same access pattern, same
    read-time placement, same prompt arithmetic) — the serve capture
    is the same instrument pointed at the same program."""
    rng = np.random.default_rng(11)
    S, n = 32, 7
    prompt = rng.integers(0, model.cfg.vocab, (S,))
    cfg = EngineConfig(max_context=128, hbm_fraction=0.25,
                       policy="importance", attention_sparsity=0.0,
                       spec=GH200, promote_thresh=1e-4,
                       telemetry_stride=4, prefill_chunk=16,
                       trace_telemetry=True)
    ref = ServingEngine(model, params, cfg)
    logits0 = ref.start(jnp.asarray(prompt[None], jnp.int32))
    tok0 = jnp.argmax(logits0, -1).astype(jnp.int32)
    ref.generate(tok0, n - 1)
    grec = trace_bridge.collect(ref)

    eng = ServingEngine(model, params, cfg)
    eng.serve([Request(rid=0, prompt=prompt, max_new_tokens=n)],
              num_slots=1)
    atts = trace_bridge.attribute(trace_bridge.collect_serve(eng))
    rec = atts[0].record
    assert np.array_equal(rec.access, grec.access)
    assert np.array_equal(rec.tier, grec.tier)
    assert rec.prompt_len == grec.prompt_len


def _chaos_smoke(model, params):
    """Graceful-degradation smoke (module doc leg eight): same engine,
    same stream, clean then under a seeded four-kind fault schedule.
    Returns the BENCH row; raises AssertionError on any contract break.
    """
    eng = ServingEngine(model, params, EngineConfig(
        max_context=128, hbm_fraction=0.25, policy="cost_aware",
        attention_sparsity=0.0, spec=GH200, promote_thresh=1e-4,
        telemetry_stride=8, prefill_chunk=16))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, model.cfg.vocab, (24 + 8 * (i % 3),))
               for i in range(4)]

    def mk():
        return [Request(rid=i, prompt=p, max_new_tokens=10)
                for i, p in enumerate(prompts)]

    clean = eng.serve(mk(), num_slots=2, seed=0)
    assert all(r.status == "ok" for r in clean), clean.statuses
    clean_out = {r.rid: list(r.output) for r in clean}

    plane = FaultPlane(
        tier=(TierFault(start=4, stop=20, link_scale=0.05),),
        migration=(MigrationFault(start=0, stop=12, commit_frac=0.0),),
        pool=(PoolFault(step=16, delta=-2), PoolFault(step=32, delta=2)),
        poison=(PoisonFault(rid=1, step=6),))
    report = eng.serve(mk(), num_slots=2, seed=0, faults=plane)

    statuses = report.statuses
    assert set(statuses) == set(clean_out), statuses
    assert all(s in TERMINAL_STATUSES for s in statuses.values()), \
        statuses
    assert statuses[1] == "failed", statuses
    for r in report:
        if r.rid != 1:       # fault-free lanes: bitwise identical
            assert r.status == "ok" and list(r.output) == \
                clean_out[r.rid], (r.rid, r.status)
    # faults are data, not shape: clean + faulted share ONE executable
    exes = eng._serve_jit._cache_size()
    assert exes == 1, exes
    assert report.events, "fault schedule produced no telemetry events"
    n_ok = sum(1 for s in statuses.values() if s == "ok")
    return {
        "statuses": {str(k): v for k, v in sorted(statuses.items())},
        "ok_requests": n_ok,
        "failed_requests": len(statuses) - n_ok,
        "fault_events": len(report.events),
        "serve_chunk_executables": exes,
    }


def _mesh_point(model, params, mesh, *, num_slots, ci):
    """One scaling point: the mixed serve stream on `mesh` (None = the
    single-device baseline). Returns the BENCH row for this point."""
    stride = 8
    eng = ServingEngine(model, params, EngineConfig(
        max_context=128, hbm_fraction=0.25, policy="importance",
        attention_sparsity=0.0, spec=GH200, promote_thresh=1e-4,
        telemetry_stride=stride, prefill_chunk=16), mesh=mesh)
    rng = np.random.default_rng(0)
    n_requests = 2 * num_slots if ci else 3 * num_slots

    def mk():
        return [Request(rid=i,
                        prompt=rng.integers(0, model.cfg.vocab,
                                            (32 + 16 * (i % 3),)),
                        max_new_tokens=stride // 2 + 2 * (i % 3))
                for i in range(n_requests)]

    eng.serve(mk(), num_slots=num_slots, seed=0)            # compile
    reqs = mk()
    t0 = time.perf_counter()
    report = eng.serve(reqs, num_slots=num_slots, seed=1)
    wall = time.perf_counter() - t0
    exes = eng._serve_jit._cache_size()
    if ci:
        # the scaling gate is STRUCTURAL, not a speedup assertion:
        # forced host devices share the same physical cores, so the
        # curve's shape is honest data, not a pass/fail criterion
        assert exes == 1, (mesh, exes)
        assert all(s == "ok" for s in report.statuses.values()), \
            report.statuses
    total = sum(len(r.output) for r in report)
    return {
        "devices": 1 if mesh is None else mesh.devices.size,
        "mesh": None if mesh is None else dict(mesh.shape),
        "num_slots": num_slots,
        "requests": n_requests,
        "wall_tokens_per_s": total / wall,
        "ttft_p50_s": report.ttft.get("p50"),
        "tpot_p50_s": report.tpot.get("p50"),
        "serve_chunk_executables": exes,
    }


def _mesh_sweep(model, params, *, ci):
    """tokens/s + TTFT/TPOT vs device count over host-device meshes.

    Sweeps pure data-parallel meshes (data=n, model=1) for every
    available power-of-two device count (lanes scale with devices so
    per-device work is constant), plus one tensor-parallel point
    (data=n/2, model=2) at the largest count — the kv_heads/pages
    sharding path. On a 1-device host this degenerates to the baseline
    point, so `--mesh-sweep` runs anywhere; the CI mesh leg forces 8
    host devices for the real curve."""
    from repro.launch.mesh import make_test_mesh
    counts = [n for n in (1, 2, 4, 8) if n <= jax.device_count()]
    points = {}
    for n in counts:
        mesh = None if n == 1 else make_test_mesh(data=n, model=1)
        points[f"{n}x1"] = _mesh_point(model, params, mesh,
                                       num_slots=2 * n, ci=ci)
    top = max(counts)
    if top >= 4:
        points[f"{top // 2}x2"] = _mesh_point(
            model, params, make_test_mesh(data=top // 2, model=2),
            num_slots=top, ci=ci)
    return {"devices_available": jax.device_count(), "points": points}


def run_mesh_sweep(print_csv: bool = True, ci: bool = False):
    """Standalone `--mesh-sweep`: the scaling curve only, appended into
    an existing BENCH_engine.json when present (the CI mesh leg runs
    this under --xla_force_host_platform_device_count=8 and uploads the
    merged artifact)."""
    cfg = configs.get_smoke("internlm2-1.8b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    sweep = _mesh_sweep(model, params, ci=ci)
    try:
        with open("BENCH_engine.json") as f:
            result = json.load(f)
    except (OSError, ValueError):
        result = {"rows": {}}
    result.setdefault("rows", {})["mesh_sweep"] = sweep
    with open("BENCH_engine.json", "w") as f:
        json.dump(_stamp(result), f, indent=2)
    if print_csv:
        for label, row in sweep["points"].items():
            print(f"mesh/{label}/wall_tokens_per_s,"
                  f"{1e6 / row['wall_tokens_per_s']:.3f},"
                  f"{row['wall_tokens_per_s']:.3f}")
            if row["ttft_p50_s"] is not None:
                print(f"mesh/{label}/ttft_p50,"
                      f"{row['ttft_p50_s'] * 1e6:.3f},"
                      f"{row['ttft_p50_s']:.6f}")
    return sweep


def _goodput_sweep(model, params, *, ci):
    """Workload-plane goodput leg (module doc leg eleven /
    EXPERIMENTS.md §Workloads).

    Traffic comes from `benchmarks/workloads.py`: seeded heavy-tailed
    prompts around the contended 272-token band (spilling the 16-page
    per-lane HBM pool at ctx 512, Quest sparsity 0.5 — the geometry
    where placement matters), priority tiers, and sampled
    (temperature 0.7) decoding that stops on the model's real
    `eos_id`. Per policy: the three single-pattern open-loop streams
    (Poisson / bursty / diurnal) run through the SAME engine first —
    arrivals are pure data, so the serve executable count must stay at
    ONE across all of them — then the mixed Poisson+bursty stream is
    served with SLO-aware admission at a compressed arrival clock
    (every arrival lands before the first chunk completes, making
    admission order and the scored traces deterministic across hosts
    while the open-loop driver still runs) and scored into the
    goodput-under-SLO curve on MODELED per-request latency. The TPOT
    target is the static stream's modeled median, so both policies
    face the same contract and scale 1.0 sits exactly at static's
    half-good point.
    """
    import workloads as wl

    sa_cfg = SAConfig(max_evaluations=8 if ci else 24,
                      iters_per_level=3 if ci else 8, seed=0)
    n_pat = 3 if ci else 5
    n_mixed = 6 if ci else 12
    base = dict(rate_rps=8.0, len_mu=5.6, len_sigma=0.08,
                zipf_frac=0.1, min_prompt=192, max_prompt=288,
                page_tokens=16, snap_frac=0.5, out_mu=2.0,
                out_sigma=0.4, max_new=10, vocab=model.cfg.vocab,
                temperature=0.7)
    scales = (0.25, 0.5, 1.0, 2.0, 4.0, 8.0)
    patterns = ("poisson", "bursty", "diurnal")
    # live-admission contract: generous wall targets (tight targets
    # are exercised by tests/test_slo.py; the bench streams should
    # complete, so shed counts here are descriptive, normally zero)
    admission = SLOPolicy.uniform(ttft_s=300.0, tpot_s=60.0)

    def mk_engine(policy):
        return ServingEngine(model, params, EngineConfig(
            max_context=512, hbm_fraction=0.25, policy=policy,
            attention_sparsity=0.5, spec=GH200, promote_thresh=1e-4,
            telemetry_stride=8, prefill_chunk=16, prefill_budget=24,
            eos_id=model.cfg.eos_id, trace_telemetry=True))

    sweep = {"patterns": list(patterns), "scales": list(scales),
             "latency": "modeled", "policies": {}}
    tpot_target = None
    for policy in ("static", "importance"):
        eng = mk_engine(policy)
        pat_rows = {}
        for i, pat in enumerate(patterns):
            stream = wl.generate(wl.WorkloadSpec(
                seed=11 + i, n_requests=n_pat, arrival=pat, **base))
            rep = wl.drive(eng, stream, num_slots=2, slo=admission)
            statuses = list(rep.statuses.values())
            assert all(s in TERMINAL_STATUSES for s in statuses), rep
            pat_rows[pat] = {
                "requests": len(statuses),
                "ok": statuses.count("ok"),
                "shed": sum(1 for r in rep.rejected
                            if r.error is not None
                            and r.error.code == "slo_shed"),
                "eos_stops": rep.eos.get("eos_stops", 0),
            }
        mixed = wl.mixed_stream(101, n_mixed, **base)
        rep = wl.drive(eng, mixed, num_slots=2, slo=admission,
                       time_scale=1e-3)
        assert all(s in TERMINAL_STATUSES
                   for s in rep.statuses.values()), rep
        execs = int(eng._serve_jit._cache_size())
        rec = trace_bridge.collect_serve(eng)
        if tpot_target is None:
            scored = trace_bridge.score_serve(rec, GH200,
                                              sa_cfg=sa_cfg)
            tpots = sorted(sc["live_total_s"] / sc["steps"]
                           for sc in scored["requests"].values()
                           if sc["steps"])
            tpot_target = float(tpots[len(tpots) // 2])
        contract = SLOPolicy.uniform(ttft_s=300.0, tpot_s=tpot_target)
        out = trace_bridge.goodput_curve(rec, GH200, rep, contract,
                                         scales=scales, sa_cfg=sa_cfg)
        curve = out["curve"]
        sweep["policies"][policy] = {
            "curve": curve,
            "mean_goodput": float(np.mean([c["goodput"]
                                           for c in curve])),
            "bound_fraction": out["aggregate"].get("bound_fraction"),
            "live_hit_fraction": out["aggregate"]["live_hit_fraction"],
            "serve_executables": execs,
            "ttft_parts": rep.ttft_parts,
            "eos": rep.eos,
            "arrival_patterns": pat_rows,
        }
    sweep["tpot_target_s"] = tpot_target
    if ci:
        for policy, row in sweep["policies"].items():
            # one executable across poisson + bursty + diurnal + mixed:
            # arrival patterns are data, never shapes
            assert row["serve_executables"] == 1, \
                (policy, row["serve_executables"])
        st = sweep["policies"]["static"]["mean_goodput"]
        imp = sweep["policies"]["importance"]["mean_goodput"]
        # the deployable policy converts placement headroom into
        # goodput at equal targets (equality allowed: a degenerate
        # geometry no policy can beat)
        assert imp >= st, (imp, st)
    return sweep


def run_goodput_sweep(print_csv: bool = True, ci: bool = False):
    """Standalone `--goodput-sweep`: the workload-plane goodput leg
    only, appended into an existing BENCH_engine.json when present
    (the CI bench-smoke goodput step runs this and uploads the merged
    artifact)."""
    cfg = configs.get_smoke("internlm2-1.8b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    sweep = _goodput_sweep(model, params, ci=ci)
    try:
        with open("BENCH_engine.json") as f:
            result = json.load(f)
    except (OSError, ValueError):
        result = {"rows": {}}
    result.setdefault("rows", {})["goodput"] = sweep
    with open("BENCH_engine.json", "w") as f:
        json.dump(_stamp(result), f, indent=2)
    if print_csv:
        for policy, row in sweep["policies"].items():
            print(f"goodput/{policy}/mean_goodput,0.000,"
                  f"{row['mean_goodput']:.3f}")
            bf = row["bound_fraction"]
            if bf is not None:
                print(f"goodput/{policy}/bound_fraction,0.000,"
                      f"{bf:.3f}")
    return sweep


def run(print_csv: bool = True, steps: int = STEPS, ci: bool = False):
    cfg = configs.get_smoke("internlm2-1.8b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    host_steps = 2 if ci else HOST_STEPS
    if ci:                     # reduced geometry for the CI smoke job
        steps = min(steps, 2 * STRIDE)

    result = {"steps": steps, "stride": STRIDE, "ci": ci, "rows": {}}
    # rows produced only by the standalone --mesh-sweep/--goodput-sweep
    # legs survive a default rerun, so the committed artifact keeps its
    # scaling curve and goodput curves
    try:
        with open("BENCH_engine.json") as f:
            prior = json.load(f).get("rows", {})
        for standalone in ("mesh_sweep", "goodput"):
            if standalone in prior:
                result["rows"][standalone] = prior[standalone]
    except (OSError, ValueError):
        pass
    rows = []
    for policy in ("static", "importance"):
        host_sps = _time_steps(
            _engine(model, params, policy, HostLoopEngine), host_steps)
        eager_eng = _engine(model, params, policy)
        eager_sps = _time_steps(eager_eng, steps)
        fused_eng = _engine(model, params, policy)
        fused_sps = _time_fused(fused_eng, steps)
        # zero migration-driven retraces: one executable for the eager
        # step, one per distinct scan length for the fused loop
        assert eager_eng._step_jit._cache_size() == 1, \
            eager_eng._step_jit._cache_size()
        assert fused_eng._gen_jit._cache_size() == 1, \
            fused_eng._gen_jit._cache_size()
        if ci:
            # wall-clock gate with a noise margin: shared CI runners
            # jitter single-digit percents; a real fusion regression
            # (lost scan, per-step dispatch) costs far more than 10%
            assert fused_sps >= 0.9 * eager_sps, \
                (f"fused regressed below eager: "
                 f"{fused_sps:.1f} < {eager_sps:.1f} steps/s")
        result["rows"][policy] = {
            "host_steps_per_s": host_sps,
            "eager_steps_per_s": eager_sps,
            "fused_steps_per_s": fused_sps,
            "fused_speedup_vs_host": fused_sps / host_sps,
            "fused_speedup_vs_eager": fused_sps / eager_sps,
            "eager_step_executables": eager_eng._step_jit._cache_size(),
            "fused_gen_executables": fused_eng._gen_jit._cache_size(),
        }
        for mode, sps in (("host", host_sps), ("eager", eager_sps),
                          ("fused", fused_sps)):
            rows.append((f"perf/{policy}/{mode}", 1e6 / sps, sps))
        rows.append((f"perf/{policy}/fused_vs_host", 0.0,
                     fused_sps / host_sps))

    serve_stride = 8 if ci else STRIDE
    serve_ctx = 128 if ci else 512
    serve_tps, serve_exes, report = _time_serve(
        model, params, stride=serve_stride, max_context=serve_ctx,
        n_requests=4 if ci else 6)
    # zero retraces across a stream spanning >= 3 page-rounded prompt
    # lengths: ONE mixed prefill+decode executable, admissions included
    assert serve_exes == 1, serve_exes
    ttft_chunked = _ttft_long_prompt(
        model, params, ServingEngine, stride=serve_stride,
        max_context=serve_ctx, long_len=96)
    ttft_eager = _ttft_long_prompt(
        model, params, EagerAdmissionEngine, stride=serve_stride,
        max_context=serve_ctx, long_len=96)
    if ci:
        # the fresh-length admission compile + blocking forward makes
        # this a wide margin; a chunked-prefill regression (per-length
        # retrace, serialized admission) would erase it
        assert ttft_chunked < ttft_eager, (ttft_chunked, ttft_eager)
    result["rows"]["serve"] = {
        "tokens_per_s": serve_tps,
        "serve_chunk_executables": serve_exes,
        "ttft_s": report.ttft,
        "tpot_s": report.tpot,
        "ttft_long_midstream_chunked_s": ttft_chunked,
        "ttft_long_midstream_eager_s": ttft_eager,
    }
    rows.append(("perf/serve/stream", 1e6 / serve_tps, serve_tps))
    if report.ttft:
        rows.append(("perf/serve/ttft_p50", report.ttft["p50"] * 1e6,
                     report.ttft["p50"]))
        rows.append(("perf/serve/ttft_p95", report.ttft["p95"] * 1e6,
                     report.ttft["p95"]))
    if report.tpot:
        rows.append(("perf/serve/tpot_p50", report.tpot["p50"] * 1e6,
                     report.tpot["p50"]))
        rows.append(("perf/serve/tpot_p95", report.tpot["p95"] * 1e6,
                     report.tpot["p95"]))
    rows.append(("perf/serve/ttft_long_chunked", ttft_chunked * 1e6,
                 ttft_chunked))
    rows.append(("perf/serve/ttft_long_eager", ttft_eager * 1e6,
                 ttft_eager))

    sweep = _policy_sweep(model, params, steps=2 * STRIDE if ci else steps,
                          ci=ci)
    result["rows"]["policy_sweep"] = sweep
    for name, row in sweep.items():
        rows.append((f"policy/{name}/steps_per_s",
                     1e6 / row["steps_per_s"], row["steps_per_s"]))
        rows.append((f"policy/{name}/hit_fraction", 0.0,
                     row["hit_fraction"]))
        rows.append((f"policy/{name}/bound_fraction", 0.0,
                     row["bound_fraction"]))

    if ci:
        _assert_serve_bridge_matches_generate(model, params)
    chaos = _chaos_smoke(model, params)
    result["rows"]["chaos"] = chaos
    rows.append(("chaos/ok_requests", 0.0, chaos["ok_requests"]))
    rows.append(("chaos/failed_requests", 0.0,
                 chaos["failed_requests"]))
    rows.append(("chaos/fault_events", 0.0, chaos["fault_events"]))
    serve_sweep = _serve_policy_sweep(model, params, ci=ci)
    result["rows"]["serve_policy_sweep"] = serve_sweep
    for name, row in serve_sweep.items():
        agg = row["aggregate"]
        rows.append((f"serve_policy/{name}/hit_fraction", 0.0,
                     agg["live_hit_fraction"]))
        rows.append((f"serve_policy/{name}/bound_fraction", 0.0,
                     agg.get("bound_fraction", 0.0)))
    overlap = _overlap_sweep(model, params, ci=ci)
    result["rows"]["overlap"] = overlap
    for mode in ("inline", "overlap", "cost_aware_measured"):
        row = overlap[mode]
        rows.append((f"overlap/{mode}/tokens_per_s",
                     1e6 / row["tokens_per_s"], row["tokens_per_s"]))
        rows.append((f"overlap/{mode}/hit_fraction", 0.0,
                     row["hit_fraction"]))
    rows.append(("overlap/cost_aware_measured/bound_fraction", 0.0,
                 overlap["cost_aware_measured"]["bound_fraction"]))

    with open("BENCH_engine.json", "w") as f:
        json.dump(_stamp(result), f, indent=2)
    if print_csv:
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived:.3f}")
    return result


def run_overlap_sweep(print_csv: bool = True, ci: bool = False):
    """Standalone `--overlap-sweep`: the inline-vs-overlap comparison
    only, appended into an existing BENCH_engine.json when present."""
    cfg = configs.get_smoke("internlm2-1.8b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    sweep = _overlap_sweep(model, params, ci=ci)
    try:
        with open("BENCH_engine.json") as f:
            result = json.load(f)
    except (OSError, ValueError):
        result = {"rows": {}}
    result.setdefault("rows", {})["overlap"] = sweep
    with open("BENCH_engine.json", "w") as f:
        json.dump(_stamp(result), f, indent=2)
    if print_csv:
        for mode in ("inline", "overlap", "cost_aware_measured"):
            row = sweep[mode]
            print(f"overlap/{mode}/tokens_per_s,"
                  f"{1e6 / row['tokens_per_s']:.3f},"
                  f"{row['tokens_per_s']:.3f}")
            print(f"overlap/{mode}/hit_fraction,0.000,"
                  f"{row['hit_fraction']:.3f}")
            print(f"overlap/{mode}/migrated_bytes,0.000,"
                  f"{row['migrated_bytes']}")
        print(f"overlap/cost_aware_measured/bound_fraction,0.000,"
              f"{sweep['cost_aware_measured']['bound_fraction']:.4f}")
    return sweep


def run_policy_sweep(print_csv: bool = True, steps: int = STEPS):
    """Standalone `--policy-sweep`: the policy plane only — generate
    streams AND the serve-stream sweep with per-request attribution —
    full geometry, appended into an existing BENCH_engine.json when
    present."""
    cfg = configs.get_smoke("internlm2-1.8b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    sweep = _policy_sweep(model, params, steps=steps, ci=False)
    serve_sweep = _serve_policy_sweep(model, params, ci=False)
    try:
        with open("BENCH_engine.json") as f:
            result = json.load(f)
    except (OSError, ValueError):
        result = {"rows": {}}
    result.setdefault("rows", {})["policy_sweep"] = sweep
    result["rows"]["serve_policy_sweep"] = serve_sweep
    with open("BENCH_engine.json", "w") as f:
        json.dump(_stamp(result), f, indent=2)
    if print_csv:
        for name, row in sweep.items():
            print(f"policy/{name}/steps_per_s,"
                  f"{1e6 / row['steps_per_s']:.3f},"
                  f"{row['steps_per_s']:.3f}")
            print(f"policy/{name}/hit_fraction,0.000,"
                  f"{row['hit_fraction']:.3f}")
            print(f"policy/{name}/bound_fraction,0.000,"
                  f"{row['bound_fraction']:.3f}")
        for name, row in serve_sweep.items():
            agg = row["aggregate"]
            print(f"serve_policy/{name}/hit_fraction,0.000,"
                  f"{agg['live_hit_fraction']:.3f}")
            print(f"serve_policy/{name}/bound_fraction,0.000,"
                  f"{agg.get('bound_fraction', 0.0):.3f}")
    return sweep, serve_sweep


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=STEPS)
    ap.add_argument("--ci", action="store_true",
                    help="reduced geometry + fused>=eager + policy-sweep "
                         "+ chaos graceful-degradation gates (CI smoke)")
    ap.add_argument("--policy-sweep", action="store_true",
                    help="run only the device-policy sweep (steps/s, hit "
                         "fraction, fraction-of-SA-upper-bound per policy)")
    ap.add_argument("--mesh-sweep", action="store_true",
                    help="run only the mesh scaling sweep (tokens/s + "
                         "TTFT/TPOT per device count; pair with "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=8 for the full curve)")
    ap.add_argument("--overlap-sweep", action="store_true",
                    help="run only the inline-vs-overlap serve "
                         "comparison (tokens/s, hit fraction, migrated "
                         "bytes per mode + the measured-payback "
                         "cost_aware bound fraction)")
    ap.add_argument("--goodput-sweep", action="store_true",
                    help="run only the workload-plane goodput leg "
                         "(per-policy goodput-under-SLO curves on the "
                         "seeded mixed Poisson+bursty stream, one "
                         "executable across arrival patterns)")
    args = ap.parse_args()
    if args.goodput_sweep:
        run_goodput_sweep(ci=args.ci)
    elif args.overlap_sweep:
        run_overlap_sweep(ci=args.ci)
    elif args.mesh_sweep:
        run_mesh_sweep(ci=args.ci)
    elif args.policy_sweep:
        run_policy_sweep(steps=args.steps)
    else:
        run(steps=args.steps, ci=args.ci)
