"""Quickstart: reproduce the paper's core result in ~1 minute on CPU.

Builds a clustered attention trace (LLaMA-3.1-8B byte accounting, GH200
memory system), scores all five placement strategies from the paper
plus our two extras, and prints the speedup table. Expected output:
SA-guided several-x faster than Static, approaching Unlimited-HBM.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.experiment import Workload, run_all
from repro.core.sa import SAConfig
from repro.core.tiers import GH200
from repro.core.traces import synthetic_trace


def main():
    trace = synthetic_trace(
        prompt_len=30_000,   # ~30k-token LongBench-style prompt
        decode_len=1_000,    # decoded tokens (reduced from 10k for speed)
        sparsity=0.75,       # attention sparsity
        variation=0.3,       # token-importance drift
        seed=0)
    wl = Workload.llama31_8b()
    budget = 0.25 * (trace.prompt_len + trace.decode_len) \
        * wl.bytes_per_token_layer * wl.num_layers

    print(f"trace: {trace.num_pages} KV pages, {trace.num_steps} decode "
          f"steps, realized sparsity {trace.sparsity:.2f}")
    print(f"HBM KV budget: {budget / 1e9:.2f} GB "
          f"({0.25:.0%} of total KV)\n")

    results = run_all(
        trace, GH200, wl, budget,
        strategies=("unlimited", "static", "reactive", "quest", "sa",
                    "belady", "cost_aware"),
        sa_cfg=SAConfig(max_evaluations=80, seed=0))

    static = results["static"]
    print(f"{'strategy':24s} {'tokens/s':>10s} {'vs static':>10s} "
          f"{'HBM hit':>8s} {'migrated':>10s}")
    for name, r in results.items():
        print(f"{r.policy:24s} {r.tokens_per_s:10.1f} "
              f"{static.total_latency_s / r.total_latency_s:9.2f}x "
              f"{r.hbm_hit_rate:8.2f} {r.migrated_bytes / 1e9:8.1f}GB")

    sa = results["sa"]
    print(f"\nSA-guided upper bound: "
          f"{static.total_latency_s / sa.total_latency_s:.2f}x static "
          f"(paper: 4-5x typical, up to 5.87x)")


if __name__ == "__main__":
    main()
