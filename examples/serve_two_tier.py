"""End-to-end serving driver: batched requests through the two-tier
paged KV cache with dynamic placement — the paper's technique live.

Pipeline: train a small model briefly (so generations aren't pure
noise) -> prefill a batch of prompts -> decode with (a) static
placement and (b) importance-EMA placement + Quest-style sparsity,
comparing modeled throughput under the Eq.(1)-(5) cost model, plus the
continuous batcher admitting a stream of requests.

Run:  PYTHONPATH=src python examples/serve_two_tier.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.tiers import GH200
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models.model import Model
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.scheduler import ContinuousBatcher, Request
from repro.training.train_step import init_train_state, make_train_step


def main():
    cfg = configs.get_smoke("internlm2-1.8b")
    model = Model(cfg)

    # --- brief training so the model has actual structure ----------------
    state = init_train_state(model, jax.random.key(0))
    step = jax.jit(make_train_step(model, lr=5e-3))
    corpus = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=64,
                                        global_batch=8))
    for i in range(30):
        state, metrics = step(state, {"tokens": jnp.asarray(
            corpus.batch(0, i)["tokens"])})
    print(f"trained 30 steps, loss {float(metrics['loss']):.3f}")

    # --- serve with both placement policies ------------------------------
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(corpus.batch(0, 99)["tokens"][:4, :64])
    for policy, sparsity in (("static", 0.6), ("importance", 0.6)):
        eng = ServingEngine(model, state.params, EngineConfig(
            max_context=256, hbm_fraction=0.25, policy=policy,
            attention_sparsity=sparsity, spec=GH200,
            promote_thresh=0.005))
        eng.start(prompts)
        # fused hot path: one lax.scan dispatch per telemetry_stride steps
        tok = jnp.argmax(eng.step(prompts[:, -1]), -1).astype(jnp.int32)
        generated = eng.generate(tok, 31)
        s = eng.summary()
        print(f"policy={policy:11s} modeled {s['modeled_tokens_per_s']:12.0f}"
              f" tok/s  hit={s['mean_hbm_hit_rate']:.2f}"
              f"  migrated={s['migrated_bytes'] / 1e6:.1f}MB")

    # --- continuous batching over a request stream -----------------------
    cb = ContinuousBatcher(num_slots=4, total_pages=64)
    for rid in range(10):
        cb.submit(Request(rid=rid, prompt_len=48,
                          max_new_tokens=8 + 4 * (rid % 3)))
    steps = 0
    while len(cb.completed) < 10 and steps < 200:
        cb.step()
        steps += 1
    waits = [r.started_step - r.arrived_step for r in cb.completed]
    print(f"continuous batching: 10 requests in {steps} steps, "
          f"mean admission wait {np.mean(waits):.1f} steps, "
          f"final page pressure {cb.page_pressure():.2f}")


if __name__ == "__main__":
    main()
