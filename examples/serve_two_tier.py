"""End-to-end serving driver: batched requests through the two-tier
paged KV cache with dynamic placement — the paper's technique live.

Pipeline: train a small model briefly (so generations aren't pure
noise) -> prefill a batch of prompts -> decode under EVERY registered
device placement policy (static / importance / recency / cost_aware /
quest) with Quest-style sparsity, scoring each against the paper's SA
upper bound via the live-telemetry simulator bridge — then
`ServingEngine.serve`: a mixed-length request stream continuously
batched through the same fused decode loop with on-device sampling
and serve-stream trace capture, so every REQUEST comes back with its
own attributed hit/bound fractions and the stream reports its
aggregate headroom (EXPERIMENTS.md §Serve-trace).

Run:  PYTHONPATH=src python examples/serve_two_tier.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.sa import SAConfig
from repro.core.tiers import GH200
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models.model import Model
from repro.serving import trace_bridge
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.policies import policy_names
from repro.serving.sampling import SamplingConfig
from repro.serving.scheduler import Request
from repro.training.train_step import init_train_state, make_train_step


def main():
    cfg = configs.get_smoke("internlm2-1.8b")
    model = Model(cfg)

    # --- brief training so the model has actual structure ----------------
    state = init_train_state(model, jax.random.key(0))
    step = jax.jit(make_train_step(model, lr=5e-3))
    corpus = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=64,
                                        global_batch=8))
    for i in range(30):
        state, metrics = step(state, {"tokens": jnp.asarray(
            corpus.batch(0, i)["tokens"])})
    print(f"trained 30 steps, loss {float(metrics['loss']):.3f}")

    # --- the policy plane: every registered device policy, scored live
    # against the SA upper bound by the telemetry bridge ------------------
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(corpus.batch(0, 99)["tokens"][:1, :64])
    sa_cfg = SAConfig(max_evaluations=16, iters_per_level=4, seed=0)
    for policy in policy_names():
        # max_context 384 -> a 16-page HBM pool + 16 host pages: the
        # 320-token stream below spills past HBM without overrunning
        # the cache
        eng = ServingEngine(model, state.params, EngineConfig(
            max_context=384, hbm_fraction=0.25, policy=policy,
            attention_sparsity=0.6, spec=GH200, promote_thresh=0.005,
            trace_telemetry=True))
        eng.start(prompts)
        # fused hot path: one lax.scan dispatch per telemetry_stride
        # steps; decode far enough that the stream spills past the
        # 16-page HBM pool and placement decisions actually bite
        tok = jnp.argmax(eng.step(prompts[:, -1]), -1).astype(jnp.int32)
        generated = eng.generate(tok, 255)
        score = trace_bridge.score_headroom(
            trace_bridge.collect(eng), GH200, sa_cfg=sa_cfg)
        s = eng.summary()
        print(f"policy={policy:11s} modeled {s['modeled_tokens_per_s']:12.0f}"
              f" tok/s  hit={score['live_hit_fraction']:.2f}"
              f"  of-SA-bound={score['bound_fraction']:.2f}"
              f"  migrated={s['migrated_bytes'] / 1e6:.1f}MB")

    # --- continuous batching: a live request stream through serve(),
    # with serve-stream trace capture + per-request attribution --------
    eng = ServingEngine(model, state.params, EngineConfig(
        max_context=256, hbm_fraction=0.25, policy="importance",
        attention_sparsity=0.5, spec=GH200, promote_thresh=0.005,
        telemetry_stride=8, trace_telemetry=True))
    # 272-304-token prompts spill past the 16-page (256-token) per-lane
    # HBM pool, so per-request placement quality actually varies
    stream = [Request(rid=rid,
                      prompt=rng.integers(0, cfg.vocab,
                                          (272 + 16 * (rid % 3),)),
                      max_new_tokens=8 + 4 * (rid % 3))
              for rid in range(10)]
    done = eng.serve(stream, num_slots=4,
                     sampling=SamplingConfig(temperature=0.8, top_k=50),
                     seed=0)
    waits = [r.started_step - r.arrived_step for r in done]
    total = sum(len(r.output) for r in done)
    print(f"serve: {len(done)} requests, {total} sampled tokens through "
          f"the fused loop ({eng._serve_jit._cache_size()} executable), "
          f"mean admission wait {np.mean(waits):.1f} steps, "
          f"pages balanced={eng.batcher.free_pages == eng.batcher.total_pages}")
    if done.ttft:
        print(f"  ttft p50={done.ttft['p50'] * 1e3:.1f}ms "
              f"p95={done.ttft['p95'] * 1e3:.1f}ms   "
              f"tpot p50={done.tpot['p50'] * 1e3:.2f}ms "
              f"p95={done.tpot['p95'] * 1e3:.2f}ms")
    first = min(done, key=lambda r: r.rid)
    print(f"  rid=0 sampled: {first.output}")

    # the serve-trace bridge: stitch each request's decode stream out
    # of the shared batch and score it (and the aggregate) against the
    # SA bound — placement quality per REQUEST, under real lane churn
    rec = trace_bridge.collect_serve(eng)
    trace_bridge.score_serve(rec, GH200, sa_cfg=sa_cfg, report=done)
    agg = done.headroom
    print(f"  stream headroom: hit={agg['live_hit_fraction']:.2f} "
          f"of-SA-bound={agg['bound_fraction']:.2f} over "
          f"{agg['requests']:.0f} requests / {agg['decode_steps']:.0f} "
          f"decode steps")
    for rid in sorted(done.request_scores):
        sc = done.request_scores[rid]
        print(f"    rid={rid:2d} hit={sc['hit_fraction']:.2f} "
              f"of-SA-bound={sc['bound_fraction']:.2f}")


if __name__ == "__main__":
    main()
