"""Placement design-space study: the SA annealer's (W, R) search,
sensitivity to HBM budget, and the TPU-v5e vs GH200 tier ratios.

Reproduces the paper's Section III-B machinery end to end and prints
the annealing trajectory — each accepted improvement attributed to a
window move (dW), ratio move (dR), or diagonal move, exactly the
paper's three proposal operators.

Run:  PYTHONPATH=src python examples/placement_study.py
"""

from repro.core.experiment import Workload, run_strategy, tune_sa
from repro.core.sa import SAConfig
from repro.core.tiers import GH200, TPU_V5E
from repro.core.traces import synthetic_trace


def main():
    wl = Workload.llama31_8b()
    tr = synthetic_trace(prompt_len=20_000, decode_len=800, sparsity=0.75,
                         variation=0.25, seed=0)
    total_kv = (tr.prompt_len + tr.decode_len) \
        * wl.bytes_per_token_layer * wl.num_layers

    # --- SA search over (W, R) -------------------------------------------
    res = tune_sa(tr, GH200, wl, 0.25 * total_kv,
                  cfg=SAConfig(max_evaluations=100, seed=0))
    w, r = res.best_state
    print(f"SA best (W, R) = ({w}, {r:.1f}) after {res.evaluations} "
          f"objective evaluations, {res.temperature_levels} temperature "
          f"levels")
    print(f"accepted improvements by operator: {res.accept_attribution} "
          f"(proposals sampled 0.4/0.4/0.2)")
    accepted = [h for h in res.history if h[3]]
    print(f"walk: {len(res.history)} proposals, {len(accepted)} accepted")

    # --- sensitivity: HBM budget fraction ---------------------------------
    print("\nHBM budget sensitivity (SA speedup vs static):")
    for frac in (0.1, 0.25, 0.5, 0.75):
        budget = frac * total_kv
        st = run_strategy("static", tr, GH200, wl, budget)
        sa = run_strategy("sa", tr, GH200, wl, budget,
                          sa_cfg=SAConfig(max_evaluations=60, seed=1))
        print(f"  budget={frac:.0%}: {st.total_latency_s / sa.total_latency_s:5.2f}x "
              f"(sa hit rate {sa.hbm_hit_rate:.2f})")

    # --- hardware adaptation: GH200 vs TPU v5e ----------------------------
    print("\ntier-ratio sensitivity (same trace, same budget=25%):")
    for spec in (GH200, TPU_V5E):
        st = run_strategy("static", tr, spec, wl, 0.25 * total_kv)
        sa = run_strategy("sa", tr, spec, wl, 0.25 * total_kv,
                          sa_cfg=SAConfig(max_evaluations=60, seed=2))
        print(f"  {spec.name:8s} (HBM:eff-DRAM = {spec.bw_ratio:5.1f}x): "
              f"SA {st.total_latency_s / sa.total_latency_s:5.2f}x static")
    print("\n=> the harsher the tier ratio, the more placement matters —"
          "\n   the paper's conclusion transfers to TPU with MORE headroom.")


if __name__ == "__main__":
    main()
