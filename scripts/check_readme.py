"""Execute the README's quickstart: every fenced ```bash block, line
by line, from the repo root.

The docs CI job runs this so the README can never drift from a
runnable state — if a quickstart command breaks or is renamed, the
docs gate fails the PR, not a user's first five minutes with the
repo. Comment lines inside the blocks are skipped; each command runs
with PYTHONPATH=src prepended to the environment (the README commands
set it inline too, so they also work copy-pasted).

Run:  python scripts/check_readme.py [--list] [README.md ...]
      --list prints the extracted commands without executing them.
"""

from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_FENCE = re.compile(r"^```(\w*)\s*$")


def extract_commands(path: str) -> list:
    """The non-comment lines of every ```bash fence, in order."""
    commands = []
    lang = None
    with open(path, encoding="utf-8") as f:
        for line in f:
            m = _FENCE.match(line.strip())
            if m:
                lang = m.group(1) if lang is None else None
                continue
            if lang == "bash":
                cmd = line.rstrip()
                if cmd and not cmd.lstrip().startswith("#"):
                    commands.append(cmd)
    return commands


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("files", nargs="*",
                    default=[os.path.join(REPO_ROOT, "README.md")])
    ap.add_argument("--list", action="store_true",
                    help="print the extracted commands, don't run them")
    args = ap.parse_args(argv)

    env = dict(os.environ)
    src = os.path.join(REPO_ROOT, "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")

    commands = []
    for path in args.files:
        got = extract_commands(path)
        if not got:
            print(f"error: no ```bash blocks found in {path}")
            return 2
        commands += got
    if args.list:
        print("\n".join(commands))
        return 0

    for cmd in commands:
        print(f"$ {cmd}", flush=True)
        t0 = time.time()
        proc = subprocess.run(cmd, shell=True, cwd=REPO_ROOT, env=env)
        dt = time.time() - t0
        if proc.returncode != 0:
            print(f"FAILED ({proc.returncode}) after {dt:.0f}s: {cmd}")
            return proc.returncode
        print(f"ok ({dt:.0f}s)", flush=True)
    print(f"README quickstart green: {len(commands)} commands")
    return 0


if __name__ == "__main__":
    sys.exit(main())
