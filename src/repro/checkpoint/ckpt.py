"""Sharded pytree checkpoints: msgpack manifest + compressed chunks.

Design goals (1000+-node posture, no orbax in this environment):
  * layout-independent restore — arrays are stored as logical full
    tensors in chunked form; on restore they are device_put with ANY
    target sharding/mesh, so down/up-scaling the mesh (elastic restart)
    is a restore-time concern only;
  * integrity — each chunk carries a crc32; the manifest is written
    last and fsync'd, then a COMMIT marker makes the step visible —
    a torn write can never be mistaken for a valid checkpoint;
  * multi-host writes — each process saves only the shards it owns
    (`process_slice`), and any process can assemble the full tensor at
    restore because chunk files are addressed by global offset;
  * no hard compressor dependency — chunks are zstd-compressed when
    `zstandard` is importable, else zlib (stdlib). The manifest records
    the codec, so either writer's checkpoints restore anywhere zstd is
    available, and zlib checkpoints restore everywhere.
"""

from __future__ import annotations

import os
import struct
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard as zstd
except ImportError:          # zlib fallback keeps checkpoints working
    zstd = None

_CHUNK = 64 * 1024 * 1024   # 64 MB logical chunks

DEFAULT_CODEC = "zstd" if zstd is not None else "zlib"


def _compressor(codec: str):
    if codec == "zstd":
        if zstd is None:
            raise RuntimeError("codec 'zstd' requested but the zstandard "
                               "package is not installed")
        return zstd.ZstdCompressor(level=3).compress
    if codec == "zlib":
        return lambda raw: zlib.compress(raw, 6)
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _decompressor(codec: str):
    if codec == "zstd":
        if zstd is None:
            raise RuntimeError(
                "checkpoint was written with zstd but the zstandard "
                "package is not installed; re-save with codec='zlib' "
                "or install zstandard to restore it")
        return zstd.ZstdDecompressor().decompress
    if codec == "zlib":
        return zlib.decompress
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def save_pytree(tree: Any, directory: str,
                codec: Optional[str] = None) -> None:
    codec = codec or DEFAULT_CODEC
    compress = _compressor(codec)
    os.makedirs(directory, exist_ok=True)
    manifest = {"leaves": [], "codec": codec}
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        name = _path_str(path)
        arr = np.asarray(jax.device_get(leaf))
        fname = name.replace("/", ".") + "." + codec
        raw = arr.tobytes()
        chunks = []
        with open(os.path.join(directory, fname), "wb") as f:
            for off in range(0, max(len(raw), 1), _CHUNK):
                blob = compress(raw[off:off + _CHUNK])
                chunks.append({"off": off, "nbytes": len(blob),
                               "crc": zlib.crc32(blob)})
                f.write(struct.pack("<I", len(blob)))
                f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"].append({
            "name": name, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "chunks": chunks,
        })
    with open(os.path.join(directory, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
        f.flush()
        os.fsync(f.fileno())
    # commit marker LAST: restore only trusts committed checkpoints
    with open(os.path.join(directory, "COMMIT"), "w") as f:
        f.write("ok")
        f.flush()
        os.fsync(f.fileno())


def is_committed(directory: str) -> bool:
    return os.path.exists(os.path.join(directory, "COMMIT"))


def restore_pytree(target: Any, directory: str,
                   shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of `target` (arrays or
    ShapeDtypeStructs). `shardings` (same tree-shape, NamedSharding
    leaves) places each array on the CURRENT mesh — which may differ
    from the mesh that saved it (elastic restart)."""
    assert is_committed(directory), f"no committed checkpoint in {directory}"
    with open(os.path.join(directory, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    by_name = {l["name"]: l for l in manifest["leaves"]}
    # manifests from before the codec field were always zstd
    decompress = _decompressor(manifest.get("codec", "zstd"))

    paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(paths))
    out = []
    for (path, leaf), shd in zip(paths, shard_leaves):
        name = _path_str(path)
        meta = by_name[name]
        buf = bytearray()
        with open(os.path.join(directory, meta["file"]), "rb") as f:
            for ch in meta["chunks"]:
                (n,) = struct.unpack("<I", f.read(4))
                blob = f.read(n)
                assert zlib.crc32(blob) == ch["crc"], \
                    f"corrupt chunk in {name}"
                buf.extend(decompress(blob))
        arr = np.frombuffer(bytes(buf), dtype=meta["dtype"]) \
            .reshape(meta["shape"])
        want_dtype = jnp.dtype(leaf.dtype)
        jarr = jnp.asarray(arr).astype(want_dtype)
        if shd is not None:
            jarr = jax.device_put(jarr, shd)
        out.append(jarr)
    return jax.tree_util.tree_unflatten(treedef, out)
