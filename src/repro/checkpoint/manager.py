"""Checkpoint manager: async saves, keep-N retention, auto-resume,
elastic restore — the fault-tolerance control loop of the trainer.

Failure model handled (per DESIGN.md §5):
  * process crash mid-save        -> COMMIT protocol: partial dirs are
                                      ignored and garbage-collected;
  * node loss / re-scale          -> restore reshards onto whatever mesh
                                      the restarted job has (shardings
                                      are a restore-time argument);
  * straggler checkpoint writes   -> saves run on a background thread;
                                      the train loop never blocks on IO
                                      (`wait()` only at shutdown);
  * data-pipeline recovery        -> the manager persists the step, and
                                      `repro.data` batches are pure
                                      functions of (seed, shard, step).
"""

from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Any, List, Optional

import jax

from repro.checkpoint.ckpt import is_committed, restore_pytree, save_pytree

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.gc_uncommitted()

    # ------------------------------------------------------------------ #
    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step}")

    def steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.root):
            m = _STEP_RE.match(d)
            if m and is_committed(os.path.join(self.root, d)):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree: Any, *, blocking: bool = False) -> None:
        """Snapshot to host memory synchronously (cheap), write async."""
        self.wait()                       # one in-flight save at a time
        host_tree = jax.tree.map(lambda x: jax.device_get(x), tree)
        target = self._dir(step)

        def _write():
            try:
                save_pytree(host_tree, target)
                self._gc()
            except BaseException as e:     # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------------ #
    def restore(self, target: Any, *, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Any:
        if step is None:
            step = self.latest_step()
        assert step is not None, "no committed checkpoint to restore"
        return restore_pytree(target, self._dir(step), shardings=shardings)

    def restore_or_init(self, target: Any, init_fn, *,
                        shardings: Optional[Any] = None):
        """Auto-resume: restore the latest committed step or initialize.
        Returns (tree, start_step)."""
        step = self.latest_step()
        if step is None:
            return init_fn(), 0
        return self.restore(target, step=step, shardings=shardings), step

    # ------------------------------------------------------------------ #
    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._dir(s), ignore_errors=True)

    def gc_uncommitted(self) -> None:
        for d in os.listdir(self.root):
            full = os.path.join(self.root, d)
            if _STEP_RE.match(d) and not is_committed(full):
                shutil.rmtree(full, ignore_errors=True)
