"""Deterministic, shardable synthetic data pipeline.

Fault-tolerance property used by the runtime: batch (shard, step) is a
pure function of (seed, shard, step) — any worker can recompute any
other worker's batch, so a failed/straggling data worker is replaced by
skip-ahead recomputation instead of replay logs. This is the standard
deterministic-input-pipeline trick used by large-scale trainers.

The corpus is a Zipfian token stream with injected n-gram structure so
losses actually decrease during the example runs (pure uniform noise
gives a flat loss and hides wiring bugs).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    num_shards: int = 1
    seed: int = 0
    zipf_a: float = 1.2
    ngram: int = 3        # injected structure order


class SyntheticCorpus:
    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.num_shards == 0
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        # fixed n-gram transition table: next-token = f(prev) with noise
        self._succ = base.integers(0, cfg.vocab,
                                   size=(cfg.ngram, cfg.vocab))

    def batch(self, shard: int, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, shard, step)."""
        cfg = self.cfg
        per_shard = cfg.global_batch // cfg.num_shards
        rng = np.random.default_rng(
            (cfg.seed, shard, step))          # independent stream
        # Zipf-distributed seeds + deterministic n-gram continuation
        out = np.empty((per_shard, cfg.seq_len), np.int32)
        cur = (rng.zipf(cfg.zipf_a, size=per_shard) - 1) % cfg.vocab
        out[:, 0] = cur
        for t in range(1, cfg.seq_len):
            use_struct = rng.random(per_shard) < 0.8
            nxt_struct = self._succ[t % cfg.ngram, cur]
            nxt_rand = (rng.zipf(cfg.zipf_a, size=per_shard) - 1) % cfg.vocab
            cur = np.where(use_struct, nxt_struct, nxt_rand).astype(np.int32)
            out[:, t] = cur
        return {"tokens": out}


def make_batches(cfg: DataConfig, shard: int,
                 start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    corpus = SyntheticCorpus(cfg)
    step = start_step
    while True:
        yield corpus.batch(shard, step)
        step += 1
