from repro.data.pipeline import DataConfig, SyntheticCorpus, make_batches

__all__ = ["DataConfig", "SyntheticCorpus", "make_batches"]
