"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

`paged_attention_ref` defines the exact semantics of the per-tier paged
decode attention:

  * q:        [B, KH, G, HD]   one query token, grouped GQA layout
  * k_pool:   [B, P, T, KH, HD] physical page pool of ONE tier
  * v_pool:   [B, P, T, KH, HD]
  * page_list:[B, N] int32     pool slot of the n-th resident logical
                               page; -1 = hole (nothing resident)
  * page_valid:[B, N] int32    number of valid tokens in that page (0..T)

  returns (out, m, l, page_lse):
  * out:      [B, KH, G, HD]   UNNORMALIZED partial numerator / l
  * m:        [B, KH, G]       running max of scores (f32)
  * l:        [B, KH, G]       sum of exp(score - m) (f32)
  * page_lse: [B, KH, G, N]    per-page log-sum-exp of scores (f32);
                               -inf for invalid pages

Two tiers are combined exactly with `merge_partials` (associative
log-sum-exp merge), which is also how sequence-parallel attention
composes across devices.

RoPE is applied to K *before* it enters the cache, so page order carries
no positional meaning and causality reduces to validity masking.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q, k_pool, v_pool, page_list, page_valid,
                        ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    B, KH, G, HD = q.shape
    P, T = k_pool.shape[1], k_pool.shape[2]
    N = page_list.shape[1]
    scale = HD ** -0.5

    slot = jnp.clip(page_list, 0, P - 1)                     # [B, N]
    bidx = jnp.arange(B)[:, None]
    k = k_pool[bidx, slot]                                   # [B, N, T, KH, HD]
    v = v_pool[bidx, slot]

    # scores: [B, KH, G, N, T]
    s = jnp.einsum("bkgd,bntkd->bkgnt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    tok = jnp.arange(T)[None, None, :]
    valid = (page_list[:, :, None] >= 0) & (tok < page_valid[:, :, None])
    s = jnp.where(valid[:, None, None], s, NEG_INF)

    m = jnp.max(s, axis=(-2, -1))                            # [B, KH, G]
    # all-invalid guard
    m_safe = jnp.where(m <= NEG_INF / 2, 0.0, m)
    p = jnp.exp(s - m_safe[..., None, None])
    p = jnp.where(valid[:, None, None], p, 0.0)
    l = jnp.sum(p, axis=(-2, -1))                            # [B, KH, G]
    num = jnp.einsum("bkgnt,bntkd->bkgd", p, v.astype(jnp.float32))
    out = num / jnp.maximum(l, 1e-20)[..., None]

    page_lse = jnp.where(
        jnp.any(valid, -1)[:, None, None],
        m_safe[..., None] + jnp.log(jnp.maximum(
            jnp.sum(p, axis=-1), 1e-37)),
        NEG_INF)                                             # [B, KH, G, N]
    m = jnp.where(l > 0, m_safe, NEG_INF)
    return out.astype(q.dtype), m, l, page_lse


def pool_attention_ref(q, k_pool, v_pool, page_valid,
                       ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Gather-free tier attention: identity page layout, mask-only.

    Semantically identical to `paged_attention_ref` with
    page_list = arange(P) (the layout `PagedKVCache.tier_lists` always
    produces): slot p holds logical data iff page_valid[b, p] > 0.

    This is the SPMD-lowering path: no dynamic gather means GSPMD can
    keep the pools sharded on the PAGES dim and insert only the small
    softmax-stat + output all-reduces (the LSE merge is associative
    over pages, so page-sharding == sequence-parallel attention).
    Inputs stay bf16; only softmax stats are f32 (no f32 pool copies).
    """
    B, KH, G, HD = q.shape
    P, T = k_pool.shape[1], k_pool.shape[2]
    scale = HD ** -0.5

    # bf16 dots: the TPU MXU takes bf16 operands with f32 internal
    # accumulation, so a bf16-out dot is the faithful lowering — an
    # explicit preferred_element_type=f32 makes the CPU backend
    # materialize f32 copies of the (huge) pools, which a real TPU
    # never does. Softmax math stays f32 on the small score tensor.
    s = jnp.einsum("bkgd,bptkd->bkgpt", q, k_pool)
    s = s.astype(jnp.float32) * scale
    tok = jnp.arange(T)[None, None, :]
    valid = tok < page_valid[:, :, None]                     # [B,P,T]
    s = jnp.where(valid[:, None, None], s, NEG_INF)

    m = jnp.max(s, axis=(-2, -1))                            # [B,KH,G]
    m_safe = jnp.where(m <= NEG_INF / 2, 0.0, m)
    p = jnp.exp(s - m_safe[..., None, None])
    p = jnp.where(valid[:, None, None], p, 0.0)
    l = jnp.sum(p, axis=(-2, -1))
    num = jnp.einsum("bkgpt,bptkd->bkgd", p.astype(q.dtype), v_pool)
    out = num.astype(jnp.float32) / jnp.maximum(l, 1e-20)[..., None]

    page_lse = jnp.where(
        jnp.any(valid, -1)[:, None, None],
        m_safe[..., None] + jnp.log(jnp.maximum(jnp.sum(p, -1), 1e-37)),
        NEG_INF)                                             # [B,KH,G,P]
    m = jnp.where(l > 0, m_safe, NEG_INF)
    return out.astype(q.dtype), m, l, page_lse


def merge_partials(parts) -> Tuple[jax.Array, jax.Array]:
    """Merge per-tier partial attentions exactly.

    parts: list of (out [**, HD], m [**], l [**]) from paged_attention_ref.
    Returns (out, lse) with out normalized over the union of tiers.
    """
    ms = jnp.stack([p[1] for p in parts])                    # [n, ...]
    m = jnp.max(ms, axis=0)
    m_safe = jnp.where(m <= NEG_INF / 2, 0.0, m)
    num = 0.0
    den = 0.0
    for out, mi, li in parts:
        corr = jnp.exp(jnp.where(li > 0, mi - m_safe, NEG_INF))
        num = num + out.astype(jnp.float32) * (li * corr)[..., None]
        den = den + li * corr
    merged = num / jnp.maximum(den, 1e-20)[..., None]
    lse = m_safe + jnp.log(jnp.maximum(den, 1e-37))
    return merged, lse


def page_importance(page_lse: jax.Array, total_lse: jax.Array) -> jax.Array:
    """Attention mass per page: sum over (KH, G) of exp(page_lse - lse).

    page_lse: [B, KH, G, N]; total_lse: [B, KH, G] -> [B, N] in [0, H].
    """
    mass = jnp.exp(page_lse - total_lse[..., None])
    mass = jnp.where(page_lse <= NEG_INF / 2, 0.0, mass)
    return mass.sum(axis=(1, 2))


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        q_offset: int = 0) -> jax.Array:
    """Oracle for the prefill flash kernel. q,k,v: [B, S, H, D]."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = (jnp.arange(sk)[None, :]
                <= (jnp.arange(sq) + q_offset)[:, None])
        s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)
