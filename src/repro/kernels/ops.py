"""Public jit'd wrappers around the Pallas kernels.

`tiered_paged_attention` is the two-tier composition the whole serving
stack uses: per-tier paged attention (Pallas kernel on TPU, pure-jnp
oracle on CPU) merged exactly via log-sum-exp — the TPU-idiomatic form
of the paper's concurrent HBM/DRAM reads (Eq. 2's max(t_h, t_e) becomes
two overlapped kernel invocations whose partials merge associatively).

Backend selection: `use_pallas=None` auto-picks the kernel on TPU and
the reference on CPU (interpret-mode Pallas is used by tests, not by
the hot path — it is Python-slow).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.paged_attention import paged_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def tier_attention(q, k_pool, v_pool, page_list, page_valid,
                   *, use_pallas: Optional[bool] = None):
    """Partial attention over one tier -> (out, m, l, page_lse).

    TPU: the Pallas paged kernel (page-table gather in SMEM).
    Otherwise: the gather-free dense pool form — page_list from
    `tier_lists` is identity-or-hole, holes already have valid == 0,
    so masking alone is exact, and GSPMD keeps pools page-sharded.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return paged_attention(q, k_pool, v_pool, page_list, page_valid,
                               interpret=not _on_tpu())
    return ref.pool_attention_ref(q, k_pool, v_pool, page_valid)


def tiered_paged_attention(
    q: jax.Array,
    k_hbm: jax.Array, v_hbm: jax.Array,
    k_host: jax.Array, v_host: jax.Array,
    hbm_list: jax.Array, hbm_valid: jax.Array,
    host_list: jax.Array, host_valid: jax.Array,
    *, use_pallas: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Decode attention over the union of two tiers.

    q: [B, KH, G, HD]. Returns (out [B, KH, G, HD], importance [B, Nh+Ne])
    where importance is the per-page attention mass (summed over heads),
    ordered [hbm pages..., host pages...] matching the two lists.
    """
    out_h, m_h, l_h, lse_h = tier_attention(
        q, k_hbm, v_hbm, hbm_list, hbm_valid, use_pallas=use_pallas)
    out_e, m_e, l_e, lse_e = tier_attention(
        q, k_host, v_host, host_list, host_valid, use_pallas=use_pallas)
    merged, total_lse = ref.merge_partials(
        [(out_h, m_h, l_h), (out_e, m_e, l_e)])
    imp_h = ref.page_importance(lse_h, total_lse)
    imp_e = ref.page_importance(lse_e, total_lse)
    return merged.astype(q.dtype), jnp.concatenate([imp_h, imp_e], axis=-1)


def flash_attention(q, k, v, *, causal: bool = True,
                    use_pallas: Optional[bool] = None,
                    q_block: int = 256, k_block: int = 256) -> jax.Array:
    """Prefill/train attention, public layout [B, S, H, D]."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return ref.flash_attention_ref(q, k, v, causal=causal)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, q_block=q_block,
                               k_block=k_block, interpret=not _on_tpu())
    return out.transpose(0, 2, 1, 3)
