"""Pallas TPU kernel: causal flash attention (prefill / training path).

Standard streaming-softmax tiling adapted to TPU: query and key blocks
sized for VMEM, MXU-aligned (multiples of 128 on the contracting dims),
running (m, l, acc) in VMEM scratch. Upper-triangular key blocks are
masked (not skipped) — the dry-run roofline counts them, and skipping
via fori_loop-in-kernel is recorded as a §Perf candidate.

Layout: [B, H, S, D] (ops.py handles the [B, S, H, D] public layout).
Grid: (B, H, NQ, NK), NK innermost.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, out_ref, m_scr, l_scr, acc_scr,
            *, q_block: int, k_block: int, causal: bool):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)          # [QB, D]
    k = k_ref[...].astype(jnp.float32)          # [KB, D]
    v = v_ref[...].astype(jnp.float32)          # [KB, D]
    scale = q.shape[-1] ** -0.5

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = iq * q_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = ik * k_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_old = m_scr[...]
    m_blk = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_old, m_blk)
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    corr = jnp.where(m_old <= NEG_INF / 2, 0.0, jnp.exp(m_old - m_safe))
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        out_ref[...] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-20)[:, None]
                        ).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "q_block", "k_block",
                                    "interpret"))
def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, q_block: int = 256,
                         k_block: int = 256,
                         interpret: bool = True) -> jax.Array:
    """q, k, v: [B, H, S, D] -> out [B, H, S, D]."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    q_block = min(q_block, Sq)
    k_block = min(k_block, Sk)
    assert Sq % q_block == 0 and Sk % k_block == 0

    grid = (B, H, Sq // q_block, Sk // k_block)

    kernel = functools.partial(_kernel, q_block=q_block, k_block=k_block,
                               causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, q_block, D),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((None, None, k_block, D),
                         lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((None, None, k_block, D),
                         lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, q_block, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
