"""Pallas TPU kernel: paged GQA decode attention over ONE memory tier.

This is the compute hot-spot of the paper's serving path: every decode
step streams resident KV pages and produces (a) the partial attention
output for that tier and (b) per-page log-sum-exp scores that the
placement policy uses as token-importance statistics — so importance
tracking is free, fused into the attention read pass.

TPU mapping decisions (HARDWARE ADAPTATION notes):
  * A page (16 tokens x 128 head_dim) is exactly a (16, 128) VMEM tile —
    the page size the paper takes from Quest happens to be the native
    TPU sublane x lane tile, so page gathers are aligned DMAs.
  * The page table is a scalar-prefetch operand
    (`pltpu.PrefetchScalarGridSpec`): the index_map dereferences
    page_list BEFORE the grid step runs, so Mosaic can overlap the
    page DMA of step i+1 with the FLOPs of step i — the TPU analogue
    of the paper's overlap of link transfers and HBM reads.
  * Running softmax state (m, l, acc) lives in VMEM scratch; one grid
    step processes one page for one (batch, kv_head) pair.

Grid: (B, KH, N) with N = max resident pages (innermost, sequential).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(page_list_ref, page_valid_ref,   # scalar prefetch (SMEM)
            q_ref, k_ref, v_ref,             # VMEM blocks
            out_ref, m_out_ref, l_out_ref, lse_ref,   # outputs
            m_scr, l_scr, acc_scr,           # scratch
            *, page_tokens: int):
    b = pl.program_id(0)
    kh = pl.program_id(1)
    i = pl.program_id(2)
    n_pages = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)        # [G, HD]
    k = k_ref[...].astype(jnp.float32)        # [T, HD]
    v = v_ref[...].astype(jnp.float32)        # [T, HD]
    scale = q.shape[-1] ** -0.5

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # validity: page exists and token offset < page_valid
    n_valid = page_valid_ref[b, i]
    exists = page_list_ref[b, i] >= 0
    tok_ok = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) < n_valid
    valid = tok_ok & exists
    s = jnp.where(valid, s, NEG_INF)

    # per-page lse (independent of running state -> numerically clean)
    m_p = jnp.max(s, axis=-1)                              # [G]
    m_p_safe = jnp.where(m_p <= NEG_INF / 2, 0.0, m_p)
    p_loc = jnp.where(valid, jnp.exp(s - m_p_safe[:, None]), 0.0)
    l_p = jnp.sum(p_loc, axis=-1)                          # [G]
    lse_ref[...] = jnp.where(l_p > 0,
                             m_p_safe + jnp.log(jnp.maximum(l_p, 1e-37)),
                             NEG_INF)

    # running softmax update
    m_old = m_scr[...]
    m_new = jnp.maximum(m_old, m_p)
    m_new_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    corr_old = jnp.where(m_old <= NEG_INF / 2, 0.0,
                         jnp.exp(m_old - m_new_safe))
    corr_p = jnp.where(l_p > 0, jnp.exp(m_p_safe - m_new_safe), 0.0)
    pv = jax.lax.dot_general(p_loc, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [G, HD]
    l_scr[...] = l_scr[...] * corr_old + l_p * corr_p
    acc_scr[...] = acc_scr[...] * corr_old[:, None] + pv * corr_p[:, None]
    m_scr[...] = m_new

    @pl.when(i == n_pages - 1)
    def _finalize():
        l = l_scr[...]
        out_ref[...] = (acc_scr[...]
                        / jnp.maximum(l, 1e-20)[:, None]).astype(out_ref.dtype)
        m_out_ref[...] = m_scr[...]
        l_out_ref[...] = l


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    page_list: jax.Array, page_valid: jax.Array,
                    *, interpret: bool = True,
                    ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Semantics identical to `repro.kernels.ref.paged_attention_ref`.

    q: [B, KH, G, HD]; k_pool/v_pool: [B, P, T, KH, HD];
    page_list/page_valid: [B, N] int32.
    """
    B, KH, G, HD = q.shape
    P, T = k_pool.shape[1], k_pool.shape[2]
    N = page_list.shape[1]

    grid = (B, KH, N)

    def q_map(b, kh, i, pl_ref, pv_ref):
        return (b, kh, 0, 0)

    def kv_map(b, kh, i, pl_ref, pv_ref):
        slot = jnp.maximum(pl_ref[b, i], 0)   # clamp holes to page 0
        return (b, slot, 0, kh, 0)

    def out_map(b, kh, i, pl_ref, pv_ref):
        return (b, kh, 0, 0)

    def ml_map(b, kh, i, pl_ref, pv_ref):
        return (b, kh, 0)

    def lse_map(b, kh, i, pl_ref, pv_ref):
        return (b, kh, 0, i)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, G, HD), q_map),
            pl.BlockSpec((None, None, T, None, HD), kv_map),
            pl.BlockSpec((None, None, T, None, HD), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((None, None, G, HD), out_map),
            pl.BlockSpec((None, None, G), ml_map),
            pl.BlockSpec((None, None, G), ml_map),
            pl.BlockSpec((None, None, G, None), lse_map),
        ],
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, HD), jnp.float32),
        ],
    )

    out_shapes = [
        jax.ShapeDtypeStruct((B, KH, G, HD), q.dtype),
        jax.ShapeDtypeStruct((B, KH, G), jnp.float32),
        jax.ShapeDtypeStruct((B, KH, G), jnp.float32),
        jax.ShapeDtypeStruct((B, KH, G, N), jnp.float32),
    ]

    kernel = functools.partial(_kernel, page_tokens=T)
    out, m, l, lse = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        interpret=interpret,
    )(page_list, page_valid, q, k_pool, v_pool)
    return out, m, l, lse
