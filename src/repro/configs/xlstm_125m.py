"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

No KV cache: recurrent state only (sub-quadratic; runs long_500k).
Paper's placement technique inapplicable (DESIGN.md §6).
"""
from repro.models.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="xlstm",
    num_layers=12, d_model=768, num_heads=4, kv_heads=4,
    d_ff=0, vocab=50304, head_dim=192, subquadratic=True,
    xlstm=XLSTMConfig(slstm_every=4, expand=2, conv_width=4, chunk=128),
)


def smoke_config():
    return ModelConfig(
        name="xlstm-smoke", family="xlstm",
        num_layers=2, d_model=64, num_heads=4, kv_heads=4,
        d_ff=0, vocab=256, head_dim=16, subquadratic=True,
        xlstm=XLSTMConfig(slstm_every=2, expand=2, conv_width=4, chunk=8))
