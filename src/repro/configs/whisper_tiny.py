"""whisper-tiny — enc-dec, conv frontend STUBBED (input_specs provides
precomputed frame embeddings) [arXiv:2212.04356; unverified]."""
from repro.models.config import EncDecConfig, FrontendStub, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    num_layers=4, d_model=384, num_heads=6, kv_heads=6,
    d_ff=1536, vocab=51865, head_dim=64, tie_embeddings=True,
    encdec=EncDecConfig(enc_layers=4, enc_positions=1500),
    frontend=FrontendStub(kind="audio", num_embeddings=1500),
)


def smoke_config():
    return ModelConfig(
        name="whisper-smoke", family="encdec",
        num_layers=2, d_model=64, num_heads=4, kv_heads=4,
        d_ff=128, vocab=256, head_dim=16, tie_embeddings=True,
        encdec=EncDecConfig(enc_layers=2, enc_positions=64),
        frontend=FrontendStub(kind="audio", num_embeddings=64))
