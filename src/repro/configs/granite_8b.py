"""granite-8b — llama-arch dense GQA, code model [arXiv:2405.04324; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", family="dense",
    num_layers=36, d_model=4096, num_heads=32, kv_heads=8,
    d_ff=14336, vocab=49152, head_dim=128, rope_theta=1e6,
)


def smoke_config():
    return ModelConfig(
        name="granite-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, kv_heads=2,
        d_ff=96, vocab=256, head_dim=16)
