"""llama4-maverick-400b-a17b — MoE 128e top-1, interleaved dense/MoE
layers, shared expert [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

The assignment's "early fusion" refers to the multimodal frontend; the
backbone here is the text transformer (the dry-run exercises it with
token inputs). Interleave=2 (every other layer MoE) reproduces the
~400B total / ~17B active split with 48 layers x 128 experts.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, kv_heads=8,
    d_ff=8192, vocab=202048, head_dim=128, rope_theta=5e5,
    moe=MoEConfig(num_experts=128, top_k=1, interleave=2,
                  capacity_factor=1.25, shared_expert=True),
)


def smoke_config():
    return ModelConfig(
        name="llama4-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, kv_heads=2,
        d_ff=64, vocab=256, head_dim=16,
        moe=MoEConfig(num_experts=4, top_k=1, interleave=2,
                      capacity_factor=1.25, shared_expert=True))
