"""zamba2-1.2b — hybrid Mamba2 backbone + ONE weight-shared attention
block applied periodically [arXiv:2411.15242; hf]. ssm_state=64.

attn_every=19 -> shared-attn sites at blocks 18 and 37 (two
applications, as in Zamba2-1.2B). Only those sites own KV caches —
the most placement-friendly assigned arch (DESIGN.md §6).
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, kv_heads=32,
    d_ff=8192, vocab=32000, head_dim=64, subquadratic=True,
    ssm=SSMConfig(state_dim=64, conv_width=4, expand=2, chunk=128,
                  attn_every=19),
)


def smoke_config():
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        num_layers=4, d_model=64, num_heads=4, kv_heads=4,
        d_ff=128, vocab=256, head_dim=16, subquadratic=True,
        ssm=SSMConfig(state_dim=16, conv_width=4, expand=2, chunk=8,
                      attn_every=2))
