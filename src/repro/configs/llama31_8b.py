"""llama-3.1-8b — the paper's own evaluation model (Section IV-A)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama31-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, kv_heads=8,
    d_ff=14336, vocab=128256, head_dim=128, rope_theta=5e5,
    eos_id=128001,                     # <|end_of_text|>
)


def smoke_config():
    return ModelConfig(
        name="llama31-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, kv_heads=2,
        d_ff=96, vocab=256, head_dim=16,
        eos_id=2)                      # reduced-vocab stand-in
