"""qwen3-32b — dense GQA with qk RMSNorm [hf:Qwen/Qwen3-8B; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=64, kv_heads=8,
    d_ff=25600, vocab=151936, head_dim=128, qk_norm=True,
    rope_theta=1e6,
    eos_id=151645,                     # <|im_end|>
)


def smoke_config():
    return ModelConfig(
        name="qwen3-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, kv_heads=2,
        d_ff=128, vocab=256, head_dim=16, qk_norm=True,
        eos_id=2)                      # reduced-vocab stand-in
