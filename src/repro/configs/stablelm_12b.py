"""stablelm-12b — dense GQA [hf:stabilityai/stablelm-2-1_6b; hf].

Note (DESIGN.md): stablelm-2-12b uses parallel attention/FFN residuals
in some variants; we implement the standard sequential pre-norm block
with the assigned dimensions — shape- and FLOP-identical.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b", family="dense",
    num_layers=40, d_model=5120, num_heads=32, kv_heads=8,
    d_ff=13824, vocab=100352, head_dim=160, rope_theta=1e6,
)


def smoke_config():
    return ModelConfig(
        name="stablelm-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, kv_heads=2,
        d_ff=96, vocab=256, head_dim=16)
