"""granite-moe-3b-a800m — MoE 40e top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, kv_heads=8,
    d_ff=512, vocab=49155, head_dim=64, rope_theta=1e6,
    moe=MoEConfig(num_experts=40, top_k=8, interleave=1,
                  capacity_factor=1.25, pad_experts_to=48,
                  group_size=512),
)


def smoke_config():
    return ModelConfig(
        name="granite-moe-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, kv_heads=2,
        d_ff=32, vocab=256, head_dim=16,
        moe=MoEConfig(num_experts=4, top_k=2, interleave=1,
                      capacity_factor=1.25))
