"""internlm2-1.8b — dense GQA [arXiv:2403.17297; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b", family="dense",
    num_layers=24, d_model=2048, num_heads=16, kv_heads=8,
    d_ff=8192, vocab=92544, head_dim=128, rope_theta=1e6,
    eos_id=2,                          # </s> (internlm2 tokenizer)
)


def smoke_config():
    return ModelConfig(
        name="internlm2-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, kv_heads=2,
        d_ff=128, vocab=256, head_dim=16,
        eos_id=2)                      # reduced-vocab stand-in, same id
