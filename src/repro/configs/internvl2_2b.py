"""internvl2-2b — VLM: InternViT frontend STUBBED (patch embeddings via
input_specs), InternLM2-2b backbone [arXiv:2404.16821; hf]."""
from repro.models.config import FrontendStub, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, kv_heads=8,
    d_ff=8192, vocab=92553, head_dim=128, rope_theta=1e6,
    frontend=FrontendStub(kind="vision", num_embeddings=256),
)


def smoke_config():
    return ModelConfig(
        name="internvl2-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, kv_heads=2,
        d_ff=128, vocab=256, head_dim=16,
        frontend=FrontendStub(kind="vision", num_embeddings=16))
