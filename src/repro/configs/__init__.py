"""Assigned architecture configs (public-literature values) + the
paper's own LLaMA-3.1-8B.

Each module exposes CONFIG (the exact assigned configuration) and
smoke_config() (a reduced same-family variant for CPU tests).
`get(name)` / `get_smoke(name)` are the registry entry points used by
--arch flags across the launchers and benchmarks.
"""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "internlm2_1_8b",
    "granite_8b",
    "qwen3_32b",
    "stablelm_12b",
    "llama4_maverick_400b_a17b",
    "granite_moe_3b_a800m",
    "whisper_tiny",
    "internvl2_2b",
    "xlstm_125m",
    "zamba2_1_2b",
]

# canonical ids as given in the assignment (dashes) -> module names
ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}
ALIASES.update({
    "internlm2-1.8b": "internlm2_1_8b",
    "qwen3-32b": "qwen3_32b",
    "granite-8b": "granite_8b",
    "stablelm-12b": "stablelm_12b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "whisper-tiny": "whisper_tiny",
    "internvl2-2b": "internvl2_2b",
    "xlstm-125m": "xlstm_125m",
    "zamba2-1.2b": "zamba2_1_2b",
    "llama31-8b": "llama31_8b",
})


def _module(name: str):
    name = ALIASES.get(name, name)
    return importlib.import_module(f"repro.configs.{name}")


def get(name: str):
    return _module(name).CONFIG


def get_smoke(name: str):
    return _module(name).smoke_config()


def all_arch_names():
    return [i.replace("_", "-") for i in ARCH_IDS]
