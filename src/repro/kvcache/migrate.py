"""jit-safe page migration between the HBM and host tiers.

The control plane (`repro.serving.engine` / a placement policy) decides
WHAT moves; this module executes a batch of moves inside jit with
static shapes: both directions take fixed-size index arrays padded with
-1 rows. Padded rows are routed to out-of-bounds indices and dropped by
the scatter (`mode="drop"`) — NOT masked via gather+select, which would
both read stale values and collide on duplicate clamped indices.

Execution is an explicit TWO-PHASE commit (PR 8, the async-migration
split): `stage_plan` gathers every source page from the input pools
into a staging buffer, and `commit_staged` scatters the buffer into the
destination pools and rewrites the maps. `apply_migrations` — the
inline path every pre-overlap call site uses — is exactly
stage-then-commit with zero lag, so the split is bitwise-invisible to
it (pinned by tests/test_async_migration.py). The overlap serve
pipeline (`EngineConfig.overlap_migrations`) threads a staged
`MigrationPlan` through the scan carry instead and commits it one step
later, concurrently with the next step's decode compute; hazard masking
for that lag lives in `repro.serving.control.revalidate_plan`.

On a real TPU the two pools live in different `memory_kind`s
(`repro.kvcache.paged.host_memory_kind` feature-detects pinned host
memory) and XLA lowers the cross-pool scatter into DMA transfers over
the host link — the M_i / M_o traffic of Eq. (3)/(4). The byte
accounting used by the simulator and by the engine's telemetry matches
1:1.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kvcache.paged import NO_SLOT, PagedKVCache


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MigrationPlan:
    """Fixed-capacity migration batch. All arrays [M]; -1 rows are no-ops.

    promote: host slot `src` -> hbm slot `dst` (page `logical`)
    demote:  hbm slot `src`  -> host slot `dst`
    Every entry also names the (layer, batch) coordinate.
    """
    pro_layer: jax.Array
    pro_batch: jax.Array
    pro_src: jax.Array      # host slot
    pro_dst: jax.Array      # hbm slot
    pro_logical: jax.Array
    dem_layer: jax.Array
    dem_batch: jax.Array
    dem_src: jax.Array      # hbm slot
    dem_dst: jax.Array      # host slot
    dem_logical: jax.Array

    @classmethod
    def empty(cls, capacity: int) -> "MigrationPlan":
        # ten DISTINCT buffers, not one aliased array: the overlap
        # serve loop donates the empty plan as the initial scan carry,
        # and XLA rejects donating the same buffer twice
        return cls(*[jnp.full((capacity,), -1, jnp.int32)
                     for _ in range(10)])

    @classmethod
    def build(cls, capacity: int, promotes, demotes) -> "MigrationPlan":
        """promotes/demotes: iterables of (layer, batch, src, dst, logical).

        `capacity` must be a per-geometry constant (see
        `repro.serving.control.plan_capacity`), NOT derived from the
        number of rows — a row-count capacity gives `apply_migrations`
        a different traced shape on nearly every step and recompiles it
        for each distinct promote/demote count.
        """
        import numpy as np

        def pack(rows):
            arr = np.full((capacity, 5), -1, np.int32)
            rows = list(rows)[:capacity]
            if rows:
                arr[: len(rows)] = np.asarray(rows, np.int32)
            return [jnp.asarray(arr[:, i]) for i in range(5)]
        return cls(*pack(promotes), *pack(demotes))

    @property
    def capacity(self) -> int:
        return self.pro_layer.shape[0]

    def row_counts(self) -> Tuple[jax.Array, jax.Array]:
        """(n_promotes, n_demotes) actually encoded in the plan — the
        non-sentinel rows. jit-safe; matches the counts a planner
        returned when it built the plan (telemetry cross-check)."""
        return (jnp.sum(self.pro_layer >= 0), jnp.sum(self.dem_layer >= 0))


def _oob(idx, ok, bound):
    """Route masked rows out of bounds (dropped by mode='drop').
    Sentinels must be OOB-HIGH: negative indices wrap NumPy-style."""
    return jnp.where(ok, idx, jnp.int32(bound))


def stage_plan(cache: PagedKVCache, plan: MigrationPlan
               ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Phase 1 of the two-phase commit: gather every source page.

    Returns `(dem_k, dem_v, pro_k, pro_v)`, each [M, T, KH, HD] — the
    HBM pages the plan demotes and the host pages it promotes, read
    from the INPUT pools before any scatter runs. Staging first is what
    makes a swap safe: a demotion whose destination is the host slot
    being vacated by a promotion (``dem_dst == pro_src``) reads the
    promoted page before the victim overwrites its slot — the
    gather-before-scatter discipline the engine has relied on since the
    first fused step. Sentinel (-1) rows gather an arbitrary in-bounds
    page; `commit_staged` routes them out of bounds and drops them.
    """
    L = cache.k_hbm.shape[0]
    hbm_pages = cache.k_hbm.shape[2]
    host_pages = cache.k_host.shape[2]
    d_l = jnp.clip(plan.dem_layer, 0, L - 1)
    d_b = jnp.maximum(plan.dem_batch, 0)
    d_src = jnp.clip(plan.dem_src, 0, hbm_pages - 1)
    dem_k = cache.k_hbm[d_l, d_b, d_src]          # [M, T, KH, HD]
    dem_v = cache.v_hbm[d_l, d_b, d_src]
    p_l = jnp.clip(plan.pro_layer, 0, L - 1)
    p_b = jnp.maximum(plan.pro_batch, 0)
    p_src = jnp.clip(plan.pro_src, 0, host_pages - 1)
    pro_k = cache.k_host[p_l, p_b, p_src]
    pro_v = cache.v_host[p_l, p_b, p_src]
    return dem_k, dem_v, pro_k, pro_v


def commit_staged(cache: PagedKVCache, plan: MigrationPlan,
                  staged: Tuple[jax.Array, jax.Array, jax.Array, jax.Array]
                  ) -> PagedKVCache:
    """Phase 2 of the two-phase commit: scatter the staged pages and
    rewrite the maps. Shapes are static in `plan`.

    `staged` is `stage_plan`'s gather of the SAME plan. Sentinel rows
    scatter to out-of-bounds indices (`mode="drop"`). Owner clears land
    before owner sets, so swapped slots end up owned by the arriving
    page, not marked free. The caller owns hazard ordering: when the
    commit lags the plan (overlap mode), it must first mask rows the
    interim steps invalidated (`control.revalidate_plan`) and re-stage
    against the commit-time pools.
    """
    dem_k, dem_v, pro_k, pro_v = staged
    k_hbm, v_hbm = cache.k_hbm, cache.v_hbm
    k_host, v_host = cache.k_host, cache.v_host
    page_table = cache.page_table
    hbm_owner, host_owner = cache.hbm_owner, cache.host_owner
    L = k_hbm.shape[0]
    hbm_pages = k_hbm.shape[2]
    host_pages = k_host.shape[2]
    max_pages = page_table.shape[2]

    # ---- index prep --------------------------------------------------------
    d_ok = plan.dem_layer >= 0
    d_l = _oob(plan.dem_layer, d_ok, L)
    d_b = jnp.maximum(plan.dem_batch, 0)
    d_src = jnp.minimum(jnp.maximum(plan.dem_src, 0), hbm_pages - 1)
    d_dst = _oob(plan.dem_dst, d_ok, host_pages)
    d_logical = _oob(plan.dem_logical, d_ok, max_pages)

    p_ok = plan.pro_layer >= 0
    p_l = _oob(plan.pro_layer, p_ok, L)
    p_b = jnp.maximum(plan.pro_batch, 0)
    p_src = jnp.minimum(jnp.maximum(plan.pro_src, 0), host_pages - 1)
    p_dst = _oob(plan.pro_dst, p_ok, hbm_pages)
    p_logical = _oob(plan.pro_logical, p_ok, max_pages)

    # ---- scatter data ------------------------------------------------------
    k_host = k_host.at[d_l, d_b, d_dst].set(dem_k, mode="drop")
    v_host = v_host.at[d_l, d_b, d_dst].set(dem_v, mode="drop")
    k_hbm = k_hbm.at[p_l, p_b, p_dst].set(pro_k, mode="drop")
    v_hbm = v_hbm.at[p_l, p_b, p_dst].set(pro_v, mode="drop")

    # ---- owner maps: clear vacated slots FIRST, then record arrivals -------
    hbm_owner = hbm_owner.at[d_l, d_b, _oob(plan.dem_src, d_ok, hbm_pages)] \
        .set(jnp.full_like(d_src, NO_SLOT), mode="drop")
    hbm_owner = hbm_owner.at[p_l, p_b, p_dst].set(
        jnp.where(p_ok, p_logical, NO_SLOT), mode="drop")
    host_owner = host_owner.at[p_l, p_b, _oob(plan.pro_src, p_ok, host_pages)] \
        .set(jnp.full_like(p_src, NO_SLOT), mode="drop")
    host_owner = host_owner.at[d_l, d_b, d_dst].set(
        jnp.where(d_ok, d_logical, NO_SLOT), mode="drop")

    # ---- page table --------------------------------------------------------
    page_table = page_table.at[d_l, d_b, d_logical].set(
        d_dst + hbm_pages, mode="drop")
    page_table = page_table.at[p_l, p_b, p_logical].set(p_dst, mode="drop")

    return dataclasses.replace(
        cache, k_hbm=k_hbm, v_hbm=v_hbm, k_host=k_host, v_host=v_host,
        page_table=page_table, hbm_owner=hbm_owner, host_owner=host_owner)


def apply_migrations(cache: PagedKVCache,
                     plan: MigrationPlan) -> PagedKVCache:
    """Execute a migration batch inline: two-phase commit with zero lag.

    Exactly `commit_staged(cache, plan, stage_plan(cache, plan))` — the
    pre-overlap call sites (the inline serve step, `step`/`run`/
    `generate`) keep this entry point, and the two-phase split is
    bitwise-invisible to them (tests/test_async_migration.py).
    """
    return commit_staged(cache, plan, stage_plan(cache, plan))


def migration_bytes(plan: MigrationPlan, page_bytes: int
                    ) -> Tuple[jax.Array, jax.Array]:
    """(M_i, M_o) bytes for Eq. (3)/(4) telemetry."""
    m_i = jnp.sum(plan.pro_layer >= 0) * page_bytes
    m_o = jnp.sum(plan.dem_layer >= 0) * page_bytes
    return m_i, m_o
