"""jit-safe page migration between the HBM and host tiers.

The control plane (`repro.serving.engine` / a placement policy) decides
WHAT moves; this module executes a batch of moves inside jit with
static shapes: both directions take fixed-size index arrays padded with
-1 rows. Padded rows are routed to out-of-bounds indices and dropped by
the scatter (`mode="drop"`) — NOT masked via gather+select, which would
both read stale values and collide on duplicate clamped indices.

On a real TPU the two pools live in different `memory_kind`s and XLA
lowers the cross-pool scatter into DMA transfers over the host link —
the M_i / M_o traffic of Eq. (3)/(4). The byte accounting used by the
simulator and by the engine's telemetry matches 1:1.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kvcache.paged import NO_SLOT, PagedKVCache


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MigrationPlan:
    """Fixed-capacity migration batch. All arrays [M]; -1 rows are no-ops.

    promote: host slot `src` -> hbm slot `dst` (page `logical`)
    demote:  hbm slot `src`  -> host slot `dst`
    Every entry also names the (layer, batch) coordinate.
    """
    pro_layer: jax.Array
    pro_batch: jax.Array
    pro_src: jax.Array      # host slot
    pro_dst: jax.Array      # hbm slot
    pro_logical: jax.Array
    dem_layer: jax.Array
    dem_batch: jax.Array
    dem_src: jax.Array      # hbm slot
    dem_dst: jax.Array      # host slot
    dem_logical: jax.Array

    @classmethod
    def empty(cls, capacity: int) -> "MigrationPlan":
        z = jnp.full((capacity,), -1, jnp.int32)
        return cls(*([z] * 10))

    @classmethod
    def build(cls, capacity: int, promotes, demotes) -> "MigrationPlan":
        """promotes/demotes: iterables of (layer, batch, src, dst, logical).

        `capacity` must be a per-geometry constant (see
        `repro.serving.control.plan_capacity`), NOT derived from the
        number of rows — a row-count capacity gives `apply_migrations`
        a different traced shape on nearly every step and recompiles it
        for each distinct promote/demote count.
        """
        import numpy as np

        def pack(rows):
            arr = np.full((capacity, 5), -1, np.int32)
            rows = list(rows)[:capacity]
            if rows:
                arr[: len(rows)] = np.asarray(rows, np.int32)
            return [jnp.asarray(arr[:, i]) for i in range(5)]
        return cls(*pack(promotes), *pack(demotes))

    @property
    def capacity(self) -> int:
        return self.pro_layer.shape[0]

    def row_counts(self) -> Tuple[jax.Array, jax.Array]:
        """(n_promotes, n_demotes) actually encoded in the plan — the
        non-sentinel rows. jit-safe; matches the counts a planner
        returned when it built the plan (telemetry cross-check)."""
        return (jnp.sum(self.pro_layer >= 0), jnp.sum(self.dem_layer >= 0))


def _oob(idx, ok, bound):
    """Route masked rows out of bounds (dropped by mode='drop').
    Sentinels must be OOB-HIGH: negative indices wrap NumPy-style."""
    return jnp.where(ok, idx, jnp.int32(bound))


def apply_migrations(cache: PagedKVCache,
                     plan: MigrationPlan) -> PagedKVCache:
    """Execute a migration batch. Shapes are static in `plan`.

    All source pages are gathered from the INPUT pools before any
    scatter runs, so a swap — a demotion whose destination is the host
    slot being vacated by a promotion (``dem_dst == pro_src``) — reads
    the promoted page before the victim overwrites its slot. Owner
    clears likewise land before owner sets, so the swapped slots end up
    owned by the arriving page, not marked free.
    """
    k_hbm, v_hbm = cache.k_hbm, cache.v_hbm
    k_host, v_host = cache.k_host, cache.v_host
    page_table = cache.page_table
    hbm_owner, host_owner = cache.hbm_owner, cache.host_owner
    L = k_hbm.shape[0]
    hbm_pages = k_hbm.shape[2]
    host_pages = k_host.shape[2]
    max_pages = page_table.shape[2]

    # ---- index prep --------------------------------------------------------
    d_ok = plan.dem_layer >= 0
    d_l = _oob(plan.dem_layer, d_ok, L)
    d_b = jnp.maximum(plan.dem_batch, 0)
    d_src = jnp.minimum(jnp.maximum(plan.dem_src, 0), hbm_pages - 1)
    d_dst = _oob(plan.dem_dst, d_ok, host_pages)
    d_logical = _oob(plan.dem_logical, d_ok, max_pages)

    p_ok = plan.pro_layer >= 0
    p_l = _oob(plan.pro_layer, p_ok, L)
    p_b = jnp.maximum(plan.pro_batch, 0)
    p_src = jnp.minimum(jnp.maximum(plan.pro_src, 0), host_pages - 1)
    p_dst = _oob(plan.pro_dst, p_ok, hbm_pages)
    p_logical = _oob(plan.pro_logical, p_ok, max_pages)

    # ---- gather every source page from the input pools ---------------------
    d_lr = jnp.minimum(d_l, L - 1)
    dem_k = k_hbm[d_lr, d_b, d_src]               # [M, T, KH, HD]
    dem_v = v_hbm[d_lr, d_b, d_src]
    p_lr = jnp.minimum(p_l, L - 1)
    pro_k = k_host[p_lr, p_b, p_src]
    pro_v = v_host[p_lr, p_b, p_src]

    # ---- scatter data ------------------------------------------------------
    k_host = k_host.at[d_l, d_b, d_dst].set(dem_k, mode="drop")
    v_host = v_host.at[d_l, d_b, d_dst].set(dem_v, mode="drop")
    k_hbm = k_hbm.at[p_l, p_b, p_dst].set(pro_k, mode="drop")
    v_hbm = v_hbm.at[p_l, p_b, p_dst].set(pro_v, mode="drop")

    # ---- owner maps: clear vacated slots FIRST, then record arrivals -------
    hbm_owner = hbm_owner.at[d_l, d_b, _oob(plan.dem_src, d_ok, hbm_pages)] \
        .set(jnp.full_like(d_src, NO_SLOT), mode="drop")
    hbm_owner = hbm_owner.at[p_l, p_b, p_dst].set(
        jnp.where(p_ok, p_logical, NO_SLOT), mode="drop")
    host_owner = host_owner.at[p_l, p_b, _oob(plan.pro_src, p_ok, host_pages)] \
        .set(jnp.full_like(p_src, NO_SLOT), mode="drop")
    host_owner = host_owner.at[d_l, d_b, d_dst].set(
        jnp.where(d_ok, d_logical, NO_SLOT), mode="drop")

    # ---- page table --------------------------------------------------------
    page_table = page_table.at[d_l, d_b, d_logical].set(
        d_dst + hbm_pages, mode="drop")
    page_table = page_table.at[p_l, p_b, p_logical].set(p_dst, mode="drop")

    return dataclasses.replace(
        cache, k_hbm=k_hbm, v_hbm=v_hbm, k_host=k_host, v_host=v_host,
        page_table=page_table, hbm_owner=hbm_owner, host_owner=host_owner)


def migration_bytes(plan: MigrationPlan, page_bytes: int
                    ) -> Tuple[jax.Array, jax.Array]:
    """(M_i, M_o) bytes for Eq. (3)/(4) telemetry."""
    m_i = jnp.sum(plan.pro_layer >= 0) * page_bytes
    m_o = jnp.sum(plan.dem_layer >= 0) * page_bytes
    return m_i, m_o
