"""jit-safe page migration between the HBM and host tiers.

The control plane (`repro.serving.engine` / a placement policy) decides
WHAT moves; this module executes a batch of moves inside jit with
static shapes: both directions take fixed-size index arrays padded with
-1 rows. Padded rows are routed to out-of-bounds indices and dropped by
the scatter (`mode="drop"`) — NOT masked via gather+select, which would
both read stale values and collide on duplicate clamped indices.

On a real TPU the two pools live in different `memory_kind`s and XLA
lowers the cross-pool scatter into DMA transfers over the host link —
the M_i / M_o traffic of Eq. (3)/(4). The byte accounting used by the
simulator and by the engine's telemetry matches 1:1.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kvcache.paged import NO_SLOT, PagedKVCache


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MigrationPlan:
    """Fixed-capacity migration batch. All arrays [M]; -1 rows are no-ops.

    promote: host slot `src` -> hbm slot `dst` (page `logical`)
    demote:  hbm slot `src`  -> host slot `dst`
    Every entry also names the (layer, batch) coordinate.
    """
    pro_layer: jax.Array
    pro_batch: jax.Array
    pro_src: jax.Array      # host slot
    pro_dst: jax.Array      # hbm slot
    pro_logical: jax.Array
    dem_layer: jax.Array
    dem_batch: jax.Array
    dem_src: jax.Array      # hbm slot
    dem_dst: jax.Array      # host slot
    dem_logical: jax.Array

    @classmethod
    def empty(cls, capacity: int) -> "MigrationPlan":
        z = jnp.full((capacity,), -1, jnp.int32)
        return cls(*([z] * 10))

    @classmethod
    def build(cls, capacity: int, promotes, demotes) -> "MigrationPlan":
        """promotes/demotes: iterables of (layer, batch, src, dst, logical)."""
        import numpy as np

        def pack(rows):
            arr = np.full((capacity, 5), -1, np.int32)
            rows = list(rows)[:capacity]
            if rows:
                arr[: len(rows)] = np.asarray(rows, np.int32)
            return [jnp.asarray(arr[:, i]) for i in range(5)]
        return cls(*pack(promotes), *pack(demotes))

    @property
    def capacity(self) -> int:
        return self.pro_layer.shape[0]


def _oob(idx, ok, bound):
    """Route masked rows out of bounds (dropped by mode='drop').
    Sentinels must be OOB-HIGH: negative indices wrap NumPy-style."""
    return jnp.where(ok, idx, jnp.int32(bound))


def apply_migrations(cache: PagedKVCache,
                     plan: MigrationPlan) -> PagedKVCache:
    """Execute demotions then promotions. Shapes are static in `plan`."""
    k_hbm, v_hbm = cache.k_hbm, cache.v_hbm
    k_host, v_host = cache.k_host, cache.v_host
    page_table = cache.page_table
    hbm_owner, host_owner = cache.hbm_owner, cache.host_owner
    L = k_hbm.shape[0]
    hbm_pages = k_hbm.shape[2]
    host_pages = k_host.shape[2]
    max_pages = page_table.shape[2]

    # ---- demote: HBM slot src -> host slot dst -----------------------------
    ok = plan.dem_layer >= 0
    l = _oob(plan.dem_layer, ok, L)
    b = jnp.maximum(plan.dem_batch, 0)
    src = jnp.minimum(jnp.maximum(plan.dem_src, 0), hbm_pages - 1)
    dst = _oob(plan.dem_dst, ok, host_pages)
    logical = _oob(plan.dem_logical, ok, max_pages)

    l_read = jnp.minimum(l, L - 1)
    page_k = k_hbm[l_read, b, src]                # [M, T, KH, HD]
    page_v = v_hbm[l_read, b, src]
    k_host = k_host.at[l, b, dst].set(page_k, mode="drop")
    v_host = v_host.at[l, b, dst].set(page_v, mode="drop")
    host_owner = host_owner.at[l, b, dst].set(
        jnp.where(ok, logical, NO_SLOT), mode="drop")
    hbm_owner = hbm_owner.at[l, b, _oob(plan.dem_src, ok, hbm_pages)].set(
        jnp.full_like(src, NO_SLOT), mode="drop")
    page_table = page_table.at[l, b, logical].set(
        dst + hbm_pages, mode="drop")

    # ---- promote: host slot src -> hbm slot dst ----------------------------
    ok = plan.pro_layer >= 0
    l = _oob(plan.pro_layer, ok, L)
    b = jnp.maximum(plan.pro_batch, 0)
    src = jnp.minimum(jnp.maximum(plan.pro_src, 0), host_pages - 1)
    dst = _oob(plan.pro_dst, ok, hbm_pages)
    logical = _oob(plan.pro_logical, ok, max_pages)

    l_read = jnp.minimum(l, L - 1)
    page_k = k_host[l_read, b, src]
    page_v = v_host[l_read, b, src]
    k_hbm = k_hbm.at[l, b, dst].set(page_k, mode="drop")
    v_hbm = v_hbm.at[l, b, dst].set(page_v, mode="drop")
    hbm_owner = hbm_owner.at[l, b, dst].set(
        jnp.where(ok, logical, NO_SLOT), mode="drop")
    host_owner = host_owner.at[l, b, _oob(plan.pro_src, ok, host_pages)] \
        .set(jnp.full_like(src, NO_SLOT), mode="drop")
    page_table = page_table.at[l, b, logical].set(dst, mode="drop")

    return dataclasses.replace(
        cache, k_hbm=k_hbm, v_hbm=v_hbm, k_host=k_host, v_host=v_host,
        page_table=page_table, hbm_owner=hbm_owner, host_owner=host_owner)


def migration_bytes(plan: MigrationPlan, page_bytes: int
                    ) -> Tuple[jax.Array, jax.Array]:
    """(M_i, M_o) bytes for Eq. (3)/(4) telemetry."""
    m_i = jnp.sum(plan.pro_layer >= 0) * page_bytes
    m_o = jnp.sum(plan.dem_layer >= 0) * page_bytes
    return m_i, m_o
