from repro.kvcache.paged import (
    PagedKVCache, CacheGeometry, init_cache, append_token, page_of_token,
)

__all__ = ["PagedKVCache", "CacheGeometry", "init_cache", "append_token",
           "page_of_token"]
