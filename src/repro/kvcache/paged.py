"""Two-tier paged KV cache — the paper's technique as a serving feature.

Physical layout (per attention layer, per batch element):

  k_hbm/v_hbm   [L, B, hbm_pages,  page_tokens, KH, HD]   "HBM tier"
  k_host/v_host [L, B, host_pages, page_tokens, KH, HD]   "DRAM tier"

Logical pages are mapped to physical slots by a single page table:

  page_table    [L, B, max_pages] int32 — physical slot of logical page p;
                slot < hbm_pages  -> HBM slot,
                slot >= hbm_pages -> host slot (slot - hbm_pages),
                NO_SLOT (=-1)     -> page not allocated yet.

On real TPU/GPU hardware the host pool is a `memory_kind="pinned_host"`
array and page migration is a device_put between pools; on CPU (tests,
dry-run) both pools are ordinary arrays but the data path — page tables,
tier-split attention, migration traffic accounting — is identical.
`host_memory_kind()` feature-detects pinned host memory so callers can
gate the placement (`init_cache(geo, host_kind=...)`) without baking a
backend assumption into the control plane; the serving engine probes it
once at construction and applies it only under
`EngineConfig.overlap_migrations`, where the staged commit's cross-pool
scatter lowers to an async DMA the decode compute hides.

The control plane (which page lives where) is host-side python in
`repro.serving.engine`; everything in this module is jit-safe.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

NO_SLOT = jnp.int32(-1)

#: EMA decay of the per-page attention-mass importance statistic
#: (`PagedKVCache.importance`), applied by the decode data plane every
#: step. Shared here so the device policies (repro.serving.policies)
#: can derive payback horizons from the same constant the kernel uses.
IMPORTANCE_EMA = 0.25


@dataclasses.dataclass(frozen=True)
class CacheGeometry:
    num_layers: int          # attention layers only
    batch: int
    page_tokens: int
    hbm_pages: int           # per layer per sequence
    host_pages: int
    kv_heads: int
    head_dim: int
    dtype: jnp.dtype = jnp.bfloat16

    @property
    def max_pages(self) -> int:
        return self.hbm_pages + self.host_pages

    @property
    def max_tokens(self) -> int:
        return self.max_pages * self.page_tokens

    def page_bytes(self) -> int:
        return (2 * self.page_tokens * self.kv_heads * self.head_dim
                * jnp.dtype(self.dtype).itemsize)

    @classmethod
    def for_context(cls, *, num_layers: int, batch: int, context: int,
                    kv_heads: int, head_dim: int, page_tokens: int = 16,
                    hbm_fraction: float = 0.25, pad_to: int = 16,
                    dtype=jnp.bfloat16) -> "CacheGeometry":
        """Pool sizes are padded to `pad_to` so the PAGES dim divides the
        model mesh axis (pools are page-sharded when kv_heads doesn't
        divide it — sequence-parallel KV, see launch/shardings.py)."""
        def rnd(x):
            return -(-max(x, 1) // pad_to) * pad_to
        pages = -(-context // page_tokens)
        hbm = rnd(int(round(pages * hbm_fraction)))
        host = rnd(pages - hbm + 1)
        return cls(num_layers=num_layers, batch=batch,
                   page_tokens=page_tokens, hbm_pages=hbm,
                   host_pages=host, kv_heads=kv_heads,
                   head_dim=head_dim, dtype=dtype)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PagedKVCache:
    k_hbm: jax.Array       # [L, B, Ph, T, KH, HD]
    v_hbm: jax.Array
    k_host: jax.Array      # [L, B, Pe, T, KH, HD]
    v_host: jax.Array
    page_table: jax.Array  # [L, B, max_pages] int32 physical slot
    hbm_owner: jax.Array   # [L, B, Ph] int32 logical page at slot (-1 free)
    host_owner: jax.Array  # [L, B, Pe] int32
    length: jax.Array      # [B] int32 tokens currently cached
    importance: jax.Array  # [L, B, max_pages] f32 EMA of attention mass

    @property
    def geometry_like(self) -> Tuple[int, ...]:
        return self.k_hbm.shape

    def tier_lists(self, layer=None, logical_page_mask=None):
        """Kernel operands: per-tier (page_list, page_valid).

        page_list[b, s] = s if slot s is occupied else -1 (the kernel
        streams every pool slot; free slots are masked). page_valid is
        the number of cached tokens that fall inside the owning page.
        Returns arrays for one layer ([B, P]) or all ([L, B, P]).

        logical_page_mask (bool [L, B, max_pages] or [B, max_pages]):
        Quest-style dynamic token bypassing — pages whose mask is False
        are excluded from attention this step (their data stays cached;
        only the read is skipped).
        """
        def lists(owner, mask):
            T = self.k_hbm.shape[3]
            idx = jnp.arange(owner.shape[-1], dtype=jnp.int32)
            occupied = owner >= 0
            if mask is not None:
                sel = jnp.take_along_axis(
                    mask, jnp.maximum(owner, 0), axis=-1)
                occupied = occupied & sel
            plist = jnp.where(occupied, idx, NO_SLOT)
            tokens_before = owner * T
            valid = jnp.clip(self.length[..., :, None] - tokens_before, 0, T)
            valid = jnp.where(occupied, valid, 0).astype(jnp.int32)
            return plist, valid

        ho = self.hbm_owner if layer is None else self.hbm_owner[layer]
        eo = self.host_owner if layer is None else self.host_owner[layer]
        hl, hv = lists(ho, logical_page_mask)
        el, ev = lists(eo, logical_page_mask)
        return hl, hv, el, ev


def host_memory_kind():
    """The pinned host `memory_kind` the default backend advertises, or
    None when it has no distinct host memory space (CPU, and runtimes
    predating memory-kind support).

    The capability gate for `pinned_host`-backed host pools: a positive
    probe means `jax.device_put` between the pools is a real DMA over
    the host link and XLA can overlap it with compute; a None keeps the
    host pool an ordinary device array — bitwise the same data path,
    just without the placement. Pure feature detection, no config."""
    try:
        dev = jax.devices()[0]
        kinds = {m.kind for m in dev.addressable_memories()}
    except Exception:          # old runtimes: no memories() API
        return None
    return "pinned_host" if "pinned_host" in kinds else None


def init_cache(geo: CacheGeometry, *, host_kind=None) -> PagedKVCache:
    """A fresh all-free cache for `geo`.

    `host_kind` (optional, from `host_memory_kind()`): place the host
    pools in that memory kind — `"pinned_host"` on real TPU/GPU puts
    the DRAM tier in pinned host memory so tier crossings are true
    host-link DMAs. None (the CPU/test default) keeps every pool an
    ordinary array; all shapes, dtypes, and values are identical either
    way."""
    L, B, T = geo.num_layers, geo.batch, geo.page_tokens
    kh, hd = geo.kv_heads, geo.head_dim
    shape_h = (L, B, geo.hbm_pages, T, kh, hd)
    shape_e = (L, B, geo.host_pages, T, kh, hd)
    k_host = jnp.zeros(shape_e, geo.dtype)
    v_host = jnp.zeros(shape_e, geo.dtype)
    if host_kind is not None:
        sh = jax.sharding.SingleDeviceSharding(jax.devices()[0],
                                               memory_kind=host_kind)
        k_host = jax.device_put(k_host, sh)
        v_host = jax.device_put(v_host, sh)
    return PagedKVCache(
        k_hbm=jnp.zeros(shape_h, geo.dtype),
        v_hbm=jnp.zeros(shape_h, geo.dtype),
        k_host=k_host,
        v_host=v_host,
        page_table=jnp.full((L, B, geo.max_pages), NO_SLOT, jnp.int32),
        hbm_owner=jnp.full((L, B, geo.hbm_pages), NO_SLOT, jnp.int32),
        host_owner=jnp.full((L, B, geo.host_pages), NO_SLOT, jnp.int32),
        length=jnp.zeros((B,), jnp.int32),
        importance=jnp.zeros((L, B, geo.max_pages), jnp.float32),
    )


def abstract_cache(geo: CacheGeometry) -> PagedKVCache:
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        jax.eval_shape(lambda: init_cache(geo)))


def page_of_token(token_idx, page_tokens: int):
    return token_idx // page_tokens, token_idx % page_tokens


def prefill_cache(geo: CacheGeometry, k: jax.Array, v: jax.Array,
                  length) -> PagedKVCache:
    """Populate a cache from prefill K/V (static placement: HBM first).

    k, v: [L, B, S, KH, HD] with RoPE already applied to k.
    length: int or [B] — prompt tokens actually valid (<= S).
    Logical page p maps to HBM slot p while p < hbm_pages, then host
    slot p - hbm_pages — exactly the paper's Static Placement; dynamic
    policies migrate afterwards.
    """
    L, B, S = k.shape[0], k.shape[1], k.shape[2]
    T = geo.page_tokens
    n_pages = -(-S // T)
    assert n_pages <= geo.max_pages, (n_pages, geo.max_pages)
    pad = n_pages * T - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    kp = k.reshape(L, B, n_pages, T, geo.kv_heads, geo.head_dim)
    vp = v.reshape(L, B, n_pages, T, geo.kv_heads, geo.head_dim)

    cache = init_cache(geo)
    n_h = min(n_pages, geo.hbm_pages)
    k_hbm = cache.k_hbm.at[:, :, :n_h].set(kp[:, :, :n_h].astype(geo.dtype))
    v_hbm = cache.v_hbm.at[:, :, :n_h].set(vp[:, :, :n_h].astype(geo.dtype))
    n_e = n_pages - n_h
    if n_e > 0:
        k_host = cache.k_host.at[:, :, :n_e].set(
            kp[:, :, n_h:].astype(geo.dtype))
        v_host = cache.v_host.at[:, :, :n_e].set(
            vp[:, :, n_h:].astype(geo.dtype))
    else:
        k_host, v_host = cache.k_host, cache.v_host

    pages = jnp.arange(geo.max_pages, dtype=jnp.int32)
    table = jnp.where(pages < n_pages, pages, NO_SLOT)
    page_table = jnp.broadcast_to(table, (geo.num_layers, B, geo.max_pages))

    hslots = jnp.arange(geo.hbm_pages, dtype=jnp.int32)
    hbm_owner = jnp.where(hslots < n_h, hslots, NO_SLOT)
    hbm_owner = jnp.broadcast_to(hbm_owner, (geo.num_layers, B,
                                             geo.hbm_pages))
    eslots = jnp.arange(geo.host_pages, dtype=jnp.int32)
    host_owner = jnp.where(eslots < n_e, eslots + n_h, NO_SLOT)
    host_owner = jnp.broadcast_to(host_owner, (geo.num_layers, B,
                                               geo.host_pages))

    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))
    return PagedKVCache(
        k_hbm=k_hbm, v_hbm=v_hbm, k_host=k_host, v_host=v_host,
        page_table=page_table, hbm_owner=hbm_owner, host_owner=host_owner,
        length=length, importance=cache.importance)


# ---------------------------------------------------------------------------
# jit-safe cache mutation primitives (operate on ONE layer slice)
# ---------------------------------------------------------------------------

def write_token_layer(k_hbm_l, v_hbm_l, k_host_l, v_host_l, slot, offset,
                      k_new, v_new):
    """Write one token's (k, v) into physical page `slot` at `offset`.

    Shapes: pools [B, P, T, KH, HD]; slot/offset [B] int32;
    k_new/v_new [B, KH, HD]. slot >= hbm_pages addresses the host pool.
    """
    hbm_pages = k_hbm_l.shape[1]
    host_pages = k_host_l.shape[1]
    in_hbm = slot < hbm_pages
    # masked-out writes use an out-of-range index and mode="drop": one
    # [B,KH,HD] scatter per pool, no gather+select round-trip of the
    # full pool (that pattern lowers to full-pool traffic). NOTE: the
    # sentinel must be OOB-high — negative indices wrap NumPy-style
    # before the scatter and would hit the last page.
    host_slot = jnp.where(~in_hbm, slot - hbm_pages,
                          jnp.int32(host_pages))
    hbm_slot = jnp.where(in_hbm, slot, jnp.int32(hbm_pages))

    def upd(pool, s, val):
        b = pool.shape[0]
        bidx = jnp.arange(b)
        return pool.at[bidx, s, offset].set(val.astype(pool.dtype),
                                            mode="drop")

    k_hbm_l = upd(k_hbm_l, hbm_slot, k_new)
    v_hbm_l = upd(v_hbm_l, hbm_slot, v_new)
    k_host_l = upd(k_host_l, host_slot, k_new)
    v_host_l = upd(v_host_l, host_slot, v_new)
    return k_hbm_l, v_hbm_l, k_host_l, v_host_l


def write_tokens_layer(k_hbm_l, v_hbm_l, k_host_l, v_host_l, slot, offset,
                       k_new, v_new, valid):
    """Write a slice of tokens' (k, v) into physical pages (one layer).

    The chunked-prefill generalization of `write_token_layer`: pools
    [B, P, T, KH, HD]; slot/offset/valid [B, C] int32/bool; k_new/v_new
    [B, C, KH, HD]. slot >= hbm_pages addresses the host pool; entries
    with valid == False scatter to an OOB-high sentinel and are dropped
    (partial-page appends: a slice may start and end mid-page, and may
    straddle page and tier boundaries).
    """
    hbm_pages = k_hbm_l.shape[1]
    host_pages = k_host_l.shape[1]
    in_hbm = valid & (slot < hbm_pages)
    in_host = valid & (slot >= hbm_pages)
    hbm_slot = jnp.where(in_hbm, slot, jnp.int32(hbm_pages))
    host_slot = jnp.where(in_host, slot - hbm_pages, jnp.int32(host_pages))

    def upd(pool, s, val):
        bidx = jnp.arange(pool.shape[0])[:, None]
        return pool.at[bidx, s, offset].set(val.astype(pool.dtype),
                                            mode="drop")

    k_hbm_l = upd(k_hbm_l, hbm_slot, k_new)
    v_hbm_l = upd(v_hbm_l, hbm_slot, v_new)
    k_host_l = upd(k_host_l, host_slot, k_new)
    v_host_l = upd(v_host_l, host_slot, v_new)
    return k_hbm_l, v_hbm_l, k_host_l, v_host_l


def allocate_prompt_pages(cache: PagedKVCache, pos: jax.Array,
                          valid: jax.Array, n_new: jax.Array
                          ) -> PagedKVCache:
    """Register the logical pages receiving a prompt slice and bump
    lane lengths (chunked prefill at an offset).

    pos/valid: [B, C] absolute token positions and their validity;
    n_new: [B] tokens actually consumed per lane (0 for lanes not
    prefilling). Placement is the paper's Static Placement — logical
    page p maps to HBM slot p while p < hbm_pages, else host slot
    p - hbm_pages — exactly what `prefill_cache` produces, so a prompt
    prefilled chunk-by-chunk lands in the same physical slots as a
    whole-prompt prefill (the migration planner takes over only once
    the lane starts decoding). Half-filled pages are registered in the
    owner maps immediately, so occupancy telemetry and write-slot
    choice see them as resident ("placement-visible")."""
    T = cache.k_hbm.shape[3]
    hbm_pages = cache.k_hbm.shape[2]
    host_pages = cache.k_host.shape[2]
    L = cache.page_table.shape[0]
    max_pages = cache.page_table.shape[2]
    B, C = pos.shape
    page = (pos // T).astype(jnp.int32)
    lidx = jnp.arange(L)[:, None, None]
    bidx = jnp.arange(B)[None, :, None]

    pidx = jnp.where(valid, page, max_pages)[None]
    page_table = cache.page_table.at[lidx, bidx, pidx].set(
        page[None], mode="drop")
    hslot = jnp.where(valid & (page < hbm_pages), page, hbm_pages)[None]
    hbm_owner = cache.hbm_owner.at[lidx, bidx, hslot].set(
        page[None], mode="drop")
    eslot = jnp.where(valid & (page >= hbm_pages), page - hbm_pages,
                      host_pages)[None]
    host_owner = cache.host_owner.at[lidx, bidx, eslot].set(
        page[None], mode="drop")
    return dataclasses.replace(
        cache, page_table=page_table, hbm_owner=hbm_owner,
        host_owner=host_owner,
        length=cache.length + n_new.astype(cache.length.dtype))


def append_token(cache: PagedKVCache, k_new: jax.Array, v_new: jax.Array,
                 write_slot: jax.Array, write_offset: jax.Array
                 ) -> PagedKVCache:
    """Append one token's KV across all layers.

    k_new/v_new: [L, B, KH, HD]; write_slot: [L, B] physical page slot
    chosen by the control plane; write_offset: [B] offset within page.
    """
    def per_layer(args):
        kh, vh, ke, ve, kn, vn, slot = args
        return write_token_layer(kh, vh, ke, ve, slot, write_offset, kn, vn)

    kh, vh, ke, ve = jax.lax.map(
        per_layer, (cache.k_hbm, cache.v_hbm, cache.k_host, cache.v_host,
                    k_new, v_new, write_slot))
    return dataclasses.replace(cache, k_hbm=kh, v_hbm=vh, k_host=ke,
                               v_host=ve, length=cache.length + 1)
