"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization; tests
and benches must keep seeing 1 device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The 256-chip (`data`, `model`) pod mesh — or, with
    `multi_pod`, the 512-chip (`pod`, `data`, `model`) twin-pod one
    the dry-run cost tables assume."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Small (`data`, `model`) mesh over however many devices exist.

    On a CPU-only box, `XLA_FLAGS=--xla_force_host_platform_device_count=N`
    (set BEFORE jax initializes) fakes N host devices — how CI and the
    README's "Scaling out" quickstart exercise the sharded serve loop
    without accelerators."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    """{axis name: size}, e.g. {"data": 2, "model": 2}."""
    return dict(mesh.shape)
