"""Distributed training driver.

Wires the full runtime: mesh + shardings + data pipeline + train step +
checkpoint manager (async save, auto-resume, elastic restore). Usable
on one CPU host (reduced config) and, unmodified, on a TPU slice (the
mesh builder reads the real device topology there).

  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --smoke --steps 50 --ckpt-dir /tmp/ck
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.launch import shardings as shd
from repro.launch.mesh import make_test_mesh
from repro.models import layers as layers_mod
from repro.models.model import Model
from repro.training.train_step import (
    TrainState, init_train_state, make_train_step)
from repro.training.optimizer import AdamWState


def main():
    """CLI driver: short training run on the smoke or full config."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", type=int, default=1, help="data mesh axis")
    ap.add_argument("--model", type=int, default=1, help="model mesh axis")
    args = ap.parse_args()

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    model = Model(cfg)
    mesh = make_test_mesh(args.data, args.model)
    layers_mod.set_activation_batch_axes(
        shd.batch_axes(mesh, args.batch))

    pshard = shd.param_shardings(model.logical_axes(),
                                 model.abstract_params(), mesh, "train")
    rep = shd.replicated(mesh)
    state_shard = TrainState(
        params=pshard, opt=AdamWState(step=rep, m=pshard, v=pshard))

    step_fn = jax.jit(make_train_step(model, lr=args.lr),
                      in_shardings=(state_shard,
                                    {"tokens": shd.tokens_sharding(
                                        mesh, args.batch)}),
                      out_shardings=(state_shard, rep),
                      donate_argnums=(0,))

    corpus = SyntheticCorpus(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    with mesh:
        state = init_train_state(model, jax.random.key(0))
        start = 0
        if mgr is not None and mgr.latest_step() is not None:
            state = mgr.restore(state)
            start = mgr.latest_step()
            print(f"auto-resumed from step {start}")

        t0 = time.time()
        for i in range(start, args.steps):
            batch = {"tokens": jnp.asarray(corpus.batch(0, i)["tokens"])}
            state, metrics = step_fn(state, batch)
            if (i + 1) % 10 == 0:
                dt = (time.time() - t0) / (i + 1 - start)
                print(f"step {i + 1:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({dt * 1e3:.0f} ms/step)")
            if mgr is not None and (i + 1) % args.ckpt_every == 0:
                mgr.save(i + 1, state)      # async
        if mgr is not None:
            mgr.save(args.steps, state, blocking=True)
    print("done")


if __name__ == "__main__":
    main()
