"""Roofline-term derivation from the compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell — all in seconds:

  compute    = HLO_FLOPs   / (chips * peak_FLOP/s)      [197 TF bf16/chip]
  memory     = HLO_bytes   / (chips * HBM_bw)           [819 GB/s/chip]
  collective = coll_bytes  / (chips * link_bw)          [~50 GB/s/link]

HLO_FLOPs / HLO_bytes come from `compiled.cost_analysis()`.
collective_bytes is NOT in cost_analysis: we parse the optimized HLO
and sum the output-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (output bytes ~= data
moved per chip for these ops; a documented upper bound for all-reduce
which moves 2x in ring form — noted in EXPERIMENTS.md).

Also derives MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) and the
usefulness ratio MODEL_FLOPS / HLO_FLOPs that exposes remat/dispatch
waste.
"""

from __future__ import annotations

import json
import re
from typing import Dict, List

from repro.core.tiers import TPU_V5E_CHIP

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

# matches e.g. "  %x = bf16[16,512]{1,0} all-gather(...)" and tuple results
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+(" + "|".join(_COLL_OPS) + r")[\s(]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_of_hlo(hlo_text: str) -> Dict[str, float]:
    """Sum output bytes per collective op kind over the optimized HLO."""
    out = {k: 0.0 for k in _COLL_OPS}
    out["total"] = 0.0
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        b = _shape_bytes(m.group(1))
        kind = m.group(2)
        # ring all-reduce moves ~2x the buffer; count it as 2x so the
        # collective term is not optimistic for the dominant op
        factor = 2.0 if kind == "all-reduce" else 1.0
        out[kind] += b * factor
        out["total"] += b * factor
    return out


def roofline_terms(rec: dict, chip=TPU_V5E_CHIP) -> dict:
    """rec: one dryrun_results.jsonl record -> roofline terms (seconds).

    flops/bytes/collectives are PER-DEVICE module costs (the SPMD module
    is per-device), trip-count weighted by repro.launch.hlo_cost."""
    n = rec["devices"]
    flops = rec["flops_per_device"]
    bytes_acc = rec["bytes_per_device"]
    coll = rec["collective_bytes_per_device"]["total"]
    compute_s = flops / chip.peak_flops_bf16
    memory_s = bytes_acc / chip.hbm_bw
    collective_s = coll / chip.ici_bw
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]

    # MODEL_FLOPS: 6ND for training, 2ND per generated/processed token
    # for inference (forward only)
    n_active = rec["active_params"]
    tokens = rec["batch"] * (rec["seq"] if rec["kind"] != "decode" else 1)
    mult = 6 if rec["kind"] == "train" else 2
    model_flops = mult * n_active * tokens          # global useful FLOPs
    useful = (model_flops / n) / flops if flops > 0 else 0.0

    bound_s = max(compute_s, memory_s, collective_s)
    roofline_fraction = (model_flops / (n * chip.peak_flops_bf16)) / bound_s \
        if bound_s > 0 else 0.0

    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops": flops,
        "useful_flops_ratio": useful,
        "roofline_fraction": roofline_fraction,
    }


def load_results(path: str = "dryrun_results.jsonl") -> List[dict]:
    """Load dry-run records, keeping the last one per (arch, shape, mesh)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    # keep last record per cell
    dedup = {}
    for r in out:
        dedup[(r["arch"], r["shape"], r["mesh"])] = r
    return list(dedup.values())


def table(path: str = "dryrun_results.jsonl") -> str:
    """Render the roofline terms of every cell as an aligned text table."""
    rows = []
    header = (f"{'arch':26s} {'shape':12s} {'mesh':6s} {'dom':10s} "
              f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
              f"{'useful':>7s} {'roofl%':>7s}")
    rows.append(header)
    rows.append("-" * len(header))
    for r in sorted(load_results(path),
                    key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r.get("status") != "ok":
            rows.append(f"{r['arch']:26s} {r['shape']:12s} "
                        f"{r.get('mesh', '-'):6s} {r['status'].upper()}"
                        + (f" ({r.get('reason', '')[:60]})"
                           if r.get("reason") else ""))
            continue
        t = roofline_terms(r)
        rows.append(
            f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:6s} "
            f"{t['dominant']:10s} {t['compute_s']:10.2e} "
            f"{t['memory_s']:10.2e} {t['collective_s']:10.2e} "
            f"{t['useful_flops_ratio']:7.2f} "
            f"{100 * t['roofline_fraction']:6.1f}%")
    return "\n".join(rows)


if __name__ == "__main__":
    import sys
    print(table(sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.jsonl"))
