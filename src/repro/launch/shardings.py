"""Logical-axis -> mesh-axis sharding rules (divisibility-aware).

One rules engine covers every architecture. Per parameter, each mesh
axis claims at most one tensor dim, chosen by a priority list over the
logical axis names, skipping dims whose size is not divisible by the
mesh axis (GSPMD supports uneven shardings via padding, but divisible
placements avoid the padding waste — the non-divisible cases, e.g.
llama4's 40 heads or granite-moe's 40 experts on a 16-way model axis,
fall through to the next-priority dim and are called out in
EXPERIMENTS.md §Roofline as hillclimb candidates).

Modes:
  train — TP over `model` + FSDP over `data` (embed dim), batch over
          (`pod`, `data`);
  serve — TP over `model`, params replicated over `data`/`pod`, batch
          over `data` (and `pod` when multi-pod).

The serve loop's mesh surface lives here too: `cache_shardings` (the
two-tier paged pools), `policy_state_shardings` (per-lane policy state
threaded through the serve scan), and `serve_shardings` (the bundle of
per-lane / per-step specs `ServingEngine` pins on its fused serve
chunk). All rules read only `mesh.axis_names` + `mesh.shape`, so they
work with an `AbstractMesh` (and are unit-testable without devices —
tests/test_shardings.py).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# priority of logical names for the model (TP/EP) axis
_MODEL_PRIORITY = ("experts", "heads", "kv_heads", "mlp", "vocab",
                   "head_dim", "embed")
# priority for the data (FSDP) axis — train mode only
_FSDP_PRIORITY = ("embed", "vocab", "mlp")


def _axis_sizes(mesh) -> Dict[str, int]:
    """{axis name: size} from any Mesh-like (`Mesh`, `AbstractMesh`,
    or a test stub exposing `.shape` as a name->size mapping)."""
    return dict(mesh.shape)


def _pick_dim(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
              priority, mesh_size: int, taken: set) -> Optional[int]:
    for name in priority:
        for dim, ax in enumerate(axes):
            if ax == name and dim not in taken and \
                    shape[dim] % mesh_size == 0 and shape[dim] >= mesh_size:
                return dim
    return None


def param_pspec(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
                mesh: Mesh, mode: str = "train") -> P:
    """PartitionSpec for one parameter from its logical axis names.

    The `model` axis claims the highest-priority divisible dim
    (`_MODEL_PRIORITY`); in train mode `data` then claims an FSDP dim
    from the remainder. Serve mode replicates over `data`/`pod`."""
    sizes = _axis_sizes(mesh)
    spec = [None] * len(shape)
    taken: set = set()
    if "model" in sizes and sizes["model"] > 1:
        d = _pick_dim(axes, shape, _MODEL_PRIORITY, sizes["model"], taken)
        if d is not None:
            spec[d] = "model"
            taken.add(d)
    if mode == "train" and "data" in sizes and sizes["data"] > 1:
        d = _pick_dim(axes, shape, _FSDP_PRIORITY, sizes["data"], taken)
        if d is not None:
            spec[d] = "data"
            taken.add(d)
    return P(*spec)


def param_shardings(schema_axes: Any, abstract: Any, mesh: Mesh,
                    mode: str = "train") -> Any:
    """Map trees of (logical axes, ShapeDtypeStruct) -> NamedSharding."""
    def one(axes, leaf):
        return NamedSharding(mesh, param_pspec(axes, leaf.shape, mesh, mode))
    return jax.tree.map(one, schema_axes, abstract,
                        is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# Activation / batch / state shardings
# ---------------------------------------------------------------------------

def batch_axes(mesh: Mesh, batch: Optional[int] = None) -> Tuple[str, ...]:
    """Batch mesh axes: the WIDEST suffix of (`pod`, `data`) whose size
    product divides `batch`.

    Degrades axis by axis rather than all-or-nothing: a batch that
    divides the `data` axis but not `pod`×`data` still shards over
    `data` alone (replicating over `pod`) instead of replicating
    everywhere; only a batch no axis divides (e.g. long_500k's
    global_batch=1) drops to full replication. `batch=None` trusts the
    caller and returns every batch axis."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if batch is None:
        return axes
    sizes = _axis_sizes(mesh)
    for start in range(len(axes) + 1):
        cand = axes[start:]
        total = 1
        for a in cand:
            total *= sizes[a]
        if batch % total == 0 and batch >= total:
            return cand
    return ()


def tokens_sharding(mesh: Mesh, batch: Optional[int] = None
                    ) -> NamedSharding:
    """[B, S] token ids: batch-sharded rows, replicated positions."""
    return NamedSharding(mesh, P(batch_axes(mesh, batch), None))


def logits_sharding(mesh: Mesh, vocab: int,
                    batch: Optional[int] = None) -> NamedSharding:
    """[B, V] logits: batch rows + vocab over `model` when divisible."""
    sizes = _axis_sizes(mesh)
    v = "model" if vocab % sizes.get("model", 1) == 0 else None
    return NamedSharding(mesh, P(batch_axes(mesh, batch), v))


def _kv_shard_axis(geo, mesh: Mesh) -> str:
    """Which pool dim carries the model axis.

    kv_heads when divisible (classic TP);
    otherwise PAGES — the LSE merge over pages is associative, so
    page-sharding is exact sequence-parallel attention and keeps every
    chip busy even when kv_heads < model parallelism (llama4/qwen3-class
    GQA with kv=8 on a 16-way axis). Geometry pads pool sizes to 16.
    """
    sizes = _axis_sizes(mesh)
    m = sizes.get("model", 1)
    if geo.kv_heads % m == 0:
        return "kv_heads"
    if geo.hbm_pages % m == 0 and geo.host_pages % m == 0:
        return "pages"
    return "none"


def cache_shardings(geo, mesh: Mesh) -> Any:
    """Shardings for a PagedKVCache pytree.

    Pools [L, B, P, T, KH, HD]: batch over data(/pod); model axis on
    kv_heads or pages per `_kv_shard_axis`. Owner/valid tables follow
    the pools' pages dim so tier_lists stays fully local.
    """
    from repro.kvcache.paged import PagedKVCache
    b_ax = batch_axes(mesh, getattr(geo, "batch", None))
    ax = _kv_shard_axis(geo, mesh)
    kh = "model" if ax == "kv_heads" else None
    pg = "model" if ax == "pages" else None
    pool = NamedSharding(mesh, P(None, b_ax, pg, None, kh, None))
    owner = NamedSharding(mesh, P(None, b_ax, pg))
    table = NamedSharding(mesh, P(None, b_ax, None))
    vec = NamedSharding(mesh, P(b_ax))
    return PagedKVCache(
        k_hbm=pool, v_hbm=pool, k_host=pool, v_host=pool,
        page_table=table, hbm_owner=owner, host_owner=owner,
        length=vec, importance=table)


def policy_state_shardings(state: Any, geo, mesh: Mesh) -> Any:
    """Shardings for a `DevicePolicy.init_state` pytree.

    Policy state rides the serve scan next to the cache, so its lanes
    must co-shard with the cache's lanes: leaves shaped like the page
    table ([L, B, ...], e.g. recency's last-access stamps) take the
    batch axes on dim 1, per-lane [B] vectors take them on dim 0, and
    everything else (cost_aware's scalar payback bars, `()` for the
    stateless policies) replicates. Leaves may be concrete arrays or
    `ShapeDtypeStruct`s."""
    b_ax = batch_axes(mesh, geo.batch)

    def one(leaf):
        shape = leaf.shape
        if len(shape) >= 2 and shape[0] == geo.num_layers \
                and shape[1] == geo.batch:
            return NamedSharding(
                mesh, P(None, b_ax, *([None] * (len(shape) - 2))))
        if len(shape) == 1 and shape[0] == geo.batch:
            return NamedSharding(mesh, P(b_ax))
        return NamedSharding(mesh, P())
    return jax.tree.map(one, state)


def serve_shardings(geo, mesh: Mesh) -> Dict[str, Any]:
    """The sharding bundle `ServingEngine` pins on its fused serve
    chunk (EXPERIMENTS.md §Mesh-sharding has the full rules table).

      cache      PagedKVCache pytree (`cache_shardings`)
      lane       per-lane [B] carries (token/active/remaining/...)
      lane_kv    per-lane 2-D rows ([B, 2] PRNG keys, [B, S] prompts)
      step_lane  per-(step, lane) [stride, B] fault masks + emissions
      rep        replicated scalars/vectors (prefill credits, commit
                 caps — the fault plane is global, not per-shard)
      plan       the staged MigrationPlan carry (overlap mode): ten
                 small [M] int32 rows, replicated — every shard must
                 see the whole plan because revalidation reads owner
                 maps that may live on other shards' page ranges

    Lane axes come from `batch_axes(mesh, geo.batch)`, so a lane count
    the data axis doesn't divide degrades to replication (values
    unchanged, just no data-parallel speedup)."""
    from repro.kvcache.migrate import MigrationPlan
    b_ax = batch_axes(mesh, geo.batch)
    rep = NamedSharding(mesh, P())
    return {
        "cache": cache_shardings(geo, mesh),
        "lane": NamedSharding(mesh, P(b_ax)),
        "lane_kv": NamedSharding(mesh, P(b_ax, None)),
        "step_lane": NamedSharding(mesh, P(None, b_ax)),
        "rep": rep,
        "plan": MigrationPlan(*([rep] * 10)),
    }


def ssm_state_shardings(state: Any, mesh: Mesh) -> Any:
    """Recurrent states: batch over data; heads over model if divisible."""
    sizes = _axis_sizes(mesh)
    m = sizes.get("model", 1)

    def one(leaf):
        # state leaves are [L, B, ...]; try to shard a trailing dim on
        # model if divisible
        b_ax = batch_axes(mesh, leaf.shape[1] if leaf.ndim > 1 else None)
        spec = [None, b_ax] + [None] * (leaf.ndim - 2)
        for dim in range(2, leaf.ndim):
            if leaf.shape[dim] % m == 0 and leaf.shape[dim] >= m:
                spec[dim] = "model"
                break
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(one, state)


def replicated(mesh: Mesh) -> NamedSharding:
    """Fully replicated placement (every device holds the whole array)."""
    return NamedSharding(mesh, P())


def state_shardings_for(model, state_abs: Any, mesh: Mesh) -> Any:
    """Shardings matching Model.init_decode_state / prefill output."""
    from repro.kvcache.paged import PagedKVCache
    if isinstance(state_abs, PagedKVCache):
        geo = _geo_of(model, state_abs)
        return cache_shardings(geo, mesh)
    if isinstance(state_abs, dict):
        out = {}
        for k, v in state_abs.items():
            if k == "kv":
                out[k] = cache_shardings(_geo_of(model, v), mesh)
            elif k == "enc":
                out[k] = NamedSharding(
                    mesh, P(batch_axes(mesh, v.shape[0]), None, None))
            else:
                out[k] = ssm_state_shardings(v, mesh)
        return out
    return ssm_state_shardings(state_abs, mesh)


def _geo_of(model, cache_abs):
    """Recover a geometry-like view from an abstract cache."""
    import dataclasses

    @dataclasses.dataclass
    class _G:
        kv_heads: int
        head_dim: int
        hbm_pages: int
        host_pages: int
        batch: int
    L, B, Ph, T, KH, HD = cache_abs.k_hbm.shape
    return _G(kv_heads=KH, head_dim=HD, hbm_pages=Ph,
              host_pages=cache_abs.k_host.shape[2], batch=B)
