"""Logical-axis -> mesh-axis sharding rules (divisibility-aware).

One rules engine covers every architecture. Per parameter, each mesh
axis claims at most one tensor dim, chosen by a priority list over the
logical axis names, skipping dims whose size is not divisible by the
mesh axis (GSPMD supports uneven shardings via padding, but divisible
placements avoid the padding waste — the non-divisible cases, e.g.
llama4's 40 heads or granite-moe's 40 experts on a 16-way model axis,
fall through to the next-priority dim and are called out in
EXPERIMENTS.md §Roofline as hillclimb candidates).

Modes:
  train — TP over `model` + FSDP over `data` (embed dim), batch over
          (`pod`, `data`);
  serve — TP over `model`, params replicated over `data`/`pod`, batch
          over `data` (and `pod` when multi-pod).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# priority of logical names for the model (TP/EP) axis
_MODEL_PRIORITY = ("experts", "heads", "kv_heads", "mlp", "vocab",
                   "head_dim", "embed")
# priority for the data (FSDP) axis — train mode only
_FSDP_PRIORITY = ("embed", "vocab", "mlp")


def _pick_dim(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
              priority, mesh_size: int, taken: set) -> Optional[int]:
    for name in priority:
        for dim, ax in enumerate(axes):
            if ax == name and dim not in taken and \
                    shape[dim] % mesh_size == 0 and shape[dim] >= mesh_size:
                return dim
    return None


def param_pspec(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
                mesh: Mesh, mode: str = "train") -> P:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = [None] * len(shape)
    taken: set = set()
    if "model" in sizes and sizes["model"] > 1:
        d = _pick_dim(axes, shape, _MODEL_PRIORITY, sizes["model"], taken)
        if d is not None:
            spec[d] = "model"
            taken.add(d)
    if mode == "train" and "data" in sizes and sizes["data"] > 1:
        d = _pick_dim(axes, shape, _FSDP_PRIORITY, sizes["data"], taken)
        if d is not None:
            spec[d] = "data"
            taken.add(d)
    return P(*spec)


def param_shardings(schema_axes: Any, abstract: Any, mesh: Mesh,
                    mode: str = "train") -> Any:
    """Map trees of (logical axes, ShapeDtypeStruct) -> NamedSharding."""
    def one(axes, leaf):
        return NamedSharding(mesh, param_pspec(axes, leaf.shape, mesh, mode))
    return jax.tree.map(one, schema_axes, abstract,
                        is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# Activation / batch / state shardings
# ---------------------------------------------------------------------------

def batch_axes(mesh: Mesh, batch: Optional[int] = None) -> Tuple[str, ...]:
    """Batch mesh axes, dropped entirely when the batch is too small to
    shard (e.g. long_500k's global_batch=1 replicates over data)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if batch is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        total = 1
        for a in axes:
            total *= sizes[a]
        if batch % total != 0 or batch < total:
            return ()
    return axes


def tokens_sharding(mesh: Mesh, batch: Optional[int] = None
                    ) -> NamedSharding:
    return NamedSharding(mesh, P(batch_axes(mesh, batch), None))


def logits_sharding(mesh: Mesh, vocab: int,
                    batch: Optional[int] = None) -> NamedSharding:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    v = "model" if vocab % sizes.get("model", 1) == 0 else None
    return NamedSharding(mesh, P(batch_axes(mesh, batch), v))


def _kv_shard_axis(geo, mesh: Mesh) -> str:
    """Which pool dim carries the model axis.

    kv_heads when divisible (classic TP);
    otherwise PAGES — the LSE merge over pages is associative, so
    page-sharding is exact sequence-parallel attention and keeps every
    chip busy even when kv_heads < model parallelism (llama4/qwen3-class
    GQA with kv=8 on a 16-way axis). Geometry pads pool sizes to 16.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes.get("model", 1)
    if geo.kv_heads % m == 0:
        return "kv_heads"
    if geo.hbm_pages % m == 0 and geo.host_pages % m == 0:
        return "pages"
    return "none"


def cache_shardings(geo, mesh: Mesh) -> Any:
    """Shardings for a PagedKVCache pytree.

    Pools [L, B, P, T, KH, HD]: batch over data(/pod); model axis on
    kv_heads or pages per `_kv_shard_axis`. Owner/valid tables follow
    the pools' pages dim so tier_lists stays fully local.
    """
    from repro.kvcache.paged import PagedKVCache
    b_ax = batch_axes(mesh, getattr(geo, "batch", None))
    ax = _kv_shard_axis(geo, mesh)
    kh = "model" if ax == "kv_heads" else None
    pg = "model" if ax == "pages" else None
    pool = NamedSharding(mesh, P(None, b_ax, pg, None, kh, None))
    owner = NamedSharding(mesh, P(None, b_ax, pg))
    table = NamedSharding(mesh, P(None, b_ax, None))
    vec = NamedSharding(mesh, P(b_ax))
    return PagedKVCache(
        k_hbm=pool, v_hbm=pool, k_host=pool, v_host=pool,
        page_table=table, hbm_owner=owner, host_owner=owner,
        length=vec, importance=table)


def ssm_state_shardings(state: Any, mesh: Mesh) -> Any:
    """Recurrent states: batch over data; heads over model if divisible."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes.get("model", 1)

    def one(leaf):
        # state leaves are [L, B, ...]; try to shard a trailing dim on
        # model if divisible
        b_ax = batch_axes(mesh, leaf.shape[1] if leaf.ndim > 1 else None)
        spec = [None, b_ax] + [None] * (leaf.ndim - 2)
        for dim in range(2, leaf.ndim):
            if leaf.shape[dim] % m == 0 and leaf.shape[dim] >= m:
                spec[dim] = "model"
                break
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(one, state)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def state_shardings_for(model, state_abs: Any, mesh: Mesh) -> Any:
    """Shardings matching Model.init_decode_state / prefill output."""
    from repro.kvcache.paged import PagedKVCache
    if isinstance(state_abs, PagedKVCache):
        geo = _geo_of(model, state_abs)
        return cache_shardings(geo, mesh)
    if isinstance(state_abs, dict):
        out = {}
        for k, v in state_abs.items():
            if k == "kv":
                out[k] = cache_shardings(_geo_of(model, v), mesh)
            elif k == "enc":
                out[k] = NamedSharding(
                    mesh, P(batch_axes(mesh, v.shape[0]), None, None))
            else:
                out[k] = ssm_state_shardings(v, mesh)
        return out
    return ssm_state_shardings(state_abs, mesh)


def _geo_of(model, cache_abs):
    """Recover a geometry-like view from an abstract cache."""
    import dataclasses

    @dataclasses.dataclass
    class _G:
        kv_heads: int
        head_dim: int
        hbm_pages: int
        host_pages: int
        batch: int
    L, B, Ph, T, KH, HD = cache_abs.k_hbm.shape
    return _G(kv_heads=KH, head_dim=HD, hbm_pages=Ph,
              host_pages=cache_abs.k_host.shape[2], batch=B)
