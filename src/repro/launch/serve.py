"""Serving driver: two-tier paged-KV engine behind a continuous batcher.

  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
      --smoke --policy importance --sparsity 0.6 --requests 8
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.tiers import SPECS
from repro.models.model import Model
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.scheduler import ContinuousBatcher, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--policy", default="importance",
                    choices=["static", "importance"])
    ap.add_argument("--sparsity", type=float, default=0.0)
    ap.add_argument("--hbm-fraction", type=float, default=0.25)
    ap.add_argument("--spec", default="gh200", choices=list(SPECS))
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--batch-slots", type=int, default=4)
    args = ap.parse_args()

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    eng = ServingEngine(model, params, EngineConfig(
        max_context=args.prompt_len + args.new_tokens + 32,
        hbm_fraction=args.hbm_fraction, policy=args.policy,
        attention_sparsity=args.sparsity, spec=SPECS[args.spec]))

    cb = ContinuousBatcher(num_slots=args.batch_slots,
                           total_pages=10_000)
    for rid in range(args.requests):
        cb.submit(Request(rid=rid, prompt_len=args.prompt_len,
                          max_new_tokens=args.new_tokens))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch_slots, args.prompt_len)),
        jnp.int32)
    eng.start(prompts)
    tok = jnp.argmax(eng.step(prompts[:, -1]), -1).astype(jnp.int32)
    steps = 1
    while len(cb.completed) < args.requests and steps < 10_000:
        cb.step()
        tok = jnp.argmax(eng.step(tok), -1).astype(jnp.int32)
        steps += 1

    s = eng.summary()
    print(f"served {args.requests} requests in {steps} engine steps")
    print(f"modeled tokens/s: {s['modeled_tokens_per_s']:.0f}  "
          f"hit rate: {s['mean_hbm_hit_rate']:.2f}  "
          f"migrated: {s['migrated_bytes'] / 1e6:.1f} MB")


if __name__ == "__main__":
    main()
