"""Serving entry point: the fused two-tier engine, optionally sharded
across a device mesh.

Single device (the default) and a meshed run drive the SAME
`ServingEngine.serve` loop — the mesh only changes where the arrays
live (EXPERIMENTS.md §Mesh-sharding). On a CPU-only box, fake the
devices with XLA host devices (the flag must be set before jax
initializes, i.e. in the environment, not in code):

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
      PYTHONPATH=src python -m repro.launch.serve --smoke \\
      --mesh data=2,model=2 --requests 6 --new-tokens 8

`--parity` runs the stream twice — unmeshed, then on the mesh — and
checks the contract the tests pin: identical tokens and terminal
statuses, tolerance-close hit/bound fractions, zero retraces under the
mesh. Exit status is the check result, so CI can call it directly.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro import configs
from repro.core.sa import SAConfig
from repro.core.tiers import SPECS
from repro.launch.mesh import make_test_mesh
from repro.models.model import Model
from repro.serving import trace_bridge
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.policies import policy_names
from repro.serving.scheduler import Request


def parse_mesh(spec: str):
    """'data=2,model=2' -> a (data, model) test mesh; '' -> None.

    Raises a SystemExit with the XLA_FLAGS hint when the host has too
    few devices for the requested shape."""
    if not spec:
        return None
    sizes = {"data": 1, "model": 1}
    for part in spec.split(","):
        name, _, val = part.partition("=")
        if name.strip() not in sizes or not val.strip().isdigit():
            raise SystemExit(
                f"--mesh wants 'data=N,model=M', got {spec!r}")
        sizes[name.strip()] = int(val)
    need = sizes["data"] * sizes["model"]
    have = jax.device_count()
    if need > have:
        raise SystemExit(
            f"--mesh {spec} needs {need} devices, found {have}. On a "
            f"CPU host, set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={need} (before jax starts) to fake them.")
    return make_test_mesh(data=sizes["data"], model=sizes["model"])


def build_requests(vocab: int, n: int, prompt_len: int,
                   new_tokens: int, seed: int = 0):
    """A mixed request stream: three page-rounded prompt lengths and
    staggered budgets, so admissions/completions churn lanes."""
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab,
                                        (prompt_len + 16 * (i % 3),)),
                    max_new_tokens=new_tokens + 2 * (i % 3))
            for i in range(n)]


def run_stream(model, params, args, mesh, *, trace: bool = False):
    """Serve one stream; returns (engine, ServeReport, wall seconds)."""
    cfg = EngineConfig(
        max_context=args.prompt_len + 32 + args.new_tokens + 16,
        hbm_fraction=args.hbm_fraction, policy=args.policy,
        attention_sparsity=args.sparsity, spec=SPECS[args.spec],
        telemetry_stride=args.stride, prefill_chunk=16,
        trace_telemetry=trace)
    eng = ServingEngine(model, params, cfg, mesh=mesh)
    reqs = build_requests(model.cfg.vocab, args.requests,
                          args.prompt_len, args.new_tokens)
    t0 = time.perf_counter()
    report = eng.serve(reqs, num_slots=args.batch_slots, seed=args.seed)
    return eng, report, time.perf_counter() - t0


def check_parity(model, params, args, mesh) -> bool:
    """Single-device vs meshed serve over the same stream.

    Pins: identical tokens + terminal statuses per request (greedy
    argmax absorbs the mesh's float-reduction reassociation), zero
    retraces under the mesh, and aggregate hit/bound fractions within
    tolerance (migration choices may flip on ulp-level importance-EMA
    differences, which moves telemetry without touching tokens)."""
    ref_eng, ref, _ = run_stream(model, params, args, None, trace=True)
    mesh_eng, got, _ = run_stream(model, params, args, mesh, trace=True)

    ok = True
    exes = mesh_eng._serve_jit._cache_size()
    if exes != 1:
        print(f"PARITY FAIL: {exes} serve executables under mesh")
        ok = False
    if ref.statuses != got.statuses:
        print(f"PARITY FAIL: statuses {ref.statuses} != {got.statuses}")
        ok = False
    ref_out = {r.rid: list(r.output) for r in ref}
    got_out = {r.rid: list(r.output) for r in got}
    for rid in sorted(ref_out):
        if ref_out[rid] != got_out.get(rid):
            print(f"PARITY FAIL: request {rid} tokens diverge\n"
                  f"  1-device: {ref_out[rid]}\n"
                  f"  meshed:   {got_out.get(rid)}")
            ok = False
    sa_cfg = SAConfig(max_evaluations=6, iters_per_level=2, seed=0)
    spec = SPECS[args.spec]
    frac = {}
    for tag, eng, rep in (("1dev", ref_eng, ref),
                          ("mesh", mesh_eng, got)):
        score = trace_bridge.score_serve(
            trace_bridge.collect_serve(eng), spec, sa_cfg=sa_cfg,
            report=rep)
        agg = score["aggregate"]
        frac[tag] = (agg["live_hit_fraction"],
                     agg.get("bound_fraction", 0.0))
    d_hit = abs(frac["1dev"][0] - frac["mesh"][0])
    d_bound = abs(frac["1dev"][1] - frac["mesh"][1])
    if d_hit > 0.02 or d_bound > 0.05:
        print(f"PARITY FAIL: fractions drift hit={frac['1dev'][0]:.3f}"
              f"/{frac['mesh'][0]:.3f} bound={frac['1dev'][1]:.3f}"
              f"/{frac['mesh'][1]:.3f}")
        ok = False
    if ok:
        print(f"MESH PARITY OK: {len(ref_out)} requests, tokens + "
              f"statuses identical, hit {frac['mesh'][0]:.3f} "
              f"(d={d_hit:.4f}), bound {frac['mesh'][1]:.3f} "
              f"(d={d_bound:.4f}), 1 executable")
    return ok


def main(argv=None) -> int:
    """CLI driver; returns a process exit status."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--policy", default="importance",
                    choices=list(policy_names()))
    ap.add_argument("--sparsity", type=float, default=0.0)
    ap.add_argument("--hbm-fraction", type=float, default=0.25)
    ap.add_argument("--spec", default="gh200", choices=list(SPECS))
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--stride", type=int, default=8,
                    help="fused steps per chunk boundary")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="",
                    help="'data=N,model=M' — serve across a device "
                         "mesh ('' = single device)")
    ap.add_argument("--parity", action="store_true",
                    help="run 1-device AND meshed (default "
                         "data=2,model=2), check tokens/statuses/"
                         "fractions match; exit 1 on divergence")
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    if args.parity:
        mesh = parse_mesh(args.mesh or "data=2,model=2")
        return 0 if check_parity(model, params, args, mesh) else 1

    mesh = parse_mesh(args.mesh)
    eng, report, wall = run_stream(model, params, args, mesh)
    total = sum(len(r.output) for r in report)
    s = eng.summary()
    where = (f"mesh {dict(mesh.shape)}" if mesh is not None
             else "1 device")
    print(f"served {len(report)} requests / {total} tokens on {where} "
          f"in {wall:.2f}s ({total / wall:.1f} tok/s wall)")
    if report.ttft:
        print(f"ttft p50 {report.ttft['p50'] * 1e3:.1f} ms  "
              f"tpot p50 {report.tpot.get('p50', 0.0) * 1e3:.2f} ms")
    print(f"modeled tokens/s {s.get('modeled_tokens_per_s', 0.0):.0f}  "
          f"hbm hit rate {s.get('mean_hbm_hit_rate', 0.0):.2f}  "
          f"serve executables {eng._serve_jit._cache_size()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
