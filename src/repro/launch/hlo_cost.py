"""Trip-count-weighted cost analysis over optimized HLO text.

XLA's built-in `compiled.cost_analysis()` visits a `while` body ONCE, so
any scanned-layers model under-reports FLOPs/bytes by ~num_layers x and
collectives inside the scan are similarly under-counted. The optimized
HLO carries `backend_config={"known_trip_count":{"n":...}}`, so this
module re-derives costs with proper weighting:

  cost(while)  = n * (cost(body) + cost(cond))
  cost(fusion) = flops(called computation)
                 + bytes(fusion operands + outputs)      # fusion boundary
  cost(dot)    = 2 * prod(out) * prod(lhs contracting dims)
  bytes(op)    = operands + outputs, with in-place special cases
                 (dynamic-update-slice counts only the written window)

Collective bytes are weighted the same way (a per-layer all-reduce in a
64-layer scan counts 64x), fixing the §Roofline collective term.
"""

from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

_ELTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "tanh", "log", "rsqrt", "sqrt", "power", "negate",
    "compare", "select", "and", "or", "xor", "abs", "floor", "cosine",
    "sine", "logistic",
}


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    """Total (elements, bytes) across all tensors in a shape string."""
    elems = 0
    byts = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


def _dims_of(shape_str: str) -> List[int]:
    m = re.search(r"\w+\[([\d,]*)\]", shape_str)
    if not m or not m.group(1):
        return []
    return [int(d) for d in m.group(1).split(",")]


class Instr:
    """One parsed HLO instruction: name, shape string, op, operand refs."""

    __slots__ = ("name", "shape", "op", "operands", "line")

    def __init__(self, name, shape, op, operands, line):
        self.name = name
        self.shape = shape
        self.op = op
        self.operands = operands
        self.line = line


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}\/ ]+?))\s+"
    r"([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLED_RE = re.compile(
    r"(?:calls=|to_apply=|body=|condition=)%?([\w.\-]+)")


def parse_module(hlo: str) -> Dict[str, List[Instr]]:
    """Parse optimized HLO text into {computation name: [Instr, ...]}."""
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        stripped = re.sub(r"/\*.*?\*/", "", line).rstrip()
        is_header = (stripped.endswith("{") and "->" in stripped
                     and "=" not in stripped.split("->")[0])
        if is_header:
            hm = re.match(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if hm:
                cur = hm.group(1)
                comps[cur] = []
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, shape, op, rest = m.groups()
            # operands: %refs before any attr like ), key=...
            args_part = rest.split("), ")[0]
            operands = _OPERAND_RE.findall(args_part)
            comps[cur].append(Instr(name, shape.strip(), op, operands, line))
    return comps


class CostResult(dict):
    """Dict subclass reserved for typed cost results (plain dict today)."""


def _root_of(instrs: List[Instr]) -> Optional[Instr]:
    for i in instrs:
        if "ROOT" in i.line.split("=")[0] or i.line.lstrip().startswith(
                "ROOT"):
            return i
    return instrs[-1] if instrs else None


def _fusion_bytes(ins: Instr, table, comps, symtab, called,
                  project: bool) -> float:
    """Boundary bytes for a fusion/call instruction.

    * output: full, unless the fused root is a dynamic-update-slice
      (charge the written window — scan write-back) or, in `project`
      mode, a pure dtype convert (free on TPU: converts fuse into the
      MXU/VPU producers and never round-trip HBM).
    * operands: parameters consumed only via (dynamic-)slice are charged
      at slice size (scan bodies slice one layer of stacked tensors);
      the DUS target parameter is charged via the root rule; in
      `project` mode convert-only uses are free.
    """
    fused = next((c for c in called if c in comps), None)
    out_bytes = _shape_elems_bytes(ins.shape)[1]
    param_charge = {}
    dus_target_pos = []
    if fused is not None:
        params = [i for i in comps[fused] if i.op == "parameter"]
        pname_by_pos = {}
        for p in params:
            pm = re.search(r"parameter\((\d+)\)", p.line)
            if pm:
                pname_by_pos[int(pm.group(1))] = p.name
        pos_by_name = {v: k for k, v in pname_by_pos.items()}
        uses: Dict[str, List[Instr]] = {}
        for i in comps[fused]:
            for o in i.operands:
                uses.setdefault(o, []).append(i)
        ftab = symtab[fused]
        by_name = {i.name: i for i in comps[fused]}
        dus_target_pos = []
        root = _root_of(comps[fused])

        def _dus_out_bytes(dus: Instr) -> float:
            upd = dus.operands[1] if len(dus.operands) > 1 else None
            ub = _shape_elems_bytes(ftab.get(upd, ""))[1] if upd else 0.0
            if dus.operands and dus.operands[0] in pos_by_name:
                dus_target_pos.append(pos_by_name[dus.operands[0]])
            return 2.0 * ub

        if root is not None and root.op == "dynamic-update-slice":
            out_bytes = _dus_out_bytes(root)
        elif root is not None and root.op == "tuple":
            # multi-output fusion (e.g. scan write-backs of several
            # stacked tensors): charge each DUS element at its window
            total = 0.0
            for o in root.operands:
                elem = by_name.get(o)
                if elem is not None and elem.op == "dynamic-update-slice":
                    total += _dus_out_bytes(elem)
                elif elem is not None and project and elem.op == "convert":
                    pass
                else:
                    total += _shape_elems_bytes(
                        ftab.get(o, ""))[1]
            out_bytes = total
        elif project and root is not None and root.op == "convert":
            out_bytes = 0.0
        for pos, pname in pname_by_pos.items():
            us = uses.get(pname, [])
            if not us:
                param_charge[pos] = 0.0
            elif all(u.op in ("dynamic-slice", "slice", "gather")
                     for u in us):
                param_charge[pos] = sum(
                    _shape_elems_bytes(u.shape)[1] for u in us)
            elif project and all(u.op == "convert" for u in us):
                param_charge[pos] = 0.0
        for pos in dus_target_pos:
            param_charge[pos] = 0.0

    total = out_bytes
    for pos, o in enumerate(ins.operands):
        if o not in table:
            continue
        full = _shape_elems_bytes(table[o])[1]
        total += min(param_charge.get(pos, full), full)
    return total


def analyze(hlo: str, detail: bool = False,
            project: bool = True) -> dict:
    """Trip-count-weighted flops/bytes/collectives for an HLO module.

    `project=True` applies the TPU projections documented in the module
    docstring (free converts, sliced fusion operands); `detail=True`
    additionally returns the 25 most expensive weighted instructions."""
    comps = parse_module(hlo)
    # symbol tables per computation (name -> shape string)
    symtab = {c: {i.name: i.shape for i in instrs}
              for c, instrs in comps.items()}
    memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}
    details: List[Tuple[float, str, str, str]] = []

    def comp_cost(cname: str) -> Tuple[float, float, Dict[str, float]]:
        if cname in memo:
            return memo[cname]
        flops = 0.0
        byts = 0.0
        coll = {k: 0.0 for k in _COLL_OPS}
        if cname not in comps:
            memo[cname] = (0.0, 0.0, coll)
            return memo[cname]
        # prevent infinite recursion on malformed input
        memo[cname] = (0.0, 0.0, dict(coll))
        table = symtab[cname]

        def operand_bytes(instr: Instr) -> float:
            total = 0.0
            for o in instr.operands:
                if o in table:
                    total += _shape_elems_bytes(table[o])[1]
            return total

        for ins in comps[cname]:
            out_elems, out_bytes = _shape_elems_bytes(ins.shape)
            op = ins.op
            if op in ("parameter", "constant", "tuple", "get-tuple-element",
                      "bitcast", "after-all", "iota"):
                continue
            if op == "while":
                n = 1
                tm = _TRIP_RE.search(ins.line)
                if tm:
                    n = int(tm.group(1))
                called = _CALLED_RE.findall(ins.line)
                for c in called:
                    f, b, cl = comp_cost(c)
                    flops += n * f
                    byts += n * b
                    for k in _COLL_OPS:
                        coll[k] += n * cl[k]
                continue
            if op in ("fusion", "call", "custom-call", "conditional",
                      "async-start", "map"):
                called = _CALLED_RE.findall(ins.line)
                for c in called:
                    f, b, cl = comp_cost(c)
                    flops += f
                    for k in _COLL_OPS:
                        coll[k] += cl[k]
                # boundary bytes (slice-aware, DUS-aware, projected)
                byts += _fusion_bytes(ins, table, comps, symtab, called,
                                      project)
                continue
            if op in _COLL_OPS:
                factor = 2.0 if op == "all-reduce" else 1.0
                coll[op] += factor * out_bytes
                byts += out_bytes + operand_bytes(ins)
                continue
            if op == "dot":
                k = 1.0
                lhs_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}",
                                  ins.line)
                if lhs_m and ins.operands:
                    lhs_shape = table.get(ins.operands[0], "")
                    dims = _dims_of(lhs_shape)
                    if dims:
                        for di in lhs_m.group(1).split(","):
                            if di != "" and int(di) < len(dims):
                                k *= dims[int(di)]
                # batch dims are part of output already
                flops += 2.0 * out_elems * k
                byts += out_bytes + operand_bytes(ins)
                continue
            if op == "convolution":
                # rough: 2 * out_elems * kernel_elems / out_features
                ker = (_shape_elems_bytes(table.get(ins.operands[1], ""))[0]
                       if len(ins.operands) > 1 else 1)
                dims = _dims_of(ins.shape)
                ofeat = dims[-1] if dims else 1
                flops += 2.0 * out_elems * max(ker / max(ofeat, 1), 1.0)
                byts += out_bytes + operand_bytes(ins)
                continue
            if op == "dynamic-update-slice":
                # in-place: only the written window moves
                upd = (_shape_elems_bytes(table.get(ins.operands[1], ""))[1]
                       if len(ins.operands) > 1 else out_bytes)
                byts += 2.0 * upd
                continue
            if op in ("dynamic-slice", "gather"):
                byts += 2.0 * out_bytes
                continue
            if op == "scatter":
                upd = (_shape_elems_bytes(table.get(ins.operands[2], ""))[1]
                       if len(ins.operands) > 2 else out_bytes)
                byts += 2.0 * upd
                continue
            if op == "convert" and project:
                continue
            if op in ("copy", "convert", "reshape", "transpose", "broadcast",
                      "slice", "concatenate", "pad", "reverse",
                      "reduce", "reduce-window", "sort", "select-and-scatter",
                      "copy-start", "copy-done"):
                byts += out_bytes + operand_bytes(ins)
                if op == "reduce":
                    flops += operand_bytes(ins) / 4.0   # ~1 flop/elem
                continue
            if op in _ELTWISE_FLOP_OPS:
                flops += out_elems
                byts += out_bytes + operand_bytes(ins)
                continue
            # default: count bytes only
            byts += out_bytes + operand_bytes(ins)

        total_coll = sum(coll.values())
        coll_out = dict(coll)
        coll_out["total"] = total_coll
        memo[cname] = (flops, byts, coll_out)
        return memo[cname]

    # entry computation: the one whose header had ENTRY, else heuristic
    entry = None
    em = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    if em:
        entry = em.group(1)
    else:
        entry = max(comps, key=lambda c: len(comps[c]))
    flops, byts, coll = comp_cost(entry)
    out = {"flops": flops, "bytes": byts, "collectives": coll,
           "entry": entry, "num_computations": len(comps)}
    if detail:
        # weight per computation via call-graph walk from entry
        weights: Dict[str, float] = {entry: 1.0}
        order = [entry]
        seen = {entry}
        while order:
            cname = order.pop(0)
            w = weights.get(cname, 0.0)
            for ins in comps.get(cname, []):
                called = _CALLED_RE.findall(ins.line)
                n = 1
                if ins.op == "while":
                    tm = _TRIP_RE.search(ins.line)
                    n = int(tm.group(1)) if tm else 1
                for c in called:
                    weights[c] = weights.get(c, 0.0) + w * n
                    if c not in seen and c in comps:
                        seen.add(c)
                        order.append(c)
        rows = []
        for cname, instrs in comps.items():
            w = weights.get(cname, 0.0)
            if w == 0:
                continue
            table = symtab[cname]
            for ins in instrs:
                if ins.op in ("parameter", "constant", "tuple",
                              "get-tuple-element", "bitcast", "iota"):
                    continue
                ob = _shape_elems_bytes(ins.shape)[1]
                if ins.op in ("fusion", "call", "custom-call"):
                    called = _CALLED_RE.findall(ins.line)
                    b = _fusion_bytes(ins, table, comps, symtab, called,
                                      project)
                elif ins.op == "dynamic-update-slice":
                    b = 2 * (_shape_elems_bytes(
                        table.get(ins.operands[1], ""))[1]
                        if len(ins.operands) > 1 else ob)
                elif ins.op in ("dynamic-slice", "gather"):
                    b = 2 * ob
                elif ins.op == "while":
                    continue
                else:
                    b = ob + sum(_shape_elems_bytes(table.get(o, ""))[1]
                                 for o in ins.operands)
                rows.append((w * b, w, cname, ins.op, ins.shape[:70],
                             ins.name[:45]))
        rows.sort(reverse=True)
        out["top_instructions"] = rows[:25]
    return out
