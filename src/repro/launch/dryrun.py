"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is how the distribution config is proven coherent without real
hardware: `jax.jit(step).lower(*ShapeDtypeStructs).compile()` under the
production mesh forces GSPMD to produce a complete partitioned module
— sharding mismatches, non-divisible layouts, OOM-at-compile and
unsupported collectives all fail HERE. No arrays are ever allocated.

Per cell we record: memory_analysis (per-device bytes), cost_analysis
(FLOPs / bytes), and the collective-bytes breakdown parsed from the
optimized HLO — the inputs to EXPERIMENTS.md §Roofline.

Usage:
  python -m repro.launch.dryrun                    # all cells, both meshes
  python -m repro.launch.dryrun --arch qwen3-32b --shape decode_32k \
      --mesh single                                # one cell, in-process
  python -m repro.launch.dryrun --list             # enumerate cells

Cells run as subprocesses (one fresh XLA per cell) so a failure or a
compiler OOM never poisons the sweep; results append to
dryrun_results.jsonl.
"""

import argparse
import json
import os
import subprocess
import sys
import time

# must land before any jax import (cells import jax in-process when run
# with --arch/--shape/--mesh; the sweep spawns fresh subprocesses)
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

SHAPES = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

SUBQUADRATIC = {"xlstm-125m", "zamba2-1-2b", "zamba2-1.2b"}

RESULTS = "dryrun_results.jsonl"


def cells(archs=None, shapes=None):
    """Enumerate (arch, shape, RUN|SKIP, reason) cells for the sweep."""
    from repro import configs
    out = []
    for arch in (archs or configs.all_arch_names()):
        for shape in (shapes or SHAPES):
            if shape == "long_500k" and arch not in SUBQUADRATIC:
                out.append((arch, shape, "SKIP",
                            "pure full-attention arch; sub-quadratic "
                            "attention required at 524288 (DESIGN.md §4)"))
                continue
            out.append((arch, shape, "RUN", ""))
    return out


# ---------------------------------------------------------------------------
# Spec builders (ShapeDtypeStruct only — no allocation)
# ---------------------------------------------------------------------------

def input_specs(arch: str, shape: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    import jax
    import jax.numpy as jnp
    from repro import configs

    cfg = configs.get(arch)
    seq, batch, kind = SHAPES[shape]
    i32 = jnp.int32
    specs = {}
    if kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((batch, seq), i32)
        if cfg.family == "vlm":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (batch, cfg.frontend.num_embeddings, cfg.d_model), cfg.dtype)
        if cfg.family == "encdec":
            specs["frame_embeds"] = jax.ShapeDtypeStruct(
                (batch, cfg.frontend.num_embeddings, cfg.d_model), cfg.dtype)
    elif kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((batch, seq), i32)
        if cfg.family == "vlm":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (batch, cfg.frontend.num_embeddings, cfg.d_model), cfg.dtype)
        if cfg.family == "encdec":
            specs["frame_embeds"] = jax.ShapeDtypeStruct(
                (batch, cfg.frontend.num_embeddings, cfg.d_model), cfg.dtype)
    else:  # decode
        specs["token"] = jax.ShapeDtypeStruct((batch,), i32)
    return specs


def _abstract_state(model, batch, context):
    """Abstract decode state for the cell (ShapeDtypeStructs)."""
    import jax
    cfg = model.cfg
    if cfg.family in ("dense", "vlm", "moe", "encdec", "hybrid"):
        geo = model.cache_geometry(batch, context, hbm_fraction=0.25)
    else:
        geo = None
    if cfg.family == "encdec":
        state = jax.eval_shape(
            lambda: {"kv": model.init_decode_state(batch, geo),
                     "enc": jax.numpy.zeros(
                         (batch, cfg.frontend.num_embeddings, cfg.d_model),
                         cfg.dtype)})
    else:
        state = jax.eval_shape(lambda: model.init_decode_state(batch, geo))
    return state


# ---------------------------------------------------------------------------
# Lower + compile one cell
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape: str, mesh_kind: str) -> dict:
    """Lower + compile one cell under its mesh; returns the cost record.

    No arrays are allocated — inputs are ShapeDtypeStructs, so sharding
    mismatches and compile-time OOM surface here, cheaply."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import configs
    from repro.launch import shardings as shd
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import collective_bytes_of_hlo
    from repro.models.model import Model
    from repro.training.train_step import make_train_step, TrainState
    from repro.training.optimizer import AdamWState

    t0 = time.time()
    cfg = configs.get(arch)
    model = Model(cfg)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    seq, batch, kind = SHAPES[shape]
    specs = input_specs(arch, shape)

    from repro.models import layers as layers_mod
    layers_mod.set_activation_batch_axes(
        shd.batch_axes(mesh, batch))
    axes = model.logical_axes()
    abstract = model.abstract_params()
    mode = "train" if kind == "train" else "serve"
    pshard = shd.param_shardings(axes, abstract, mesh, mode)
    tok_shard = shd.tokens_sharding(mesh, batch)
    rep = shd.replicated(mesh)

    with mesh:
        if kind == "train":
            step = make_train_step(
                model, extra_keys=tuple(k for k in specs if k != "tokens"))
            opt_shard = AdamWState(step=rep, m=pshard, v=pshard)
            state_shard = TrainState(params=pshard, opt=opt_shard)
            state_abs = jax.eval_shape(
                lambda p: TrainState(
                    params=p,
                    opt=AdamWState(
                        step=jnp.zeros((), jnp.int32),
                        m=jax.tree.map(
                            lambda a: jnp.zeros(a.shape, jnp.float32), p),
                        v=jax.tree.map(
                            lambda a: jnp.zeros(a.shape, jnp.float32), p))),
                abstract)
            batch_abs = dict(specs)
            batch_shard = {k: (tok_shard if k == "tokens"
                               else NamedSharding(
                                   mesh, P(shd.batch_axes(mesh, batch),
                                           None, None)))
                           for k in specs}
            fn = jax.jit(step,
                         in_shardings=(state_shard, batch_shard),
                         out_shardings=(state_shard, rep),
                         donate_argnums=(0,))
            lowered = fn.lower(state_abs, batch_abs)
        elif kind == "prefill":
            geo = model.cache_geometry(batch, seq, hbm_fraction=0.25)
            extra_keys = tuple(k for k in specs if k != "tokens")

            if cfg.family == "xlstm":
                # recurrent arch: parallel (chunked) prompt scoring is
                # the prefill analogue (DESIGN.md §6)
                def pre(params, tokens):
                    return model.forward_hidden(params, tokens)
                out_shard = NamedSharding(
                    mesh, P(shd.batch_axes(mesh, batch), None, None))
            else:
                def pre(params, tokens, *extra_vals):
                    extra = dict(zip(extra_keys, extra_vals)) or None
                    return model.prefill(params, tokens, geo, extra=extra)
                state_abs = jax.eval_shape(
                    lambda p, t, *e: model.prefill(
                        p, t, geo, extra=dict(zip(extra_keys, e)) or None)[1],
                    abstract, specs["tokens"],
                    *[specs[k] for k in extra_keys])
                out_shard = (shd.logits_sharding(mesh, cfg.vocab, batch),
                             shd.state_shardings_for(model, state_abs, mesh))
            in_sh = [pshard, tok_shard] + [
                NamedSharding(mesh,
                              P(shd.batch_axes(mesh, batch), None, None))
                for _ in extra_keys]
            fn = jax.jit(pre, in_shardings=tuple(in_sh),
                         out_shardings=out_shard)
            lowered = fn.lower(abstract, specs["tokens"],
                               *[specs[k] for k in extra_keys])
        else:  # decode
            state_abs = _abstract_state(model, batch, seq)
            state_shard = shd.state_shardings_for(model, state_abs, mesh)

            def dec(params, state, token):
                return model.decode_step(params, state, token)

            tok_vec = NamedSharding(mesh, P(shd.batch_axes(mesh, batch)))
            fn = jax.jit(dec,
                         in_shardings=(pshard, state_shard, tok_vec),
                         out_shardings=(
                             shd.logits_sharding(mesh, cfg.vocab, batch),
                             state_shard),
                         donate_argnums=(1,))
            lowered = fn.lower(abstract, state_abs, specs["token"])

        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    from repro.launch.hlo_cost import analyze
    weighted = analyze(hlo)   # trip-count-weighted (scan bodies x L)
    n_dev = mesh.devices.size

    result = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "devices": int(n_dev),
        "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        # per-device module costs, trip-count weighted
        "flops_per_device": float(weighted["flops"]),
        "bytes_per_device": float(weighted["bytes"]),
        "collective_bytes_per_device": weighted["collectives"],
        # XLA's own (unweighted) numbers, for reference
        "xla_flops": float(cost.get("flops", -1)),
        "xla_bytes_accessed": float(cost.get("bytes accessed", -1)),
        "collective_bytes": collective_bytes_of_hlo(hlo),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "params": int(cfg.param_count()),
        "active_params": int(cfg.active_param_count()),
        "seq": seq, "batch": batch, "kind": kind,
    }
    return result


# ---------------------------------------------------------------------------

def main():
    """CLI driver: one in-process cell, or the subprocess-per-cell sweep."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default=RESULTS)
    ap.add_argument("--subprocess", action="store_true",
                    help="run each cell in a fresh process")
    args = ap.parse_args()

    todo = cells([args.arch] if args.arch else None,
                 [args.shape] if args.shape else None)
    if args.list:
        for c in todo:
            print(*c)
        return

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    single_cell = args.arch and args.shape and len(meshes) == 1

    if single_cell and not args.subprocess:
        arch, shape, status, why = todo[0]
        if status == "SKIP":
            print(json.dumps({"arch": arch, "shape": shape,
                              "mesh": meshes[0], "status": "skip",
                              "reason": why}))
            return
        res = run_cell(arch, shape, meshes[0])
        print(json.dumps(res))
        return

    # sweep: one subprocess per cell, appending to the results file
    with open(args.out, "a") as out:
        for arch, shape, status, why in todo:
            for mesh_kind in meshes:
                if status == "SKIP":
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "status": "skip", "reason": why}
                    out.write(json.dumps(rec) + "\n")
                    out.flush()
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape,
                       "--mesh", mesh_kind]
                t0 = time.time()
                proc = subprocess.run(cmd, capture_output=True, text=True,
                                      timeout=3600)
                if proc.returncode == 0 and proc.stdout.strip():
                    line = proc.stdout.strip().splitlines()[-1]
                    out.write(line + "\n")
                    print(f"OK   {arch} {shape} {mesh_kind} "
                          f"({time.time()-t0:.0f}s)")
                else:
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "status": "fail",
                           "stderr": proc.stderr[-2000:]}
                    out.write(json.dumps(rec) + "\n")
                    print(f"FAIL {arch} {shape} {mesh_kind}: "
                          f"{proc.stderr[-300:]}")
                out.flush()


if __name__ == "__main__":
    main()
