"""Memory-system specifications for heterogeneous KV-cache placement.

The paper (Table I) models an NVIDIA GH200: HBM3 + NVLink-C2C attached
LPDDR5X. We keep the spec as data so the same latency model runs for the
paper-faithful GH200 configuration (used to validate the paper's claims)
and for TPU-native tier constants (used by the serving stack + roofline).

All bandwidths are bytes/second, capacities in bytes.
"""

from __future__ import annotations

import dataclasses

GB = 1024**3
TB = 1024**4
GBps = 1e9  # vendor bandwidth figures are decimal
TBps = 1e12


@dataclasses.dataclass(frozen=True)
class MemorySystemSpec:
    """Two-tier memory system: HBM + off-package DRAM behind a serial link.

    Attributes mirror the paper's Table I / Section III-A symbols:
      hbm_bw          B_h  — HBM bandwidth
      hbm_capacity         — HBM bytes available to the KV cache
                             (model weights already subtracted)
      link_bw         B_k  — uni-directional serial-link bandwidth
                             (NVLink-C2C / PCIe); full duplex
      dram_bw         B_d  — internal DDR/LPDDR channel bandwidth
      dram_capacity        — off-package DRAM capacity ("sufficiently
                             large" per the paper; enforced anyway)
    """

    name: str
    hbm_bw: float
    hbm_capacity: float
    link_bw: float
    dram_bw: float
    dram_capacity: float

    @property
    def effective_dram_read_bw(self) -> float:
        # Reads from off-package DRAM traverse both the DRAM channels and
        # the serial link; Eq. (4) charges them at min(B_k, B_d).
        return min(self.link_bw, self.dram_bw)

    @property
    def bw_ratio(self) -> float:
        """HBM : effective-DRAM read bandwidth ratio (paper: ~order of 1)."""
        return self.hbm_bw / self.effective_dram_read_bw

    def with_kv_budget(self, kv_bytes: float) -> "MemorySystemSpec":
        """Spec with HBM capacity replaced by an explicit KV budget."""
        return dataclasses.replace(self, hbm_capacity=kv_bytes)


# --- Paper-faithful configuration (Table I) --------------------------------
# "Bandwidth 4.9 TB/s, Capacity 24 GB, Link 900 GB/s, DRAM 500 GB/s,
#  Capacity 480 GB".  The evaluation then says LLaMA-3.1-8B weights (~16 GB)
# leave ~8 GB of HBM for KV cache; we model that by re-budgeting capacity at
# simulation setup (`with_kv_budget`).
GH200 = MemorySystemSpec(
    name="gh200",
    hbm_bw=4.9 * TBps,
    hbm_capacity=24 * GB,
    link_bw=900 * GBps,
    dram_bw=500 * GBps,
    dram_capacity=480 * GB,
)

# --- TPU adaptations --------------------------------------------------------
# TPU v5e: 16 GB HBM @ 819 GB/s; host DDR reached over PCIe Gen4 x16 (~32
# GB/s per direction per chip, 4 chips share a host in v5e-4 trays — we model
# the per-chip share).  Host DDR channel bandwidth is generous relative to
# the link, so min(B_k, B_d) = link, which is the realistic TPU regime.
TPU_V5E = MemorySystemSpec(
    name="tpu_v5e",
    hbm_bw=819 * GBps,
    hbm_capacity=16 * GB,
    link_bw=32 * GBps,
    dram_bw=150 * GBps,
    dram_capacity=512 * GB,
)

# TPU v5p: 95 GB HBM @ 2765 GB/s; PCIe Gen5-class host link.
TPU_V5P = MemorySystemSpec(
    name="tpu_v5p",
    hbm_bw=2765 * GBps,
    hbm_capacity=95 * GB,
    link_bw=64 * GBps,
    dram_bw=300 * GBps,
    dram_capacity=1024 * GB,
)

# TPU v6e (Trillium): 32 GB HBM @ 1640 GB/s.
TPU_V6E = MemorySystemSpec(
    name="tpu_v6e",
    hbm_bw=1640 * GBps,
    hbm_capacity=32 * GB,
    link_bw=64 * GBps,
    dram_bw=300 * GBps,
    dram_capacity=1024 * GB,
)

SPECS = {s.name: s for s in (GH200, TPU_V5E, TPU_V5P, TPU_V6E)}


# --- Compute-roofline constants for the dry-run target (v5e) ----------------
@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float   # FLOP/s
    hbm_bw: float            # bytes/s
    ici_bw: float            # bytes/s per link (uni-directional)
    hbm_capacity: float


TPU_V5E_CHIP = ChipSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_capacity=16 * GB,
)
