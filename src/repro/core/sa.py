"""Simulated-annealing search over the (W, R) knobs (paper Section III-B).

The SA state is the pair (W, R): look-ahead window and migration ratio.
Faithful to the paper:

  * proposal operators sampled with probabilities (0.4, 0.4, 0.2):
      (i)   window move  dW in {+-1, +-2}, R fixed
      (ii)  ratio move   dR in {+-0.1},   W fixed
      (iii) diagonal move: one perturbation of each kind simultaneously
  * Metropolis rule  P(accept) = exp(-dT / C)
  * initial temperature calibrated to an initial acceptance ratio
    p0 = 0.8 over uphill moves
  * geometric cooling with alpha = 0.9
  * termination when the best latency improves < 0.1% across successive
    temperature levels, when C falls below a cutoff, or at an iteration
    budget.

The objective T(W, R) is one full simulator run; evaluations are
memoized because the discrete (W, R) lattice is small and SA revisits
points frequently.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Tuple

import numpy as np

State = Tuple[int, float]


@dataclasses.dataclass
class SAResult:
    best_state: State
    best_latency: float
    history: List[Tuple[int, State, float, bool]]  # (iter, state, T, accepted)
    evaluations: int
    temperature_levels: int
    accept_attribution: Dict[str, int]  # accepted improvements per operator


@dataclasses.dataclass
class SAConfig:
    p0: float = 0.8                # target initial acceptance ratio
    alpha: float = 0.9             # cooling rate
    iters_per_level: int = 20
    stop_rel_improvement: float = 1e-3   # 0.1%
    min_temperature_frac: float = 1e-4   # cutoff relative to C0
    max_evaluations: int = 400
    w_min: int = 1
    w_max: int = 64
    r_step: float = 0.1
    seed: int = 0


def _clip_state(w: int, r: float, cfg: SAConfig) -> State:
    w = int(min(max(w, cfg.w_min), cfg.w_max))
    r = round(min(max(r, 0.0), 1.0), 6)
    return w, r


def _propose(state: State, rng: np.random.Generator,
             cfg: SAConfig) -> Tuple[State, str]:
    w, r = state
    u = rng.random()
    if u < 0.4:                       # (i) window move
        dw = int(rng.choice([-2, -1, 1, 2]))
        return _clip_state(w + dw, r, cfg), "dW"
    if u < 0.8:                       # (ii) ratio move
        dr = float(rng.choice([-cfg.r_step, cfg.r_step]))
        return _clip_state(w, r + dr, cfg), "dR"
    dw = int(rng.choice([-2, -1, 1, 2]))          # (iii) diagonal
    dr = float(rng.choice([-cfg.r_step, cfg.r_step]))
    return _clip_state(w + dw, r + dr, cfg), "dWdR"


def anneal(objective: Callable[[int, float], float],
           init: State = (8, 0.5),
           cfg: SAConfig | None = None) -> SAResult:
    cfg = cfg or SAConfig()
    rng = np.random.default_rng(cfg.seed)
    cache: Dict[State, float] = {}
    evals = 0

    def T(state: State) -> float:
        nonlocal evals
        if state not in cache:
            cache[state] = float(objective(*state))
            evals += 1
        return cache[state]

    cur = _clip_state(*init, cfg)
    cur_T = T(cur)
    best, best_T = cur, cur_T

    # --- temperature calibration: sample uphill moves, set C0 so the mean
    # uphill dT is accepted with probability p0.
    uphill = []
    probe = cur
    for _ in range(16):
        cand, _op = _propose(probe, rng, cfg)
        dT = T(cand) - T(probe)
        if dT > 0:
            uphill.append(dT)
        probe = cand
        if evals >= cfg.max_evaluations // 4:
            break
    mean_up = float(np.mean(uphill)) if uphill else max(cur_T * 0.01, 1e-12)
    C0 = -mean_up / math.log(cfg.p0)
    C = C0

    history: List[Tuple[int, State, float, bool]] = []
    attribution = {"dW": 0, "dR": 0, "dWdR": 0}
    level = 0
    it = 0
    prev_level_best = best_T

    while evals < cfg.max_evaluations and C > C0 * cfg.min_temperature_frac:
        for _ in range(cfg.iters_per_level):
            if evals >= cfg.max_evaluations:
                break
            cand, op = _propose(cur, rng, cfg)
            cand_T = T(cand)
            dT = cand_T - cur_T
            accept = dT <= 0 or rng.random() < math.exp(-dT / C)
            if accept:
                if cand_T < cur_T:
                    attribution[op] += 1
                cur, cur_T = cand, cand_T
                if cur_T < best_T:
                    best, best_T = cur, cur_T
            history.append((it, cand, cand_T, accept))
            it += 1
        level += 1
        # stop when best improves < 0.1% across successive levels
        if prev_level_best - best_T < cfg.stop_rel_improvement * prev_level_best:
            break
        prev_level_best = best_T
        C *= cfg.alpha

    return SAResult(best_state=best, best_latency=best_T, history=history,
                    evaluations=evals, temperature_levels=level,
                    accept_attribution=attribution)
