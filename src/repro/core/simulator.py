"""Behavioral decode-stage memory simulator (paper Section IV-A).

Plays an attention `Trace` against a `PlacementPolicy` on a two-tier
`MemorySystemSpec` and scores every step with the Eq.(1)-(5) latency
model. All strategies in the paper's Fig. 3/4/5 are instances of this
loop with different policies.

Byte accounting convention (see EXPERIMENTS.md §Repro for discussion):
the paper's headline 4-5.87x ratios are only reachable if the constant
per-step weight stream is *not* charged against the KV placement problem
(it is an additive constant for every strategy and would compress all
ratios to ~1.2x). We default to the paper's convention
(`include_weights=False`) and also report the weight-inclusive numbers.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.latency_model import (
    StepTraffic, dram_latency, hbm_latency,
)
from repro.core.placement.base import DRAM, HBM, UNALLOC, PlacementPolicy
from repro.core.tiers import MemorySystemSpec
from repro.core.traces import Trace


@dataclasses.dataclass
class SimResult:
    policy: str
    total_latency_s: float
    tokens_per_s: float
    hbm_hit_rate: float
    migrated_bytes: float
    read_bytes_hbm: float
    read_bytes_dram: float
    step_latency_s: np.ndarray
    spec_name: str
    include_weights: bool
    #: per-step traffic volumes ([steps]-arrays per field), so callers
    #: can re-aggregate across layers/requests before pricing Eq. (2)
    #: (see repro.serving.trace_bridge).
    step_traffic: Optional[StepTraffic] = None

    def speedup_over(self, other: "SimResult") -> float:
        if self.total_latency_s == 0.0:
            return float("inf") if other.total_latency_s > 0.0 else 1.0
        return other.total_latency_s / self.total_latency_s


class HeteroMemSimulator:
    """One decode request's KV traffic under a placement policy."""

    def __init__(
        self,
        trace: Trace,
        spec: MemorySystemSpec,
        policy: PlacementPolicy,
        *,
        bytes_per_token_layer: int,
        num_layers: int,
        hbm_kv_budget_bytes: Optional[float] = None,
        weight_bytes: float = 0.0,
        include_weights: bool = False,
    ):
        self.trace = trace
        self.spec = spec
        self.policy = policy
        self.num_layers = num_layers
        self.bytes_per_token = bytes_per_token_layer * num_layers
        self.page_bytes = self.bytes_per_token * trace.page_tokens
        budget = spec.hbm_capacity if hbm_kv_budget_bytes is None \
            else hbm_kv_budget_bytes
        if np.isinf(budget):
            self.hbm_budget_pages = trace.num_pages + 1
        else:
            self.hbm_budget_pages = max(1, int(budget // self.page_bytes))
        self.weight_bytes = weight_bytes
        self.include_weights = include_weights

        n = trace.num_pages
        # --- state the policies may read ---
        self.placement = np.full(n, UNALLOC, dtype=np.int8)
        self.hbm_used = 0
        self.last_access = np.full(n, -1, dtype=np.int64)
        self.step = 0

    # -- state mutation helpers (capacity-checked) --------------------------
    def _apply_migrations(self, promote: np.ndarray, demote: np.ndarray
                          ) -> tuple[int, int]:
        """Apply and return (n_promoted, n_demoted) actually performed."""
        demote = demote[self.placement[demote] == HBM]
        promote = promote[self.placement[promote] == DRAM]
        # Demotions first (frees room), then promotions up to capacity.
        if len(demote):
            self.placement[demote] = DRAM
            self.hbm_used -= len(demote)
        room = self.hbm_budget_pages - self.hbm_used
        promote = promote[: max(room, 0)]
        if len(promote):
            self.placement[promote] = HBM
            self.hbm_used += len(promote)
        return len(promote), len(demote)

    def _place_new(self, pages: np.ndarray) -> tuple[float, float]:
        tiers = np.asarray(self.policy.place_new(self, pages), dtype=np.int8)
        # Enforce the capacity constraint regardless of policy behaviour.
        want_hbm = pages[tiers == HBM]
        room = self.hbm_budget_pages - self.hbm_used
        to_hbm = want_hbm[: max(room, 0)]
        to_dram = np.setdiff1d(pages, to_hbm, assume_unique=True)
        self.placement[to_hbm] = HBM
        self.placement[to_dram] = DRAM
        self.hbm_used += len(to_hbm)
        return len(to_hbm), len(to_dram)

    # -- main loop -----------------------------------------------------------
    def run(self) -> SimResult:
        tr, spec = self.trace, self.spec
        self.policy.reset(self)

        # Group pages by birth step ONCE (one argsort) instead of scanning
        # `page_born == s` every step — the per-step scan made long-trace
        # policy sweeps quadratic in trace length.
        born_order = np.argsort(tr.page_born, kind="stable").astype(np.int64)
        born_starts = np.searchsorted(tr.page_born, np.arange(
            tr.num_steps + 1), sorter=born_order)

        def born_at(s: int) -> np.ndarray:
            return born_order[born_starts[s]:born_starts[s + 1]]

        # Pages alive at step 0 (the prompt) are placed before decoding
        # starts; the paper charges prefill placement to the prefill stage,
        # so we do not count these writes in decode latency.
        self._place_new(born_at(0))

        steps = tr.num_steps
        lat = np.zeros(steps, dtype=np.float64)
        vol = StepTraffic(*(np.zeros(steps, dtype=np.float64)
                            for _ in range(6)))
        hits = 0
        reads = 0
        migrated = 0.0
        hbm_read_total = 0.0
        dram_read_total = 0.0

        for s in range(steps):
            self.step = s
            # 1. new pages born this step
            if s > 0:
                born = born_at(s)
                if len(born):
                    self._place_new(born)
            # one decoded token's KV is appended every step
            new_tier_hbm = self.placement[_newest_page(tr, s)] == HBM
            h_write = self.bytes_per_token if new_tier_hbm else 0.0
            e_write = 0.0 if new_tier_hbm else self.bytes_per_token

            # 2. proactive migrations
            p, d = self.policy.migrations(self, s)
            n_p, n_d = self._apply_migrations(np.asarray(p, dtype=np.int64),
                                              np.asarray(d, dtype=np.int64))

            # 3. reads
            acc = np.nonzero(tr.access[s])[0]
            in_hbm = self.placement[acc] == HBM
            n_hbm = int(in_hbm.sum())
            n_dram = len(acc) - n_hbm
            self.last_access[acc] = s

            # 4. reactive migrations (charged this step as well)
            rp, rd = self.policy.on_access(self, s, acc)
            rn_p, rn_d = self._apply_migrations(
                np.asarray(rp, dtype=np.int64), np.asarray(rd, dtype=np.int64))

            m_in = (n_p + rn_p) * self.page_bytes
            m_out = (n_d + rn_d) * self.page_bytes
            h_read = n_hbm * self.page_bytes
            e_read = n_dram * self.page_bytes
            if self.include_weights:
                h_read += self.weight_bytes

            t = StepTraffic(h_read=h_read, e_read=e_read, h_write=h_write,
                            e_write=e_write, m_in=m_in, m_out=m_out)
            lat[s] = max(hbm_latency(t, spec), dram_latency(t, spec))
            for field in ("h_read", "e_read", "h_write", "e_write",
                          "m_in", "m_out"):
                getattr(vol, field)[s] = getattr(t, field)

            hits += n_hbm
            reads += len(acc)
            migrated += m_in + m_out
            hbm_read_total += h_read
            dram_read_total += e_read

        total = float(lat.sum())
        return SimResult(
            policy=self.policy.name,
            total_latency_s=total,
            tokens_per_s=(steps / total if total > 0 else float("inf")),
            hbm_hit_rate=(hits / reads if reads else 1.0),
            migrated_bytes=migrated,
            read_bytes_hbm=hbm_read_total,
            read_bytes_dram=dram_read_total,
            step_latency_s=lat,
            spec_name=spec.name,
            include_weights=self.include_weights,
            step_traffic=vol,
        )


def _newest_page(tr: Trace, step: int) -> int:
    """Index of the page receiving the token decoded at `step`."""
    token = tr.prompt_len + step
    return min(token // tr.page_tokens, tr.num_pages - 1)
