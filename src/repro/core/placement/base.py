"""Placement-policy interface shared by the simulator and the serving engine.

A policy sees the same state the serving engine's control plane sees:
which pages exist, where they live, and (for oracle policies) the trace.
It never touches byte accounting — the simulator charges traffic from the
(promote, demote) sets the policy returns, so every policy is scored under
the identical Eq.(1)-(5) cost model.

Tiers: HBM = 0, DRAM = 1, UNALLOC = -1.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Tuple

import numpy as np

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.simulator import HeteroMemSimulator

HBM = 0
DRAM = 1
UNALLOC = -1

_EMPTY = np.zeros(0, dtype=np.int64)


class PlacementPolicy:
    """Base class. Subclasses override some of the four hooks.

    Hook order within a simulated step `s`:
      1. place_new(sim, pages)         — tier for pages born at `s`
      2. migrations(sim, s)            — proactive (pre-access) migrations
      3. <simulator charges reads for trace.access[s]>
      4. on_access(sim, s, accessed)   — reactive (post-access) migrations
    """

    name = "base"
    #: oracle policies read future trace rows; real-time policies must not.
    uses_foresight = False
    #: registry name of the jit-safe live mirror of this policy in
    #: `repro.serving.policies` (None for oracles the live engine
    #: cannot run — they need foresight the device doesn't have).
    device_counterpart: str | None = None

    def reset(self, sim: "HeteroMemSimulator") -> None:
        pass

    def place_new(self, sim: "HeteroMemSimulator",
                  pages: np.ndarray) -> np.ndarray:
        """Default: new pages go to HBM while it has room, else DRAM."""
        free = sim.hbm_budget_pages - sim.hbm_used
        tiers = np.full(len(pages), DRAM, dtype=np.int8)
        tiers[: max(free, 0)] = HBM
        return tiers

    def migrations(self, sim: "HeteroMemSimulator",
                   step: int) -> Tuple[np.ndarray, np.ndarray]:
        """Return (promote DRAM->HBM page ids, demote HBM->DRAM page ids)."""
        return _EMPTY, _EMPTY

    def on_access(self, sim: "HeteroMemSimulator", step: int,
                  accessed: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Reactive migrations after the reads of `step` were charged."""
        return _EMPTY, _EMPTY


def empty_migration() -> Tuple[np.ndarray, np.ndarray]:
    return _EMPTY, _EMPTY
