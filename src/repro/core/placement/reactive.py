"""Reactive scheduling (paper baseline #3).

"Upon accessing a KV cache entry absent from HBM, it is promoted to HBM.
If HBM is full, the least recently used (LRU) entry is evicted to
off-package DRAM."

Promotion happens *after* the access (the read itself is served from
DRAM), and both the promotion and the LRU eviction are charged as
migration traffic in the same step — which is why the paper observes
this policy drowning in migrations at low sparsity.
"""

from __future__ import annotations

import numpy as np

from repro.core.placement.base import DRAM, HBM, PlacementPolicy


class ReactiveLRU(PlacementPolicy):
    name = "reactive"
    device_counterpart = "recency"

    def __init__(self, max_promotions_per_step: int | None = None):
        # Optional cap (beyond-paper knob); None reproduces the paper.
        self.max_promotions = max_promotions_per_step

    def on_access(self, sim, step, accessed):
        missed = accessed[sim.placement[accessed] == DRAM]
        if self.max_promotions is not None:
            missed = missed[: self.max_promotions]
        n = len(missed)
        if n == 0:
            return missed, missed
        # Evict LRU HBM pages to make room (never the ones just accessed).
        room = sim.hbm_budget_pages - sim.hbm_used
        need = max(0, n - room)
        if need:
            hbm_pages = np.nonzero(sim.placement == HBM)[0]
            candidates = np.setdiff1d(hbm_pages, accessed, assume_unique=True)
            order = np.argsort(sim.last_access[candidates], kind="stable")
            evict = candidates[order][:need]
        else:
            evict = np.zeros(0, dtype=np.int64)
        return missed, evict
