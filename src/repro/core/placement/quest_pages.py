"""Page-granularity scheduling (paper baseline #4, Quest-like).

"Emulates the Quest approach by managing KV cache at page granularity
(page size: 16). Entire pages are migrated with perfect foresight of
token importance, though this incurs overhead from including unimportant
tokens in the same page."

Foresight horizon is a single step (Quest selects pages per decoding
step); granularity overhead is modeled by `unit_group`: migration
decisions operate on groups of `unit_group` consecutive trace units, so
with a token-granular trace and unit_group=16 a single hot token drags
its 15 page-mates across the link. With a page-granular (16-token) trace
unit_group=1 is the faithful setting.
"""

from __future__ import annotations

import numpy as np

from repro.core.placement.base import DRAM, HBM, PlacementPolicy


class QuestPages(PlacementPolicy):
    name = "quest"
    # one-step foresight: the live mirror promotes the pages the Quest
    # top-k mask selects, which the device does know ahead of the read
    uses_foresight = True
    device_counterpart = "quest"

    def __init__(self, unit_group: int = 1):
        self.unit_group = unit_group

    def migrations(self, sim, step):
        tr = sim.trace
        want = np.nonzero(tr.access[step])[0]          # needed this step
        g = self.unit_group
        if g > 1:
            # expand to whole groups
            groups = np.unique(want // g)
            want = (groups[:, None] * g + np.arange(g)).ravel()
            want = want[want < tr.num_pages]
            want = want[sim.placement[want] != -1]
        promote = want[sim.placement[want] == DRAM]
        if len(promote) == 0:
            return promote, promote
        # Make room by demoting resident pages that are NOT needed this
        # step, coldest (least-recently-used) first.
        room = sim.hbm_budget_pages - sim.hbm_used
        need = max(0, len(promote) - room)
        if need:
            hbm_pages = np.nonzero(sim.placement == HBM)[0]
            keep = np.zeros(sim.trace.num_pages, dtype=bool)
            keep[want] = True
            cand = hbm_pages[~keep[hbm_pages]]
            order = np.argsort(sim.last_access[cand], kind="stable")
            demote = cand[order][:need]
            # If we still lack room, drop the excess promotions (HBM is
            # simply too small for this step's working set).
            room_after = room + len(demote)
            promote = promote[:room_after]
        else:
            demote = np.zeros(0, dtype=np.int64)
        return promote, demote
