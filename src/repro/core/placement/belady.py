"""Beyond-paper oracle: Belady (furthest-next-use) eviction + one-step
prefetch.

Classical optimal demand paging adapted to the two-tier KV problem:
pages needed at the current step are promoted (like Quest), and the
victim is always the resident page whose *next* use is furthest in the
future (instead of LRU / lowest-window-frequency). This gives a second,
differently-shaped upper bound to compare the paper's SA bound against:
SA optimizes *bandwidth overlap* via (W, R); Belady optimizes *misses*.
"""

from __future__ import annotations

import numpy as np

from repro.core.placement.base import DRAM, HBM, PlacementPolicy


class BeladyOracle(PlacementPolicy):
    name = "belady"
    uses_foresight = True

    def reset(self, sim) -> None:
        tr = sim.trace
        steps, pages = tr.access.shape
        # next_use[p] = first step >= current reading p (incrementally
        # maintained; INF when never read again).
        self._INF = steps + 1
        self._next_use = np.full(pages, self._INF, dtype=np.int64)
        # per-page sorted access steps + cursor
        self._access_steps = [np.nonzero(tr.access[:, p])[0]
                              for p in range(pages)]
        self._cursor = np.zeros(pages, dtype=np.int64)
        for p in range(pages):
            a = self._access_steps[p]
            self._next_use[p] = a[0] if len(a) else self._INF

    def _advance(self, sim, step: int) -> None:
        # pages whose recorded next use is in the past: move cursor
        stale = np.nonzero(self._next_use < step)[0]
        for p in stale:
            a = self._access_steps[p]
            c = self._cursor[p]
            while c < len(a) and a[c] < step:
                c += 1
            self._cursor[p] = c
            self._next_use[p] = a[c] if c < len(a) else self._INF

    def migrations(self, sim, step):
        self._advance(sim, step)
        tr = sim.trace
        want = np.nonzero(tr.access[step])[0]
        promote = want[sim.placement[want] == DRAM]
        if len(promote) == 0:
            return promote, promote
        room = sim.hbm_budget_pages - sim.hbm_used
        need = max(0, len(promote) - room)
        if need:
            resident = np.nonzero(sim.placement == HBM)[0]
            keep = np.zeros(tr.num_pages, dtype=bool)
            keep[want] = True
            cand = resident[~keep[resident]]
            order = np.argsort(-self._next_use[cand], kind="stable")
            demote = cand[order][:need]
            promote = promote[: room + len(demote)]
        else:
            demote = np.zeros(0, dtype=np.int64)
        return promote, demote
