"""Unlimited-HBM strategy (paper baseline #1): idealized, everything in HBM.

Implemented by placing all pages in HBM and never migrating; the
simulator is constructed with an infinite page budget for this policy
(see `repro.core.experiment.run_strategy`).
"""

from __future__ import annotations

import numpy as np

from repro.core.placement.base import HBM, PlacementPolicy


class UnlimitedHBM(PlacementPolicy):
    name = "unlimited"

    def reset(self, sim) -> None:
        # The experiment harness lifts the budget; assert it did.
        if sim.hbm_budget_pages < sim.trace.num_pages:
            sim.hbm_budget_pages = sim.trace.num_pages

    def place_new(self, sim, pages: np.ndarray) -> np.ndarray:
        return np.full(len(pages), HBM, dtype=np.int8)
