"""Static placement (paper baseline #2).

"KV cache entries are written once without subsequent migration. New
entries fill HBM until capacity is reached, after which they are placed
in off-package DRAM, with no dynamic relocation."

This is exactly the base-class `place_new` plus no migrations.
"""

from repro.core.placement.base import PlacementPolicy


class StaticPlacement(PlacementPolicy):
    name = "static"
    device_counterpart = "static"
