"""SA-guided scheduling (paper Section III-B) — the upper-bound policy.

At each step the policy looks at the *a-priori known* access patterns of
the next `W` decoding steps, ranks KV pages by access frequency within
that window (the paper's priority queue), and promotes the top-`R`
portion of the pages that are qualified for migration (i.e. pages that
the frequency ranking wants resident but that currently sit in DRAM).
Capacity is maintained by demoting the coldest-by-future-frequency
resident pages.

(W, R) are the two knobs the simulated-annealing optimizer in
`repro.core.sa` tunes; this module only executes a given (W, R).
"""

from __future__ import annotations

import math

import numpy as np

from repro.core.placement.base import DRAM, HBM, UNALLOC, PlacementPolicy


class SAGuided(PlacementPolicy):
    name = "sa"
    uses_foresight = True

    def __init__(self, window: int = 8, ratio: float = 0.5):
        assert window >= 1
        assert 0.0 <= ratio <= 1.0
        self.window = int(window)
        self.ratio = float(ratio)

    def reset(self, sim) -> None:
        tr = sim.trace
        w = min(self.window, tr.num_steps)
        # Running window sum of future accesses: freq[p] = number of steps
        # in [step, step+W) that read page p. Updated incrementally per
        # step (O(pages)) instead of a [steps, pages] cumulative table.
        self._freq = tr.access[:w].sum(axis=0).astype(np.int32)
        self._w = w

    def _advance(self, sim, step: int) -> None:
        # window slides from [step-1, ...) to [step, ...)
        tr = sim.trace
        if step == 0:
            return
        self._freq -= tr.access[step - 1]
        tail = step - 1 + self._w
        if tail < tr.num_steps:
            self._freq += tr.access[tail]

    def migrations(self, sim, step):
        self._advance(sim, step)
        freq = self._freq
        placement = sim.placement
        alive = placement != UNALLOC
        budget = sim.hbm_budget_pages

        # Ideal resident set: top-`budget` alive pages by future frequency
        # (only pages actually accessed in the window qualify).
        masked = np.where(alive & (freq > 0), freq, 0)
        hot = np.nonzero(masked > 0)[0]
        if len(hot) == 0:
            z = np.zeros(0, dtype=np.int64)
            return z, z
        if len(hot) > budget:
            part = np.argpartition(masked[hot], -budget)[-budget:]
            ideal = hot[part]
        else:
            ideal = hot

        qualified = ideal[placement[ideal] == DRAM]
        if len(qualified) == 0:
            z = np.zeros(0, dtype=np.int64)
            return z, z
        # Rank qualified pages by frequency (priority queue), promote the
        # top-R portion — R throttles migration overhead.
        order = np.argsort(-masked[qualified], kind="stable")
        k = int(math.ceil(self.ratio * len(qualified)))
        promote = qualified[order[:k]]

        room = budget - sim.hbm_used
        need = max(0, len(promote) - room)
        if need:
            resident = np.nonzero(placement == HBM)[0]
            cold_order = np.argsort(masked[resident], kind="stable")
            demote = resident[cold_order][:need]
            # Never swap a colder page in for a hotter one.
            if len(demote):
                keep = masked[promote] > masked[demote[
                    np.minimum(np.arange(len(promote)), len(demote) - 1)]]
                # promotions beyond available room must beat the evictee
                prom_final = np.concatenate(
                    [promote[:room], promote[room:][keep[room:]]])
                need = max(0, len(prom_final) - room)
                demote = demote[:need]
                promote = prom_final
        else:
            demote = np.zeros(0, dtype=np.int64)
        return promote, demote
