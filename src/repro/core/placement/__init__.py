from repro.core.placement.base import (
    PlacementPolicy, HBM, DRAM, UNALLOC,
)
from repro.core.placement.unlimited import UnlimitedHBM
from repro.core.placement.static import StaticPlacement
from repro.core.placement.reactive import ReactiveLRU
from repro.core.placement.quest_pages import QuestPages
from repro.core.placement.sa_guided import SAGuided
from repro.core.placement.belady import BeladyOracle
from repro.core.placement.cost_aware import CostAwareHysteresis

POLICIES = {
    "unlimited": UnlimitedHBM,
    "static": StaticPlacement,
    "reactive": ReactiveLRU,
    "quest": QuestPages,
    "sa": SAGuided,
    "belady": BeladyOracle,
    "cost_aware": CostAwareHysteresis,
}

__all__ = [
    "PlacementPolicy", "HBM", "DRAM", "UNALLOC", "POLICIES",
    "UnlimitedHBM", "StaticPlacement", "ReactiveLRU", "QuestPages",
    "SAGuided", "BeladyOracle", "CostAwareHysteresis",
]
