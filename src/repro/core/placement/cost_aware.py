"""Beyond-paper *deployable* policy: cost-aware hysteresis (no foresight).

The paper's SA bound assumes a-priori knowledge of future accesses. This
policy is the practical counterpart the paper calls for ("predictive
modeling ... online learning of token access patterns"): it keeps an
exponential moving average of each page's observed access rate and
promotes/demotes only when the *modeled benefit exceeds the modeled
migration cost* under the same Eq.(3)/(4) bandwidth constants — i.e. the
policy embeds the paper's latency model as its own decision criterion.

Hysteresis (promote_thresh > demote_thresh) plus a per-step migration
budget bounds M_i/M_o, which is exactly the failure mode that makes
ReactiveLRU collapse at low sparsity.
"""

from __future__ import annotations

import numpy as np

from repro.core.placement.base import DRAM, HBM, UNALLOC, PlacementPolicy


def migration_economics(spec) -> tuple[float, float]:
    """(gain_per_read, move_cost) in seconds/byte under the Eq.(3)/(4)
    bandwidth constants of a `MemorySystemSpec`: what one resident byte
    saves per read, and what moving one byte across the link costs.
    Shared by this simulator policy and its live device counterpart
    (`repro.serving.policies.CostAwarePolicy`)."""
    gain_per_read = 1.0 / spec.effective_dram_read_bw - 1.0 / spec.hbm_bw
    move_cost = 1.0 / spec.link_bw + 1.0 / spec.hbm_bw
    return gain_per_read, move_cost


def payback_threshold(spec, horizon_steps: float) -> float:
    """Minimum per-step access rate (or attention-mass share) at which
    promoting a page pays back its migration cost within
    `horizon_steps` steps: rate * gain_per_read * horizon > move_cost.
    Derived purely from the spec's HBM/link/DRAM bandwidth ratios, so a
    harsher link (TPU PCIe vs GH200 NVLink-C2C) raises the bar."""
    gain_per_read, move_cost = migration_economics(spec)
    return move_cost / (gain_per_read * horizon_steps)


def hysteresis_thresholds(spec, horizon_steps: float,
                          demote_ratio: float = 0.25
                          ) -> tuple[float, float]:
    """(promote, demote) payback thresholds for a spec: promote at the
    full payback bar, demote only when importance falls below
    `demote_ratio` of it (the hysteresis band that stops thrash).
    The serving `CostAwarePolicy` carries these as policy-state DATA so
    a tier-degradation fault can recalibrate them mid-stream without
    retracing the serve executable."""
    t_pro = payback_threshold(spec, horizon_steps)
    return t_pro, demote_ratio * t_pro


class CostAwareHysteresis(PlacementPolicy):
    name = "cost_aware"
    uses_foresight = False
    device_counterpart = "cost_aware"

    def __init__(self, ema: float = 0.15, promote_thresh: float = 0.5,
                 demote_thresh: float = 0.1,
                 migration_budget_frac: float = 0.05):
        self.ema = ema
        self.promote_thresh = promote_thresh
        self.demote_thresh = demote_thresh
        self.budget_frac = migration_budget_frac

    def reset(self, sim) -> None:
        self._rate = np.zeros(sim.trace.num_pages, dtype=np.float64)
        # benefit of an HBM-resident hot page per access (seconds/byte gap)
        self._gain_per_read, self._move_cost = migration_economics(sim.spec)

    def on_access(self, sim, step, accessed):
        hit = np.zeros(sim.trace.num_pages, dtype=np.float64)
        hit[accessed] = 1.0
        alive = sim.placement != UNALLOC
        self._rate[alive] = ((1 - self.ema) * self._rate[alive]
                             + self.ema * hit[alive])

        # Expected payback horizon: a page read at rate r gains
        # r * gain_per_read per step once resident; moving costs
        # move_cost once. Promote when payback < ~1/ema steps.
        horizon = 1.0 / self.ema
        worth = self._rate * self._gain_per_read * horizon > self._move_cost

        budget = max(1, int(self.budget_frac * sim.hbm_budget_pages))
        dram_pages = np.nonzero((sim.placement == DRAM) & worth
                                & (self._rate > self.promote_thresh))[0]
        order = np.argsort(-self._rate[dram_pages], kind="stable")
        promote = dram_pages[order][:budget]
        if len(promote) == 0:
            return promote, promote

        room = sim.hbm_budget_pages - sim.hbm_used
        need = max(0, len(promote) - room)
        if need:
            resident = np.nonzero(sim.placement == HBM)[0]
            cold = resident[self._rate[resident] < self.demote_thresh]
            order = np.argsort(self._rate[cold], kind="stable")
            demote = cold[order][:need]
            promote = promote[: room + len(demote)]
        else:
            demote = np.zeros(0, dtype=np.int64)
        return promote, demote
