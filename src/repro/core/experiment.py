"""Uniform harness to score placement strategies on a trace.

This is what the paper's Fig. 3/4/5 are made of: one trace, one memory
spec, five (plus our extra) strategies, identical byte accounting.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core import sa as sa_mod
from repro.core.placement import POLICIES, SAGuided, UnlimitedHBM
from repro.core.simulator import HeteroMemSimulator, SimResult
from repro.core.tiers import MemorySystemSpec
from repro.core.traces import Trace


@dataclasses.dataclass
class Workload:
    """Byte-accounting parameters of the modeled model."""
    bytes_per_token_layer: int
    num_layers: int
    weight_bytes: float = 0.0

    @classmethod
    def llama31_8b(cls) -> "Workload":
        # kv_heads=8, head_dim=128, bf16, 32 layers; weights ~16 GB.
        return cls(bytes_per_token_layer=2 * 8 * 128 * 2, num_layers=32,
                   weight_bytes=16e9)


def make_sim(trace: Trace, spec: MemorySystemSpec, policy,
             workload: Workload, hbm_kv_budget_bytes: Optional[float],
             include_weights: bool = False) -> HeteroMemSimulator:
    return HeteroMemSimulator(
        trace, spec, policy,
        bytes_per_token_layer=workload.bytes_per_token_layer,
        num_layers=workload.num_layers,
        hbm_kv_budget_bytes=hbm_kv_budget_bytes,
        weight_bytes=workload.weight_bytes,
        include_weights=include_weights,
    )


def run_strategy(name: str, trace: Trace, spec: MemorySystemSpec,
                 workload: Workload,
                 hbm_kv_budget_bytes: Optional[float] = None,
                 include_weights: bool = False,
                 sa_cfg: Optional[sa_mod.SAConfig] = None,
                 policy_kwargs: Optional[dict] = None,
                 ) -> SimResult:
    """Run one named strategy; for "sa" runs the annealer first."""
    policy_kwargs = dict(policy_kwargs or {})
    if name == "unlimited":
        sim = make_sim(trace, spec, UnlimitedHBM(), workload,
                       hbm_kv_budget_bytes=float("inf"),
                       include_weights=include_weights)
        sim.hbm_budget_pages = trace.num_pages + 1
        return sim.run()
    if name == "sa":
        sa_result = tune_sa(trace, spec, workload, hbm_kv_budget_bytes,
                            include_weights=include_weights, cfg=sa_cfg)
        w, r = sa_result.best_state
        policy = SAGuided(window=w, ratio=r)
        res = make_sim(trace, spec, policy, workload, hbm_kv_budget_bytes,
                       include_weights).run()
        res.policy = f"sa(W={w},R={r:.1f})"
        return res
    cls = POLICIES[name]
    policy = cls(**policy_kwargs)
    return make_sim(trace, spec, policy, workload, hbm_kv_budget_bytes,
                    include_weights).run()


def tune_sa(trace: Trace, spec: MemorySystemSpec, workload: Workload,
            hbm_kv_budget_bytes: Optional[float],
            include_weights: bool = False,
            cfg: Optional[sa_mod.SAConfig] = None) -> sa_mod.SAResult:
    def objective(w: int, r: float) -> float:
        policy = SAGuided(window=w, ratio=r)
        sim = make_sim(trace, spec, policy, workload, hbm_kv_budget_bytes,
                       include_weights)
        return sim.run().total_latency_s
    return sa_mod.anneal(objective, cfg=cfg)


def run_all(trace: Trace, spec: MemorySystemSpec, workload: Workload,
            hbm_kv_budget_bytes: Optional[float],
            strategies=("unlimited", "static", "reactive", "quest", "sa"),
            include_weights: bool = False,
            sa_cfg: Optional[sa_mod.SAConfig] = None,
            ) -> Dict[str, SimResult]:
    return {name: run_strategy(
                name, trace, spec, workload, hbm_kv_budget_bytes,
                include_weights=include_weights, sa_cfg=sa_cfg)
            for name in strategies}
