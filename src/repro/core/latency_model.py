"""Executable form of the paper's latency model (Section III-A, Eq. 1-5).

A decode step (n, l) moves five kinds of traffic:

  H_r  bytes read from HBM for inference (KV pages resident in HBM,
       plus model weights — weights are pinned in HBM per the paper)
  E_r  bytes read from off-package DRAM for inference
  H_w / E_w  newly written KV entries to HBM / DRAM
  M_i  KV bytes migrated DRAM -> HBM
  M_o  KV bytes migrated HBM -> DRAM

Eq. (3):  t_h = (H_r + H_w + M_i + M_o) / B_h
Eq. (4):  t_e = E_r / min(B_k, B_d)
               + max( (E_w + M_o)/B_k,          # link, host-bound dir
                      M_i / B_k,                # link, device-bound dir
                      (E_w + M_i + M_o)/B_d )   # DRAM channels
Eq. (2):  t   = max(t_h, t_e)
Eq. (1):  T   = sum over steps.

Everything is expressed over arrays so an entire decode trace is scored in
one vectorized call; both numpy and jax.numpy work (the module only uses
the array API surface they share).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.core.tiers import MemorySystemSpec

Array = Any  # np.ndarray or jax.Array


@dataclasses.dataclass
class StepTraffic:
    """Per-step traffic volumes in bytes. Fields broadcast together.

    Each field may be a scalar or an array of shape [num_steps] (or any
    common broadcast shape, e.g. [num_tokens, num_layers]).
    """

    h_read: Array = 0.0
    e_read: Array = 0.0
    h_write: Array = 0.0
    e_write: Array = 0.0
    m_in: Array = 0.0   # DRAM -> HBM migration
    m_out: Array = 0.0  # HBM -> DRAM migration

    def scale(self, factor: float) -> "StepTraffic":
        return StepTraffic(
            h_read=self.h_read * factor,
            e_read=self.e_read * factor,
            h_write=self.h_write * factor,
            e_write=self.e_write * factor,
            m_in=self.m_in * factor,
            m_out=self.m_out * factor,
        )

    def __add__(self, other: "StepTraffic") -> "StepTraffic":
        """Elementwise sum — aggregate per-layer (or per-lane) traffic
        into one per-step volume before pricing Eq. (2)."""
        return StepTraffic(
            h_read=self.h_read + other.h_read,
            e_read=self.e_read + other.e_read,
            h_write=self.h_write + other.h_write,
            e_write=self.e_write + other.e_write,
            m_in=self.m_in + other.m_in,
            m_out=self.m_out + other.m_out,
        )

    @classmethod
    def from_page_counts(cls, *, n_hbm_read: Array, n_dram_read: Array,
                         n_promote: Array, n_demote: Array,
                         page_bytes: float, h_write: Array = 0.0,
                         e_write: Array = 0.0) -> "StepTraffic":
        """Traffic volumes from page-granular counts — the shape the
        live engine's telemetry and the simulator both emit."""
        return cls(h_read=np.asarray(n_hbm_read, np.float64) * page_bytes,
                   e_read=np.asarray(n_dram_read, np.float64) * page_bytes,
                   h_write=h_write, e_write=e_write,
                   m_in=np.asarray(n_promote, np.float64) * page_bytes,
                   m_out=np.asarray(n_demote, np.float64) * page_bytes)


def degraded_spec(spec: MemorySystemSpec, *, hbm_scale: float = 1.0,
                  link_scale: float = 1.0,
                  dram_scale: float = 1.0) -> MemorySystemSpec:
    """`spec` with its bandwidths scaled — the pricing view of a
    host-tier degradation / latency-spike window (scale < 1 slows the
    tier). Capacities are untouched: a degraded link still addresses
    the same bytes, it just moves them slower. Used by the serving
    fault plane (`repro.serving.faults`) so Eq. (1)-(5) price a
    degraded window with degraded constants, and by the cost_aware
    payback recalibration, which re-derives its thresholds from the
    degraded spec."""
    if min(hbm_scale, link_scale, dram_scale) <= 0.0:
        raise ValueError("bandwidth scales must be positive")
    return dataclasses.replace(
        spec,
        hbm_bw=spec.hbm_bw * hbm_scale,
        link_bw=spec.link_bw * link_scale,
        dram_bw=spec.dram_bw * dram_scale,
    )


def hbm_latency(t: StepTraffic, spec: MemorySystemSpec) -> Array:
    """Eq. (3)."""
    return (t.h_read + t.h_write + t.m_in + t.m_out) / spec.hbm_bw


def dram_latency(t: StepTraffic, spec: MemorySystemSpec) -> Array:
    """Eq. (4)."""
    read_term = t.e_read / spec.effective_dram_read_bw
    link_out = (t.e_write + t.m_out) / spec.link_bw   # toward DRAM
    link_in = t.m_in / spec.link_bw                   # toward HBM
    dram_chan = (t.e_write + t.m_in + t.m_out) / spec.dram_bw
    xfer_term = np.maximum(np.maximum(link_out, link_in), dram_chan)
    return read_term + xfer_term


def step_latency(t: StepTraffic, spec: MemorySystemSpec) -> Array:
    """Eq. (2): the two tiers operate concurrently; the step waits for both."""
    return np.maximum(hbm_latency(t, spec), dram_latency(t, spec))


def total_latency(t: StepTraffic, spec: MemorySystemSpec) -> float:
    """Eq. (1)."""
    return float(np.sum(step_latency(t, spec)))


def tokens_per_second(t: StepTraffic, spec: MemorySystemSpec,
                      num_tokens: int) -> float:
    T = total_latency(t, spec)
    return num_tokens / T if T > 0 else float("inf")


# ---------------------------------------------------------------------------
# Workload byte-accounting helpers
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KVWorkload:
    """Static byte-accounting for a decode workload on a given model.

    bytes_per_token_layer: KV bytes appended per generated token per layer
                           (2 * kv_heads * head_dim * dtype_bytes).
    weight_bytes_per_layer_step: weight bytes streamed from HBM per layer
                           per decode step (weights are pinned in HBM).
    num_layers, prompt_len, decode_len: trace dimensions.
    """

    bytes_per_token_layer: int
    weight_bytes_per_layer_step: int
    num_layers: int
    prompt_len: int
    decode_len: int

    @property
    def page_bytes(self) -> int:
        raise AttributeError("page size lives in the placement policy")

    def kv_bytes_total(self) -> int:
        return (self.prompt_len + self.decode_len) * self.num_layers \
            * self.bytes_per_token_layer


def gqa_kv_bytes_per_token_layer(kv_heads: int, head_dim: int,
                                 dtype_bytes: int = 2) -> int:
    return 2 * kv_heads * head_dim * dtype_bytes
