"""Attention access-pattern traces for the placement simulator.

The paper records layerwise attention scores from LLaMA-3.1-8B on
LongBench (30k-token prompts, 10k decoded tokens) and uses them as the
access pattern. We provide:

  * `synthetic_trace` — a generative model with the two knobs the paper's
    sensitivity study varies: attention *sparsity* (fraction of past
    tokens excluded per step) and *importance variation* (how fast the
    set of important tokens drifts). Importance is spatially clustered
    (heavy-hitter pages + attention sinks + a recency window), matching
    the published observations that motivate Quest-style paging.
  * `trace_from_scores` — build a trace from real attention scores
    (e.g. captured from `repro.models` on CPU) by thresholding to a
    sparsity target.

A `Trace` is page-granular: `access[s, p]` says whether page `p` is read
at decode step `s`. Pages hold `page_tokens` tokens; page `p` exists once
`page_born[p] <= s`. Token granularity is the special case
`page_tokens=1`.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Trace:
    access: np.ndarray        # bool [steps, num_pages]
    page_born: np.ndarray     # int32 [num_pages] — step at which page exists
    page_tokens: int
    prompt_len: int           # tokens
    decode_len: int           # steps == decoded tokens
    sparsity: float           # realized mean sparsity (fraction skipped)

    @property
    def num_steps(self) -> int:
        return self.access.shape[0]

    @property
    def num_pages(self) -> int:
        return self.access.shape[1]

    def alive(self, step: int) -> np.ndarray:
        return self.page_born <= step

    def validate(self) -> None:
        # Invariant: a page is never accessed before it exists.
        steps = np.arange(self.num_steps)[:, None]
        premature = self.access & (self.page_born[None, :] > steps)
        assert not premature.any(), "access before page birth"


def _pages_for(tokens: int, page_tokens: int) -> int:
    return -(-tokens // page_tokens)


def synthetic_trace(
    prompt_len: int,
    decode_len: int,
    *,
    page_tokens: int = 16,
    sparsity: float = 0.6,
    variation: float = 0.3,
    sink_pages: int = 4,
    recency_pages: int = 8,
    heavy_frac: float = 0.08,
    seed: int = 0,
) -> Trace:
    """Clustered, drifting attention access pattern.

    variation in [0, 1]: 0 -> the important-page set is frozen;
    1 -> it is resampled every step (paper's "high variation").
    Importance follows an AR(1) (Ornstein-Uhlenbeck-like) process over a
    lognormal heavy-hitter base, so a `heavy_frac` subset of pages
    dominates at any instant but the subset drifts at rate `variation`.
    """
    rng = np.random.default_rng(seed)
    prompt_pages = _pages_for(prompt_len, page_tokens)
    total_pages = _pages_for(prompt_len + decode_len, page_tokens)

    # Birth step of each page: prompt pages exist at step 0; decode pages
    # appear as tokens are generated.
    page_born = np.zeros(total_pages, dtype=np.int32)
    for p in range(prompt_pages, total_pages):
        first_token = p * page_tokens  # global token index
        page_born[p] = max(0, first_token - prompt_len)

    # Base importance: lognormal heavy hitters (a small fraction of pages
    # carries most attention mass, as in H2O / Quest observations).
    base = rng.lognormal(mean=0.0, sigma=2.0, size=total_pages)
    heavy = rng.random(total_pages) < heavy_frac
    base[heavy] *= 10.0

    # AR(1) drift: score_t = rho * score_{t-1} + (1-rho) * noise_t
    rho = 1.0 - variation
    access = np.zeros((decode_len, total_pages), dtype=bool)
    score = base * rng.lognormal(0.0, 1.0, size=total_pages)
    keep_frac = max(1.0 - sparsity, 1e-3)

    realized_reads = 0
    realized_alive = 0
    for s in range(decode_len):
        if variation > 0:
            noise = base * rng.lognormal(0.0, 1.0, size=total_pages)
            score = rho * score + (1.0 - rho) * noise
        alive = page_born <= s
        n_alive = int(alive.sum())
        k = max(1, int(round(keep_frac * n_alive)))
        # Top-k alive pages by current importance score.
        masked = np.where(alive, score, -np.inf)
        top = np.argpartition(masked, -k)[-k:]
        row = access[s]
        row[top] = True
        # Attention sinks: first pages are always read.
        row[:min(sink_pages, n_alive)] = True
        # Recency window: latest alive pages always read.
        alive_idx = np.nonzero(alive)[0]
        row[alive_idx[-recency_pages:]] = True
        row &= alive
        realized_reads += int(row.sum())
        realized_alive += n_alive

    realized_sparsity = 1.0 - realized_reads / max(realized_alive, 1)
    tr = Trace(
        access=access,
        page_born=page_born,
        page_tokens=page_tokens,
        prompt_len=prompt_len,
        decode_len=decode_len,
        sparsity=float(realized_sparsity),
    )
    tr.validate()
    return tr


def trace_from_scores(
    scores: np.ndarray,
    prompt_len: int,
    *,
    page_tokens: int = 16,
    sparsity: float = 0.6,
    sink_pages: int = 2,
    recency_pages: int = 4,
) -> Trace:
    """Build a trace from real attention scores.

    scores: [decode_steps, total_tokens] nonneg attention mass that step
            assigns to each past token (zero for not-yet-generated ones).
    A page is accessed if its pooled score is in the top-(1-sparsity)
    fraction of alive pages at that step.
    """
    decode_len, total_tokens = scores.shape
    num_pages = _pages_for(total_tokens, page_tokens)
    pad = num_pages * page_tokens - total_tokens
    if pad:
        scores = np.pad(scores, ((0, 0), (0, pad)))
    # Max-pool token scores to page scores (Quest-style page metadata).
    page_scores = scores.reshape(decode_len, num_pages, page_tokens).max(-1)

    page_born = np.zeros(num_pages, dtype=np.int32)
    for p in range(_pages_for(prompt_len, page_tokens), num_pages):
        page_born[p] = max(0, p * page_tokens - prompt_len)

    access = np.zeros((decode_len, num_pages), dtype=bool)
    keep_frac = max(1.0 - sparsity, 1e-3)
    for s in range(decode_len):
        alive = page_born <= s
        n_alive = int(alive.sum())
        k = max(1, int(round(keep_frac * n_alive)))
        masked = np.where(alive, page_scores[s], -np.inf)
        top = np.argpartition(masked, -k)[-k:]
        row = access[s]
        row[top] = True
        row[:min(sink_pages, n_alive)] = True
        alive_idx = np.nonzero(alive)[0]
        row[alive_idx[-recency_pages:]] = True
        row &= alive
        access[s] = row

    realized = 1.0 - access.sum() / max((page_born[None, :] <=
                                         np.arange(decode_len)[:, None]).sum(), 1)
    tr = Trace(access=access, page_born=page_born, page_tokens=page_tokens,
               prompt_len=prompt_len, decode_len=decode_len,
               sparsity=float(realized))
    tr.validate()
    return tr
