"""Training step: next-token loss, grads, AdamW, remat — pure JAX.

The step is written against the Model facade so every assigned
architecture trains through the same entry point (the train_4k dry-runs
lower exactly this function). Gradient accumulation and a bf16
compute / f32 optimizer-state split are built in.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training.optimizer import AdamWState, adamw_init, adamw_update


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: AdamWState


def loss_fn(model: Model, params, tokens, *, extra: Optional[Dict] = None,
            logit_chunk: int = 512):
    """Causal LM loss. tokens [B, S]; shift-by-one inside.

    The [B, S, vocab] logits tensor is never materialized: hidden states
    are unembedded in sequence chunks (vocab-parallel friendly; keeps
    peak memory ~ B * chunk * vocab).
    """
    from repro.models.layers import constrain_batch
    cfg = model.cfg
    hidden = constrain_batch(
        model.forward_hidden(params, tokens[:, :-1], extra=extra))
    # VLM prepends patch embeddings: loss only over the text tail
    if cfg.family == "vlm":
        hidden = hidden[:, -(tokens.shape[1] - 1):]
    targets = tokens[:, 1:]
    w = (params["embed"].T if cfg.tie_embeddings else params["unembed"])

    B, S, D = hidden.shape
    c = min(logit_chunk, S)
    pad = (-S) % c
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
    nc = (S + pad) // c
    valid = (jnp.arange(S + pad) < S).astype(jnp.float32)
    valid = jnp.broadcast_to(valid, (B, S + pad))
    hc = hidden.reshape(B, nc, c, D).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, nc, c).transpose(1, 0, 2)
    vc = valid.reshape(B, nc, c).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(h_blk, t_blk, v_blk):
        logits = jnp.einsum("bcd,dv->bcv", h_blk, w).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, t_blk[..., None], axis=-1)[..., 0]
        return jnp.sum((logz - gold) * v_blk)

    def body(acc, xs):
        h_blk, t_blk, v_blk = xs
        return acc + chunk_loss(h_blk, t_blk, v_blk), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            (hc, tc, vc))
    return total / (B * S)


def make_train_step(model: Model, *, accum_steps: int = 1,
                    extra_keys: tuple = (), lr=None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    batch: {"tokens": [B, S]} (+ modality extras). With accum_steps > 1
    the batch's leading dim is split into micro-batches and gradients
    are accumulated in f32 before one optimizer update.
    """

    def grads_of(params, tokens, extra):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(model, p, tokens, extra=extra))(params)
        return loss, grads

    def train_step(state: TrainState, batch: Dict) -> tuple:
        tokens = batch["tokens"]
        extra = {k: batch[k] for k in extra_keys} or None

        if accum_steps == 1:
            loss, grads = grads_of(state.params, tokens, extra)
        else:
            B = tokens.shape[0]
            mb = B // accum_steps

            def micro(i, carry):
                acc, loss_acc = carry
                sl = jax.lax.dynamic_slice_in_dim(tokens, i * mb, mb, 0)
                ex = None
                if extra is not None:
                    ex = {k: jax.lax.dynamic_slice_in_dim(v, i * mb, mb, 0)
                          for k, v in extra.items()}
                loss, g = grads_of(state.params, sl, ex)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return acc, loss_acc + loss

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            grads, loss_sum = jax.lax.fori_loop(
                0, accum_steps, micro, (zero, jnp.zeros((), jnp.float32)))
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss_sum / accum_steps

        params, opt = adamw_update(grads, state.opt, state.params, lr=lr)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return TrainState(params=params, opt=opt), {
            "loss": loss, "grad_norm": gnorm, "step": opt.step}

    return train_step


def init_train_state(model: Model, rng) -> TrainState:
    params = model.init(rng)
    return TrainState(params=params, opt=adamw_init(params))
