"""AdamW + cosine schedule, pure JAX (no optax in this environment).

Optimizer state mirrors the parameter pytree (m, v) and is sharded with
the same PartitionSpecs as the parameters, so under FSDP the optimizer
state is fully sharded too (ZeRO-style).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    def zeros(p):
        return jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def cosine_schedule(step, *, peak_lr=3e-4, warmup=100, total=10_000,
                    min_frac=0.1):
    step = step.astype(jnp.float32)
    warm = peak_lr * step / max(warmup, 1)
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (min_frac + (1 - min_frac) * 0.5
                     * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos)


def adamw_update(grads, state: AdamWState, params, *, lr=None,
                 b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
                 grad_clip=1.0) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    if lr is None:
        lr = cosine_schedule(step)

    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                     state.v, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(step=step, m=m, v=v)
