from repro.training.optimizer import AdamWState, adamw_init, adamw_update
from repro.training.train_step import loss_fn, make_train_step, TrainState

__all__ = ["AdamWState", "adamw_init", "adamw_update", "loss_fn",
           "make_train_step", "TrainState"]
