"""Parameter schema system: one declaration drives init, abstract
shapes (for the allocation-free dry-run), and logical sharding axes.

A schema is a pytree (nested dicts) of `Param` leaves. Logical axis
names are resolved to mesh axes by `repro.launch.shardings`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Param:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]     # logical axis per dim (None = replicated)
    init: str = "normal"                # normal | zeros | ones | embed
    fan_in_axes: Tuple[int, ...] = ()   # dims forming fan-in for scaling

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Schema = Dict[str, Any]  # nested dict with Param leaves


def _is_leaf(x) -> bool:
    return isinstance(x, Param)


def init_params(schema: Schema, rng: jax.Array,
                dtype=jnp.bfloat16) -> Any:
    leaves, treedef = jax.tree.flatten(schema, is_leaf=_is_leaf)
    rngs = jax.random.split(rng, len(leaves))
    out = []
    for p, r in zip(leaves, rngs):
        if p.init == "zeros":
            out.append(jnp.zeros(p.shape, dtype))
        elif p.init == "ones":
            out.append(jnp.ones(p.shape, dtype))
        else:
            fan_in = (np.prod([p.shape[i] for i in p.fan_in_axes])
                      if p.fan_in_axes else p.shape[0] if p.shape else 1)
            scale = 0.02 if p.init == "embed" else 1.0 / math.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(r, p.shape, jnp.float32)
                        * scale).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def abstract_params(schema: Schema, dtype=jnp.bfloat16) -> Any:
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype),
        schema, is_leaf=_is_leaf)


def logical_axes(schema: Schema) -> Any:
    return jax.tree.map(lambda p: p.axes, schema, is_leaf=_is_leaf)


def param_bytes(schema: Schema, dtype_bytes: int = 2) -> int:
    total = 0
    for p in jax.tree.leaves(schema, is_leaf=_is_leaf):
        total += int(np.prod(p.shape)) * dtype_bytes
    return total


def count_params(schema: Schema) -> int:
    return sum(int(np.prod(p.shape))
               for p in jax.tree.leaves(schema, is_leaf=_is_leaf))
