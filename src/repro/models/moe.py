"""Mixture-of-Experts FFN with capacity-based einsum dispatch (GShard
style) — the TPU-native MoE formulation: dispatch/combine are matmuls,
so under (data, model) sharding XLA lowers them to all-to-all-class
collectives instead of host-side scatter.

Tokens are processed in groups to bound the [group, E, capacity]
dispatch tensor; group size is a tunable (a §Perf knob). Experts are
sharded over the `model` ("expert") axis.

Supports top-1 (llama4-maverick) through top-8 (granite-moe), optional
always-on shared expert, and interleaved dense/MoE layer stacks.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import constrain_batch, rms_norm
from repro.models.params import Param


def moe_schema(cfg: ModelConfig, L: int):
    d, f = cfg.d_model, cfg.d_ff
    E = cfg.moe.num_experts_padded
    s = {
        "moe_norm": Param((L, d), ("layers", "embed"), "ones"),
        "router": Param((L, d, cfg.moe.num_experts),
                        ("layers", "embed", None), fan_in_axes=(1,)),
        "we_gate": Param((L, E, d, f), ("layers", "experts", "embed", "mlp"),
                         fan_in_axes=(2,)),
        "we_up": Param((L, E, d, f), ("layers", "experts", "embed", "mlp"),
                       fan_in_axes=(2,)),
        "we_down": Param((L, E, f, d), ("layers", "experts", "mlp", "embed"),
                         fan_in_axes=(2,)),
    }
    if cfg.moe.shared_expert:
        s["ws_gate"] = Param((L, d, f), ("layers", "embed", "mlp"),
                             fan_in_axes=(1,))
        s["ws_up"] = Param((L, d, f), ("layers", "embed", "mlp"),
                           fan_in_axes=(1,))
        s["ws_down"] = Param((L, f, d), ("layers", "mlp", "embed"),
                             fan_in_axes=(1,))
    return s


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def moe_ffn(x: jax.Array, lp, cfg: ModelConfig, *,
            group_size: Optional[int] = None) -> jax.Array:
    """x: [B, S, d] -> [B, S, d] routed through experts.

    Routing: softmax over experts, top-k, per-expert capacity
    C = k * group / E * capacity_factor (tokens over capacity are
    dropped — their residual path still carries them).
    """
    moe = cfg.moe
    B, S, d = x.shape
    T_real = B * S
    xt = x.reshape(T_real, d)
    if group_size is None:
        group_size = min(T_real, moe.group_size)
    # pad the token stream to a group multiple; padded rows route like
    # normal tokens (consuming capacity of at most one group) and their
    # outputs are sliced away below.
    pad = (-T_real) % group_size
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    T = T_real + pad
    G = T // group_size
    E, k = moe.num_experts, moe.top_k
    C = _round_up(max(int(group_size * k / E * moe.capacity_factor), 4), 4)

    xg = xt.reshape(G, group_size, d)
    logits = jnp.einsum("gsd,de->gse", xg, lp["router"]).astype(jnp.float32)
    E_pad = moe.num_experts_padded
    if E_pad != E:
        # physical expert padding (EP divisibility): padded experts are
        # unreachable by routing; one_hot below targets E_pad columns.
        logits = jnp.pad(logits, ((0, 0), (0, 0), (0, E_pad - E)),
                         constant_values=-1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # [G,s,k]
    # normalize selected gates (standard for k>1)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # position-in-expert via cumulative counts across the k choices
    dispatch = jnp.zeros((G, group_size, E_pad, C), jnp.bool_)
    combine = jnp.zeros((G, group_size, E_pad, C), jnp.float32)
    counts = jnp.zeros((G, E_pad), jnp.int32)
    for j in range(k):
        onehot = jax.nn.one_hot(gate_idx[..., j], E_pad, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=1) - 1 + counts[:, None, :]
        counts = counts + onehot.sum(axis=1)
        within = (pos < C) & (onehot > 0)
        pos_c = jnp.clip(pos, 0, C - 1)
        oh_c = jax.nn.one_hot(pos_c, C, dtype=jnp.float32) \
            * within[..., None].astype(jnp.float32)          # [G,s,E,C]
        dispatch = dispatch | (oh_c > 0)
        combine = combine + oh_c * gate_vals[..., j][..., None, None] \
            * onehot[..., None].astype(jnp.float32)

    dd = dispatch.astype(cfg.dtype)
    expert_in = jnp.einsum("gsec,gsd->gecd", dd, xg)         # [G,E,C,d]
    h = (jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, lp["we_gate"]))
         * jnp.einsum("gecd,edf->gecf", expert_in, lp["we_up"]))
    expert_out = jnp.einsum("gecf,efd->gecd", h, lp["we_down"])
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(cfg.dtype), expert_out)

    if moe.shared_expert:
        sh = (jax.nn.silu(jnp.einsum("gsd,df->gsf", xg, lp["ws_gate"]))
              * jnp.einsum("gsd,df->gsf", xg, lp["ws_up"]))
        y = y + jnp.einsum("gsf,fd->gsd", sh, lp["ws_down"])

    return y.reshape(T, d)[:T_real].reshape(B, S, d)


def moe_block(h, lp, cfg: ModelConfig, *, group_size=None):
    h = constrain_batch(h)
    x = rms_norm(h, lp["moe_norm"], cfg.norm_eps)
    return h + moe_ffn(x, lp, cfg, group_size=group_size)
