"""Architecture configuration — one dataclass drives every family.

A `ModelConfig` fully determines parameter schema, forward pass, cache
kind and sharding. The ten assigned architectures are instantiated in
`repro.configs.<id>` from public-literature values.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    #: apply MoE every `interleave`-th layer (1 = every layer); other
    #: layers use a dense FFN of size d_ff.
    interleave: int = 1
    capacity_factor: float = 1.25
    #: llama4-style always-on shared expert (same size as one expert)
    shared_expert: bool = False
    #: pad the PHYSICAL expert count up to this multiple so experts
    #: divide the model mesh axis (EP). Padded experts are masked out
    #: of routing — the logical model is unchanged. §Perf iteration M1:
    #: granite-moe's 40 experts pad to 48 on a 16-way axis.
    pad_experts_to: int = 0
    #: token-group size for capacity dispatch; the [G, S, E, C] dispatch
    #: tensor scales with S*C ~ group^2/E — §Perf iteration M2 knob.
    group_size: int = 1024

    @property
    def num_experts_padded(self) -> int:
        if self.pad_experts_to <= 0:
            return self.num_experts
        p = self.pad_experts_to
        return -(-self.num_experts // p) * p


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64          # N: per-channel state size (Mamba2)
    conv_width: int = 4
    expand: int = 2              # inner dim = expand * d_model
    chunk: int = 128             # chunked-scan block length
    #: hybrid (zamba2): apply a weight-shared attention block every
    #: `attn_every` SSM blocks; 0 disables attention entirely.
    attn_every: int = 0


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    #: every `slstm_every`-th block is an sLSTM block, the rest mLSTM
    slstm_every: int = 4
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    enc_layers: int
    #: encoder input length (frames after the stubbed conv frontend)
    enc_positions: int = 1500
    #: learned decoder position table size (>= longest decode shape)
    dec_positions: int = 40960


@dataclasses.dataclass(frozen=True)
class FrontendStub:
    """Modality frontend stub: input_specs() provides precomputed
    frame/patch embeddings of shape [batch, num_embeddings, d_model]."""
    kind: str                    # "audio" | "vision"
    num_embeddings: int          # frames or patches


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | encdec | vlm | ssm | xlstm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: jnp.dtype = jnp.bfloat16
    param_dtype: jnp.dtype = jnp.bfloat16
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encdec: Optional[EncDecConfig] = None
    frontend: Optional[FrontendStub] = None
    #: KV page size in tokens for the two-tier paged cache
    kv_page_tokens: int = 16
    #: supports O(sub-quadratic) decode at 500k context
    subquadratic: bool = False
    #: the tokenizer's end-of-sequence id (public value per arch; None
    #: when the config predates EOS plumbing). The serving stack reads
    #: it through `EngineConfig(eos_id=model.cfg.eos_id)` — generated
    #: traffic stops on the REAL id, not a probed sentinel.
    eos_id: Optional[int] = None

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)
        assert self.num_heads % max(self.kv_heads, 1) == 0
        assert self.eos_id is None or 0 <= self.eos_id < self.vocab, \
            f"eos_id {self.eos_id} outside vocab {self.vocab}"

    # --- derived sizes -----------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.kv_heads

    def kv_bytes_per_token_layer(self, dtype_bytes: int = 2) -> int:
        return 2 * self.kv_heads * self.head_dim * dtype_bytes

    def attention_layer_ids(self) -> Tuple[int, ...]:
        """Layers that own a KV cache (hybrid archs: only shared-attn sites)."""
        if self.family in ("ssm", "xlstm"):
            return ()
        if self.family == "hybrid":
            assert self.ssm is not None and self.ssm.attn_every > 0
            return tuple(range(self.ssm.attn_every - 1, self.num_layers,
                               self.ssm.attn_every))
        return tuple(range(self.num_layers))

    def param_count(self) -> int:
        """Approximate parameter count (used for 6ND roofline maths)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.num_layers
        h, kh, hd = self.num_heads, self.kv_heads, self.head_dim
        attn = d * h * hd + 2 * d * kh * hd + h * hd * d
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family in ("ssm", "xlstm"):
            inner = (self.ssm.expand if self.ssm else
                     self.xlstm.expand) * d
            blk = 2 * d * inner + inner * d + inner * 8  # rough
            return L * blk + emb
        mlp = 3 * d * f
        if self.moe:
            moe_layers = len(range(self.moe.interleave - 1, L,
                                   self.moe.interleave))
            dense_layers = L - moe_layers
            moe_mlp = moe_layers * (self.moe.num_experts * 3 * d * f
                                    + d * self.moe.num_experts
                                    + (3 * d * f if self.moe.shared_expert
                                       else 0))
            body = L * attn + dense_layers * mlp + moe_mlp
        elif self.family == "hybrid":
            n_attn = len(self.attention_layer_ids())
            inner = self.ssm.expand * d
            ssm_blk = 2 * d * inner + inner * d
            body = (L * ssm_blk + n_attn * 0  # shared attn counted once
                    + attn + mlp)
        else:
            body = L * (attn + mlp)
        if self.encdec:
            body += self.encdec.enc_layers * (attn + mlp) + L * attn  # cross
        return body + emb

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if not self.moe:
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.num_layers
        full = self.param_count()
        moe_layers = len(range(self.moe.interleave - 1, L,
                               self.moe.interleave))
        all_experts = moe_layers * self.moe.num_experts * 3 * d * f
        active_experts = moe_layers * self.moe.top_k * 3 * d * f
        return full - all_experts + active_experts
