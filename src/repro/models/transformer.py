"""Decoder-only + encoder-decoder transformer families.

Covers: internlm2 / granite / qwen3 (qk_norm) / stablelm (dense GQA),
internvl2 (VLM = dense backbone over [patch_embeds; token_embeds]),
whisper (enc-dec with stubbed conv frontend), and the attention blocks
of the MoE and hybrid families (moe.py / ssm.py reuse `attn_qkv` etc.).

All layer stacks are `lax.scan`s over stacked parameters: compile time
and HLO size are depth-independent, which is what makes the 64-layer /
512-device dry-runs tractable, and the remat policy wraps the scan body.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kvcache.paged import (
    IMPORTANCE_EMA, PagedKVCache, allocate_prompt_pages,
    write_token_layer, write_tokens_layer,
)
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_rope, attention, constrain_batch, layer_norm,
    prefix_chunk_attention, repeat_kv, rms_norm, swiglu,
)
from repro.models.params import Param


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------

def attn_schema(cfg: ModelConfig, L: int, prefix_axes=("layers",)):
    d, h, kh, hd = cfg.d_model, cfg.num_heads, cfg.kv_heads, cfg.head_dim
    Lax = prefix_axes
    Ld = (L,) if L else ()
    s = {
        "attn_norm": Param(Ld + (d,), Lax + ("embed",), "ones"),
        "wq": Param(Ld + (d, h, hd), Lax + ("embed", "heads", "head_dim"),
                    fan_in_axes=(len(Ld),)),
        "wk": Param(Ld + (d, kh, hd), Lax + ("embed", "kv_heads", "head_dim"),
                    fan_in_axes=(len(Ld),)),
        "wv": Param(Ld + (d, kh, hd), Lax + ("embed", "kv_heads", "head_dim"),
                    fan_in_axes=(len(Ld),)),
        "wo": Param(Ld + (h, hd, d), Lax + ("heads", "head_dim", "embed"),
                    fan_in_axes=(len(Ld), len(Ld) + 1)),
    }
    if cfg.qk_norm:
        s["q_norm"] = Param(Ld + (hd,), Lax + ("head_dim",), "ones")
        s["k_norm"] = Param(Ld + (hd,), Lax + ("head_dim",), "ones")
    return s


def mlp_schema(cfg: ModelConfig, L: int, prefix_axes=("layers",)):
    d, f = cfg.d_model, cfg.d_ff
    Ld = (L,) if L else ()
    Lax = prefix_axes
    return {
        "mlp_norm": Param(Ld + (d,), Lax + ("embed",), "ones"),
        "w_gate": Param(Ld + (d, f), Lax + ("embed", "mlp"),
                        fan_in_axes=(len(Ld),)),
        "w_up": Param(Ld + (d, f), Lax + ("embed", "mlp"),
                      fan_in_axes=(len(Ld),)),
        "w_down": Param(Ld + (f, d), Lax + ("mlp", "embed"),
                        fan_in_axes=(len(Ld),)),
    }


def dense_schema(cfg: ModelConfig):
    L = cfg.num_layers
    s = {
        "embed": Param((cfg.vocab, cfg.d_model), ("vocab", "embed"), "embed"),
        "final_norm": Param((cfg.d_model,), ("embed",), "ones"),
        "layers": {**attn_schema(cfg, L), **mlp_schema(cfg, L)},
    }
    if not cfg.tie_embeddings:
        s["unembed"] = Param((cfg.d_model, cfg.vocab), ("embed", "vocab"),
                             fan_in_axes=(0,))
    return s


def encdec_schema(cfg: ModelConfig):
    """Whisper-style: LN+bias, GELU MLP, learned positions, cross-attn."""
    d, f = cfg.d_model, cfg.d_ff
    Le = cfg.encdec.enc_layers
    Ld = cfg.num_layers

    def ln(L):
        return {
            "w": Param((L, d), ("layers", "embed"), "ones"),
            "b": Param((L, d), ("layers", "embed"), "zeros"),
        }

    def attn(L):
        base = attn_schema(cfg, L)
        del base["attn_norm"]
        return base

    def mlp(L):
        return {
            "w_in": Param((L, d, f), ("layers", "embed", "mlp"),
                          fan_in_axes=(1,)),
            "b_in": Param((L, f), ("layers", "mlp"), "zeros"),
            "w_out": Param((L, f, d), ("layers", "mlp", "embed"),
                           fan_in_axes=(1,)),
            "b_out": Param((L, d), ("layers", "embed"), "zeros"),
        }

    return {
        "embed": Param((cfg.vocab, d), ("vocab", "embed"), "embed"),
        "dec_pos": Param((cfg.encdec.dec_positions, d),
                         (None, "embed"), "embed"),
        "enc_pos": Param((cfg.encdec.enc_positions, d), (None, "embed"),
                         "embed"),
        "enc_layers": {
            "ln1": ln(Le), "attn": attn(Le), "ln2": ln(Le), "mlp": mlp(Le),
        },
        "enc_final": {"w": Param((d,), ("embed",), "ones"),
                      "b": Param((d,), ("embed",), "zeros")},
        "dec_layers": {
            "ln1": ln(Ld), "self_attn": attn(Ld),
            "ln2": ln(Ld), "cross_attn": attn(Ld),
            "ln3": ln(Ld), "mlp": mlp(Ld),
        },
        "dec_final": {"w": Param((d,), ("embed",), "ones"),
                      "b": Param((d,), ("embed",), "zeros")},
    }


# ---------------------------------------------------------------------------
# Forward building blocks
# ---------------------------------------------------------------------------

def attn_qkv(x, lp, cfg: ModelConfig, positions, rope: bool = True):
    """x [B,S,d] -> q [B,S,H,HD], k/v [B,S,KH,HD] (RoPE applied)."""
    q = jnp.einsum("bsd,dhk->bshk", x, lp["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, lp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, lp["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def full_attn_block(h, lp, cfg: ModelConfig, positions, *, causal=True,
                    collect_kv=False):
    """Pre-norm attention block over a full sequence (train/prefill)."""
    h = constrain_batch(h)
    x = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
    q, k, v = attn_qkv(x, lp, cfg, positions)
    kr = repeat_kv(k, cfg.q_per_kv)
    vr = repeat_kv(v, cfg.q_per_kv)
    o = attention(q, kr, vr, causal=causal)
    h = h + jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
    return (h, (k, v)) if collect_kv else (h, None)


def dense_mlp_block(h, lp, cfg: ModelConfig):
    h = constrain_batch(h)
    x = rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
    return h + swiglu(x, lp["w_gate"], lp["w_up"], lp["w_down"])


def dense_layer(h, lp, cfg: ModelConfig, positions, collect_kv=False):
    h, kv = full_attn_block(h, lp, cfg, positions, collect_kv=collect_kv)
    h = dense_mlp_block(h, lp, cfg)
    return h, kv


# ---------------------------------------------------------------------------
# Dense decoder: forward (train / prefill) and paged decode step
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens):
    return params["embed"][tokens].astype(cfg.dtype)


def unembed(params, cfg: ModelConfig, h):
    w = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    return jnp.einsum("bsd,dv->bsv", h, w)


def dense_forward(params, cfg: ModelConfig, tokens, *,
                  input_embeds: Optional[jax.Array] = None,
                  collect_kv: bool = False, remat: bool = True):
    """tokens [B,S] (or input_embeds [B,S,d]) -> logits [B,S,V].

    collect_kv additionally returns post-RoPE (k, v) stacked [L,B,S,KH,HD]
    for prefill cache population.
    """
    h = embed_tokens(params, cfg, tokens) if input_embeds is None \
        else input_embeds
    B, S = h.shape[0], h.shape[1]
    positions = jnp.arange(S)[None, :]

    def body(carry, lp):
        out, kv = dense_layer(carry, lp, cfg, positions,
                              collect_kv=collect_kv)
        return out, kv

    if remat:
        body = jax.checkpoint(body)
    h, kvs = jax.lax.scan(body, h, params["layers"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, cfg, h)
    return (logits, kvs) if collect_kv else logits


def allocate_token_page(cache: PagedKVCache,
                        write_slot: jax.Array) -> PagedKVCache:
    """Register the logical page receiving this step's token in the page
    table / owner maps (MUST run before tier_lists so the fresh page is
    visible to the attention kernel)."""
    import dataclasses as dc
    L, B = write_slot.shape
    hbm_pages = cache.k_hbm.shape[2]
    host_pages = cache.k_host.shape[2]
    T = cache.k_hbm.shape[3]
    max_pages = cache.page_table.shape[2]
    logical = jnp.minimum(cache.length // T, max_pages - 1)   # [B]
    lidx = jnp.arange(L)[:, None]
    bidx = jnp.arange(B)[None, :]
    page_table = cache.page_table.at[lidx, bidx, logical[None, :]].set(
        write_slot)
    in_hbm = write_slot < hbm_pages
    hslot = jnp.clip(write_slot, 0, hbm_pages - 1)
    hbm_owner = cache.hbm_owner.at[lidx, bidx, hslot].set(
        jnp.where(in_hbm, logical[None, :],
                  cache.hbm_owner[lidx, bidx, hslot]))
    eslot = jnp.clip(write_slot - hbm_pages, 0, host_pages - 1)
    host_owner = cache.host_owner.at[lidx, bidx, eslot].set(
        jnp.where(~in_hbm, logical[None, :],
                  cache.host_owner[lidx, bidx, eslot]))
    return dc.replace(cache, page_table=page_table, hbm_owner=hbm_owner,
                      host_owner=host_owner)


def mask_write_visible(cache: PagedKVCache, logical_page_mask):
    """Quest masks must never hide the page receiving this step's token
    (the step's own K/V lands there and attention must see it). Returns
    the mask with the current logical page forced visible, or None.
    Shared by every cache-backed decode path (dense/vlm/moe/hybrid/
    encdec)."""
    if logical_page_mask is None:
        return None
    B = cache.length.shape[0]
    T = cache.k_hbm.shape[3]
    logical = jnp.minimum(cache.length // T, cache.page_table.shape[2] - 1)
    return logical_page_mask.at[..., jnp.arange(B), logical].set(True)


def dense_decode_step(params, cfg: ModelConfig, cache: PagedKVCache,
                      token: jax.Array, write_slot: jax.Array,
                      use_pallas: Optional[bool] = None,
                      logical_page_mask: Optional[jax.Array] = None,
                      ) -> Tuple[jax.Array, PagedKVCache]:
    """One decode step over the two-tier paged cache.

    token: [B] int32. write_slot: [L, B] physical slot receiving this
    token's page (chosen by the control plane; slot >= hbm_pages means
    host pool). logical_page_mask enables Quest-style token bypassing
    (False pages are not read). Returns (logits [B, V], updated cache).
    """
    B = token.shape[0]
    T = cache.k_hbm.shape[3]
    pos = cache.length                        # [B]
    offset = pos % T
    h = embed_tokens(params, cfg, token[:, None])    # [B,1,d]

    cache = allocate_token_page(cache, write_slot)
    logical_page_mask = mask_write_visible(cache, logical_page_mask)
    hl, hv, el, ev = cache.tier_lists(
        logical_page_mask=logical_page_mask)  # [L,B,P*]

    def body(carry, xs):
        hcur = carry
        lp, k_hbm_l, v_hbm_l, k_host_l, v_host_l, slot, hl_l, hv_l, el_l, ev_l = xs
        x = rms_norm(hcur, lp["attn_norm"], cfg.norm_eps)
        q, k, v = attn_qkv(x, lp, cfg, pos[:, None])
        # write this token's k/v BEFORE attending (it must see itself)
        k_hbm_l, v_hbm_l, k_host_l, v_host_l = write_token_layer(
            k_hbm_l, v_hbm_l, k_host_l, v_host_l, slot, offset,
            k[:, 0], v[:, 0])
        # GQA grouped layout [B, KH, G, HD]
        qg = q[:, 0].reshape(B, cfg.kv_heads, cfg.q_per_kv, cfg.head_dim)
        # the freshly written token must be visible: recompute valid
        # counts with length+1
        hv_new = _bump_valid(hv_l, slot, offset, T, hbm=True,
                             hbm_pages=k_hbm_l.shape[1])
        ev_new = _bump_valid(ev_l, slot - k_hbm_l.shape[1], offset, T,
                             hbm=False, hbm_pages=k_hbm_l.shape[1])
        o, imp = ops.tiered_paged_attention(
            qg, k_hbm_l, v_hbm_l, k_host_l, v_host_l,
            hl_l, hv_new, el_l, ev_new, use_pallas=use_pallas)
        o = o.reshape(B, 1, cfg.num_heads, cfg.head_dim)
        hcur = hcur + jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
        hcur = dense_mlp_block(hcur, lp, cfg)
        return hcur, (k_hbm_l, v_hbm_l, k_host_l, v_host_l, imp)

    xs = (params["layers"], cache.k_hbm, cache.v_hbm, cache.k_host,
          cache.v_host, write_slot, hl, hv, el, ev)
    h, (k_hbm, v_hbm, k_host, v_host, imp) = jax.lax.scan(body, h, xs)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, cfg, h)[:, 0]

    cache = _update_cache_after_step(cache, k_hbm, v_hbm, k_host, v_host,
                                     imp, write_slot, offset)
    return logits, cache


def _bump_valid(valid, slot, offset, T, *, hbm: bool, hbm_pages: int):
    """Account for the token written this step in the tier valid counts."""
    B = valid.shape[0]
    in_tier = (slot < hbm_pages) if hbm else (slot >= 0)
    s = jnp.clip(slot, 0, valid.shape[1] - 1)
    bidx = jnp.arange(B)
    bumped = valid.at[bidx, s].set(
        jnp.where(in_tier, jnp.maximum(valid[bidx, s], offset + 1),
                  valid[bidx, s]))
    return bumped


def _update_cache_after_step(cache, k_hbm, v_hbm, k_host, v_host, imp,
                             write_slot, offset):
    """Fold the step's pool updates + importance stats back into the
    cache (tables were already updated by allocate_token_page)."""
    import dataclasses as dc
    L, B = write_slot.shape
    max_pages = cache.page_table.shape[2]
    lidx = jnp.arange(L)[:, None]
    bidx = jnp.arange(B)[None, :]

    # importance: EMA over per-page attention mass. imp is [L, B, Ph+Pe]
    # in tier-slot order; scatter back to logical pages via owners.
    ema = IMPORTANCE_EMA
    owner = jnp.concatenate([cache.hbm_owner, cache.host_owner], axis=2)
    owner_safe = jnp.clip(owner, 0, max_pages - 1)
    mass = jnp.zeros_like(cache.importance)
    mass = mass.at[lidx[..., None], bidx[..., None], owner_safe].add(
        jnp.where(owner >= 0, imp, 0.0))
    importance = (1 - ema) * cache.importance + ema * mass

    return dc.replace(cache, k_hbm=k_hbm, v_hbm=v_hbm, k_host=k_host,
                      v_host=v_host, length=cache.length + 1,
                      importance=importance)


# ---------------------------------------------------------------------------
# Chunked prefill (Sarathi-style) into the paged cache at an offset
# ---------------------------------------------------------------------------

def prefill_chunk_attn(hcur, lp, cfg: ModelConfig, pools, pos, page,
                       offset, valid):
    """One layer's chunked-prefill attention block over the paged pools.

    hcur: [B, C, d] residual stream for a prompt slice; pools:
    (k_hbm_l, v_hbm_l, k_host_l, v_host_l); pos/page/offset/valid:
    [B, C] absolute positions and their page coordinates. Writes the
    slice's K/V at static-placement slots (slot == logical page), then
    attends causally against the pools flattened in slot order — which
    IS logical token order while the lane is prefilling, because the
    migration planner only touches lanes that have started decoding.
    Shared by the dense and moe chunked-prefill forwards.
    """
    kh, vh, ke, ve = pools
    B, C = pos.shape
    T = kh.shape[2]
    hcur = constrain_batch(hcur)
    x = rms_norm(hcur, lp["attn_norm"], cfg.norm_eps)
    q, k, v = attn_qkv(x, lp, cfg, pos)
    kh, vh, ke, ve = write_tokens_layer(kh, vh, ke, ve, page, offset,
                                        k, v, valid)
    keys = jnp.concatenate([kh, ke], axis=1)        # [B, Ph+Pe, T, KH, HD]
    vals = jnp.concatenate([vh, ve], axis=1)
    S = keys.shape[1] * T
    keys = keys.reshape(B, S, cfg.kv_heads, cfg.head_dim)
    vals = vals.reshape(B, S, cfg.kv_heads, cfg.head_dim)
    o = prefix_chunk_attention(q, repeat_kv(keys, cfg.q_per_kv),
                               repeat_kv(vals, cfg.q_per_kv), pos)
    hcur = hcur + jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
    return hcur, (kh, vh, ke, ve)


def chunk_coords(page_tokens: int, chunk: int, start: jax.Array,
                 n_valid: jax.Array):
    """Page coordinates for a `chunk`-token slice at lane offsets
    `start` [B] with `n_valid` [B] real tokens: (pos, page, offset,
    valid), all [B, C]."""
    pos = start[:, None] + jnp.arange(chunk, dtype=start.dtype)[None, :]
    valid = jnp.arange(chunk)[None, :] < n_valid[:, None]
    page = (pos // page_tokens).astype(jnp.int32)
    offset = (pos % page_tokens).astype(jnp.int32)
    return pos, page, offset, valid


def dense_prefill_chunk(params, cfg: ModelConfig, cache: PagedKVCache,
                        tokens: jax.Array, start: jax.Array,
                        n_valid: jax.Array
                        ) -> Tuple[jax.Array, PagedKVCache]:
    """Consume a [B, C] prompt slice directly into the paged cache.

    Token j of lane b sits at absolute position start[b] + j and is
    real while j < n_valid[b] (the rest of the slice is padding and is
    neither written nor trusted). K/V pages are written at an offset
    under static placement — no batch-1 side cache, no per-length
    compiles: C is the only traced shape, lane offsets are data.
    Returns (logits [B, C, V], updated cache); the logits at slice
    index n_valid-1 are those of the last consumed prompt position, so
    the first output token can be sampled on device at the step where
    prefill crosses prompt_len.

    Bitwise invariant (pinned by tests/test_chunked_prefill.py): for
    the valid positions this reproduces `dense_forward` exactly, at ANY
    chunk budget — per-position ops are shape-invariant and
    `prefix_chunk_attention` sees the identical visible prefix.
    """
    C = tokens.shape[1]
    T = cache.k_hbm.shape[3]
    pos, page, offset, valid = chunk_coords(T, C, start, n_valid)
    h = embed_tokens(params, cfg, tokens)

    def body(carry, xs):
        lp, kh, vh, ke, ve = xs
        hcur, pools = prefill_chunk_attn(carry, lp, cfg, (kh, vh, ke, ve),
                                         pos, page, offset, valid)
        hcur = dense_mlp_block(hcur, lp, cfg)
        return hcur, pools

    xs = (params["layers"], cache.k_hbm, cache.v_hbm, cache.k_host,
          cache.v_host)
    h, (kh, vh, ke, ve) = jax.lax.scan(body, h, xs)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, cfg, h)
    import dataclasses as dc
    cache = dc.replace(cache, k_hbm=kh, v_hbm=vh, k_host=ke, v_host=ve)
    cache = allocate_prompt_pages(cache, pos, valid, n_valid)
    return logits, cache


# ---------------------------------------------------------------------------
# Encoder-decoder (whisper)
# ---------------------------------------------------------------------------

def _ln(x, p, eps):
    return layer_norm(x, p["w"], p["b"], eps)


def _encdec_attn(x_q, x_kv, lp, cfg, *, causal):
    q = jnp.einsum("bsd,dhk->bshk", x_q, lp["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x_kv, lp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x_kv, lp["wv"])
    kr = repeat_kv(k, cfg.q_per_kv)
    vr = repeat_kv(v, cfg.q_per_kv)
    o = attention(q, kr, vr, causal=causal)
    return jnp.einsum("bshk,hkd->bsd", o, lp["wo"])


def encoder_forward(params, cfg: ModelConfig, frames: jax.Array,
                    remat: bool = True):
    """frames: [B, F, d] precomputed frame embeddings (conv stub)."""
    F = frames.shape[1]
    h = (frames.astype(cfg.dtype)
         + params["enc_pos"][:F][None].astype(cfg.dtype))

    def body(carry, lp):
        carry = constrain_batch(carry)
        x = _ln(carry, lp["ln1"], cfg.norm_eps)
        carry = carry + _encdec_attn(x, x, lp["attn"], cfg, causal=False)
        x = _ln(carry, lp["ln2"], cfg.norm_eps)
        m = jnp.einsum("bsd,df->bsf", x, lp["mlp"]["w_in"]) + lp["mlp"]["b_in"]
        carry = carry + (jnp.einsum("bsf,fd->bsd", jax.nn.gelu(m),
                                    lp["mlp"]["w_out"]) + lp["mlp"]["b_out"])
        return carry, None

    if remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return _ln(h, params["enc_final"], cfg.norm_eps)


def encdec_forward(params, cfg: ModelConfig, tokens, enc_embeds,
                   remat: bool = True, collect_kv: bool = False):
    """Teacher-forced decode over encoder output. tokens [B,S]."""
    enc = encoder_forward(params, cfg, enc_embeds, remat=remat)
    S = tokens.shape[1]
    h = (params["embed"][tokens]
         + params["dec_pos"][:S][None]).astype(cfg.dtype)

    def body(carry, lp):
        carry = constrain_batch(carry)
        x = _ln(carry, lp["ln1"], cfg.norm_eps)
        sa = lp["self_attn"]
        k = jnp.einsum("bsd,dhk->bshk", x, sa["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, sa["wv"])
        carry = carry + _encdec_attn(x, x, sa, cfg, causal=True)
        x = _ln(carry, lp["ln2"], cfg.norm_eps)
        carry = carry + _encdec_attn(x, enc, lp["cross_attn"], cfg,
                                     causal=False)
        x = _ln(carry, lp["ln3"], cfg.norm_eps)
        m = jnp.einsum("bsd,df->bsf", x, lp["mlp"]["w_in"]) + lp["mlp"]["b_in"]
        carry = carry + (jnp.einsum("bsf,fd->bsd", jax.nn.gelu(m),
                                    lp["mlp"]["w_out"]) + lp["mlp"]["b_out"])
        return carry, ((k, v) if collect_kv else None)

    if remat:
        body = jax.checkpoint(body)
    h, kvs = jax.lax.scan(body, h, params["dec_layers"])
    h = _ln(h, params["dec_final"], cfg.norm_eps)
    logits = unembed(params, cfg, h)
    return (logits, kvs, enc) if collect_kv else logits
