"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) and sLSTM
(scalar memory, inherently sequential — per the xLSTM paper).

mLSTM uses exponential gating with the stabilizer recurrence
  m_t = max(logsig(f_t) + m_{t-1}, i_t)
which is max-plus associative, so the chunked form computes the exact
same m_t in parallel:  m_i = max(m_prev + lf_i, max_{j<=i} w_ij) with
w_ij = lf_i - lf_j + i_j. Outputs are bit-for-bit the sequential
recurrence (validated in tests), and every heavy op is an MXU matmul —
this is the linear-attention analogue of flash attention's streaming
softmax, which is why the same (carry m, rescale on update) machinery
appears in our paged-attention kernel.

No KV cache exists in this family: the recurrent state is a fixed-size
matrix that is hot on every step, so (DESIGN.md §6) the paper's
placement technique is inapplicable — state is pinned in HBM exactly
like weights.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import constrain_batch, rms_norm
from repro.models.params import Param

NEG = -1e30


# ---------------------------------------------------------------------------
# Schemas
# ---------------------------------------------------------------------------

def mlstm_schema(cfg: ModelConfig, L: int):
    d = cfg.d_model
    inner = cfg.xlstm.expand * d
    H = cfg.num_heads
    W = cfg.xlstm.conv_width
    return {
        "norm": Param((L, d), ("layers", "embed"), "ones"),
        "w_up": Param((L, d, 2 * inner), ("layers", "embed", "mlp"),
                      fan_in_axes=(1,)),
        "conv_w": Param((L, W, inner), ("layers", None, "mlp"),
                        fan_in_axes=(1,)),
        "conv_b": Param((L, inner), ("layers", "mlp"), "zeros"),
        "wq": Param((L, inner, inner), ("layers", "mlp", None),
                    fan_in_axes=(1,)),
        "wk": Param((L, inner, inner), ("layers", "mlp", None),
                    fan_in_axes=(1,)),
        "wv": Param((L, inner, inner), ("layers", "mlp", None),
                    fan_in_axes=(1,)),
        "wi": Param((L, inner, H), ("layers", "mlp", "heads"),
                    fan_in_axes=(1,)),
        "wf": Param((L, inner, H), ("layers", "mlp", "heads"),
                    fan_in_axes=(1,)),
        "bi": Param((L, H), ("layers", "heads"), "zeros"),
        "bf": Param((L, H), ("layers", "heads"), "ones"),
        "y_norm": Param((L, inner), ("layers", "mlp"), "ones"),
        "w_out": Param((L, inner, d), ("layers", "mlp", "embed"),
                       fan_in_axes=(1,)),
    }


def slstm_schema(cfg: ModelConfig, L: int):
    d = cfg.d_model
    H = cfg.num_heads
    P = d // H
    gates = {}
    for g in ("z", "i", "f", "o"):
        gates[f"w{g}"] = Param((L, d, d), ("layers", "embed", None),
                               fan_in_axes=(1,))
        gates[f"r{g}"] = Param((L, H, P, P), ("layers", "heads", None, None),
                               fan_in_axes=(2,))
        gates[f"b{g}"] = Param((L, d), ("layers", "embed"),
                               "ones" if g == "f" else "zeros")
    return {
        "norm": Param((L, d), ("layers", "embed"), "ones"),
        **gates,
        "y_norm": Param((L, d), ("layers", "embed"), "ones"),
        "w_out": Param((L, d, d), ("layers", "embed", None),
                       fan_in_axes=(1,)),
    }


# ---------------------------------------------------------------------------
# mLSTM: chunk-parallel forward / recurrent decode / sequential ref
# ---------------------------------------------------------------------------

def _mlstm_inputs(h, lp, cfg: ModelConfig):
    h = constrain_batch(h)
    d = cfg.d_model
    inner = cfg.xlstm.expand * d
    H = cfg.num_heads
    P = inner // H
    x = rms_norm(h, lp["norm"], cfg.norm_eps)
    up = jnp.einsum("bsd,dk->bsk", x, lp["w_up"])
    xpath, z = jnp.split(up, 2, axis=-1)
    return xpath, z, inner, H, P


def _qkv_gates(xconv, xpath, lp, H, P):
    B_, S, inner = xconv.shape
    q = jnp.einsum("bsk,kj->bsj", xconv, lp["wq"]).reshape(B_, S, H, P)
    k = jnp.einsum("bsk,kj->bsj", xconv, lp["wk"]).reshape(B_, S, H, P)
    v = jnp.einsum("bsk,kj->bsj", xpath, lp["wv"]).reshape(B_, S, H, P)
    k = k.astype(jnp.float32) * (P ** -0.5)
    ig = (jnp.einsum("bsk,kh->bsh", xconv, lp["wi"])
          + lp["bi"]).astype(jnp.float32)
    fg = (jnp.einsum("bsk,kh->bsh", xconv, lp["wf"])
          + lp["bf"]).astype(jnp.float32)
    lf = jax.nn.log_sigmoid(fg)
    return (q.astype(jnp.float32), k, v.astype(jnp.float32), ig, lf)


def _causal_conv(x, w, b):
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    return sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W)) + b


def mlstm_forward_layer(h, lp, cfg: ModelConfig):
    """h [B,S,d] -> [B,S,d] (residual added by caller)."""
    B_, S, d = h.shape
    xpath, z, inner, H, P = _mlstm_inputs(h, lp, cfg)
    xconv = jax.nn.silu(_causal_conv(xpath, lp["conv_w"], lp["conv_b"]))
    q, k, v, ig, lf = _qkv_gates(xconv, xpath, lp, H, P)

    Q = min(cfg.xlstm.chunk, S)
    S_real = S
    pad = (-S) % Q
    if pad:
        # padded steps: lf=0 (no decay), i=-inf (no input) -> state fixed
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)))
        ig = jnp.pad(ig, ((0, 0), (0, pad), (0, 0)),
                     constant_values=NEG)
        z = jnp.pad(z, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q
    qc = q.reshape(B_, nc, Q, H, P)
    kc = k.reshape(B_, nc, Q, H, P)
    vc = v.reshape(B_, nc, Q, H, P)
    igc = ig.reshape(B_, nc, Q, H)
    lfc = jnp.cumsum(lf.reshape(B_, nc, Q, H), axis=2)      # within-chunk

    tri = jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :]

    def chunk_body(carry, xs):
        C_prev, n_prev, m_prev = carry       # [B,H,P,P], [B,H,P], [B,H]
        qb, kb, vb, ib, lfb = xs             # [B,Q,H,*]
        # log weights w_ij = lf_i - lf_j + i_j  (i>=j)
        w = (lfb[:, :, None, :] - lfb[:, None, :, :]
             + ib[:, None, :, :])                            # [B,Qi,Qj,H]
        w = jnp.where(tri[None, :, :, None], w, NEG)
        c_i = m_prev[:, None, :] + lfb                       # [B,Q,H]
        m_i = jnp.maximum(jnp.max(w, axis=2), c_i)           # exact m_t
        p = jnp.exp(w - m_i[:, :, None, :])
        carry_w = jnp.exp(c_i - m_i)                         # [B,Q,H]

        qk = jnp.einsum("bihp,bjhp->bijh", qb, kb)           # [B,Qi,Qj,H]
        num_intra = jnp.einsum("bijh,bijh,bjhp->bihp", qk, p, vb)
        num_carry = jnp.einsum("bhpr,bihp->bihr", C_prev, qb) \
            * carry_w[..., None]
        den_intra = jnp.einsum("bijh,bijh->bih", qk, p)
        den_carry = jnp.einsum("bhp,bihp->bih", n_prev, qb) * carry_w
        num = num_intra + num_carry
        den = den_intra + den_carry
        y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]

        # chunk-end state update
        lf_end = lfb[:, -1, :]                               # [B,H]
        a_j = lf_end[:, None, :] - lfb + ib                  # [B,Q,H]
        m_new = jnp.maximum(m_prev + lf_end, jnp.max(a_j, axis=1))
        scale_old = jnp.exp(m_prev + lf_end - m_new)
        pw = jnp.exp(a_j - m_new[:, None, :])                # [B,Q,H]
        C_new = (C_prev * scale_old[:, :, None, None]
                 + jnp.einsum("bjh,bjhp,bjhr->bhpr", pw, kb, vb))
        n_new = (n_prev * scale_old[:, :, None]
                 + jnp.einsum("bjh,bjhp->bhp", pw, kb))
        return (C_new, n_new, m_new), y

    C0 = jnp.zeros((B_, H, P, P), jnp.float32)
    n0 = jnp.zeros((B_, H, P), jnp.float32)
    m0 = jnp.full((B_, H), NEG, jnp.float32)
    _, ys = jax.lax.scan(
        chunk_body, (C0, n0, m0),
        (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
         vc.transpose(1, 0, 2, 3, 4), igc.transpose(1, 0, 2, 3),
         lfc.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B_, S, inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(cfg.dtype), lp["y_norm"], cfg.norm_eps)
    return jnp.einsum("bsk,kd->bsd", y, lp["w_out"])[:, :S_real]


def mlstm_forward_layer_ref(h, lp, cfg: ModelConfig):
    """Sequential oracle."""
    B_, S, d = h.shape
    xpath, z, inner, H, P = _mlstm_inputs(h, lp, cfg)
    xconv = jax.nn.silu(_causal_conv(xpath, lp["conv_w"], lp["conv_b"]))
    q, k, v, ig, lf = _qkv_gates(xconv, xpath, lp, H, P)

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, it, lft = xs
        m_new = jnp.maximum(lft + m, it)
        f_ = jnp.exp(lft + m - m_new)
        i_ = jnp.exp(it - m_new)
        C = C * f_[:, :, None, None] + i_[:, :, None, None] \
            * jnp.einsum("bhp,bhr->bhpr", kt, vt)
        n = n * f_[:, :, None] + i_[:, :, None] * kt
        num = jnp.einsum("bhpr,bhp->bhr", C, qt)
        den = jnp.einsum("bhp,bhp->bh", n, qt)
        y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
        return (C, n, m_new), y

    C0 = jnp.zeros((B_, H, P, P), jnp.float32)
    n0 = jnp.zeros((B_, H, P), jnp.float32)
    m0 = jnp.full((B_, H), NEG, jnp.float32)
    _, ys = jax.lax.scan(
        step, (C0, n0, m0),
        (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
         v.transpose(1, 0, 2, 3), ig.transpose(1, 0, 2),
         lf.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2, 3).reshape(B_, S, inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(cfg.dtype), lp["y_norm"], cfg.norm_eps)
    return jnp.einsum("bsk,kd->bsd", y, lp["w_out"])


def mlstm_decode_layer(h, lp, cfg: ModelConfig, state):
    """h [B,d]; state = (C [B,H,P,P], n [B,H,P], m [B,H], conv [B,W-1,inner])."""
    C, n, m, conv_state = state
    B_, d = h.shape
    xpath, z, inner, H, P = _mlstm_inputs(h[:, None], lp, cfg)
    xp = xpath[:, 0]
    hist = jnp.concatenate([conv_state, xp[:, None]], axis=1)
    xconv = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, lp["conv_w"])
                        + lp["conv_b"])
    q, k, v, ig, lf = _qkv_gates(xconv[:, None], xpath, lp, H, P)
    qt, kt, vt = q[:, 0], k[:, 0], v[:, 0]
    it, lft = ig[:, 0], lf[:, 0]
    m_new = jnp.maximum(lft + m, it)
    f_ = jnp.exp(lft + m - m_new)
    i_ = jnp.exp(it - m_new)
    C = C * f_[:, :, None, None] + i_[:, :, None, None] \
        * jnp.einsum("bhp,bhr->bhpr", kt, vt)
    n = n * f_[:, :, None] + i_[:, :, None] * kt
    num = jnp.einsum("bhpr,bhp->bhr", C, qt)
    den = jnp.einsum("bhp,bhp->bh", n, qt)
    y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    y = y.reshape(B_, inner) * jax.nn.silu(z[:, 0].astype(jnp.float32))
    y = rms_norm(y.astype(cfg.dtype), lp["y_norm"], cfg.norm_eps)
    out = jnp.einsum("bk,kd->bd", y, lp["w_out"])
    return out, (C, n, m_new, hist[:, 1:])


# ---------------------------------------------------------------------------
# sLSTM (sequential by construction)
# ---------------------------------------------------------------------------

def _slstm_step(lp, cfg, carry, xt):
    """carry: (c, n, m, hprev) each [B,H,P]; xt: [B,d] pre-projected gates."""
    c, n, m, hprev = carry
    H = cfg.num_heads
    P = cfg.d_model // H
    B_ = xt.shape[0]

    def gate(name):
        wx = jnp.einsum("bd,dk->bk", xt, lp[f"w{name}"])
        rh = jnp.einsum("bhp,hpr->bhr", hprev, lp[f"r{name}"]
                        ).reshape(B_, H * P)
        return (wx + rh + lp[f"b{name}"]).astype(jnp.float32) \
            .reshape(B_, H, P)

    zt = jnp.tanh(gate("z"))
    it = gate("i")
    ft = jax.nn.log_sigmoid(gate("f"))
    ot = jax.nn.sigmoid(gate("o"))
    m_new = jnp.maximum(ft + m, it)
    i_ = jnp.exp(it - m_new)
    f_ = jnp.exp(ft + m - m_new)
    c = f_ * c + i_ * zt
    n = f_ * n + i_
    hnew = ot * c / jnp.maximum(n, 1.0)
    return (c, n, m_new, hnew), hnew


def slstm_forward_layer(h, lp, cfg: ModelConfig):
    h = constrain_batch(h)
    B_, S, d = h.shape
    H, P = cfg.num_heads, d // cfg.num_heads
    x = rms_norm(h, lp["norm"], cfg.norm_eps)

    def step(carry, xt):
        return _slstm_step(lp, cfg, carry, xt)

    z0 = jnp.zeros((B_, H, P), jnp.float32)
    m0 = jnp.full((B_, H, P), NEG, jnp.float32)
    (_, _, _, _), ys = jax.lax.scan(step, (z0, z0, m0, z0),
                                    x.transpose(1, 0, 2))
    y = ys.transpose(1, 0, 2, 3).reshape(B_, S, d)
    y = rms_norm(y.astype(cfg.dtype), lp["y_norm"], cfg.norm_eps)
    return jnp.einsum("bsd,dk->bsk", y, lp["w_out"])


def slstm_decode_layer(h, lp, cfg: ModelConfig, state):
    x = rms_norm(h, lp["norm"], cfg.norm_eps)
    state, y = _slstm_step(lp, cfg, state, x)
    B_ = h.shape[0]
    y = y.reshape(B_, cfg.d_model)
    y = rms_norm(y.astype(cfg.dtype), lp["y_norm"], cfg.norm_eps)
    return jnp.einsum("bd,dk->bk", y, lp["w_out"]), state
