"""Mamba2 (SSD) blocks + the zamba2-style hybrid stack.

Training/prefill uses the chunked SSD algorithm (intra-chunk attention-
like matmuls + inter-chunk state scan) — the TPU-friendly O(S) form in
which every large op is an MXU matmul. Decode is the O(1) recurrent
update. Both are validated against a sequential reference in tests.

Hybrid (zamba2): a stack of Mamba2 blocks with ONE weight-shared
attention block applied every `attn_every` blocks; those shared-attn
sites are the only KV-cache owners, which per DESIGN.md §6 makes this
the most placement-friendly assigned architecture.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import constrain_batch, rms_norm
from repro.models.params import Param


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------

def mamba2_schema(cfg: ModelConfig, L: int):
    d = cfg.d_model
    ssm = cfg.ssm
    inner = ssm.expand * d
    H = cfg.num_heads            # ssm heads
    N = ssm.state_dim
    conv_ch = inner + 2 * N
    return {
        "norm": Param((L, d), ("layers", "embed"), "ones"),
        # in_proj -> [z(inner), x(inner), B(N), C(N), dt(H)]
        "w_in": Param((L, d, 2 * inner + 2 * N + H),
                      ("layers", "embed", "mlp"), fan_in_axes=(1,)),
        "conv_w": Param((L, ssm.conv_width, conv_ch),
                        ("layers", None, "mlp"), fan_in_axes=(1,)),
        "conv_b": Param((L, conv_ch), ("layers", "mlp"), "zeros"),
        "a_log": Param((L, H), ("layers", "heads"), "zeros"),
        "dt_bias": Param((L, H), ("layers", "heads"), "zeros"),
        "skip_d": Param((L, H), ("layers", "heads"), "ones"),
        "y_norm": Param((L, inner), ("layers", "mlp"), "ones"),
        "w_out": Param((L, inner, d), ("layers", "mlp", "embed"),
                       fan_in_axes=(1,)),
    }


# ---------------------------------------------------------------------------
# Chunked SSD forward (one layer)
# ---------------------------------------------------------------------------

def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x [B,S,C]; w [W,C]; left-pad W-1."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    return out + b


def _split_proj(x, lp, cfg: ModelConfig):
    ssm = cfg.ssm
    inner = ssm.expand * cfg.d_model
    N, H = ssm.state_dim, cfg.num_heads
    proj = jnp.einsum("bsd,dk->bsk", x, lp["w_in"])
    z, xin, Bc, Cc, dt = jnp.split(
        proj, [inner, 2 * inner, 2 * inner + N, 2 * inner + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    return z, conv_in, dt


def mamba2_forward_layer(h, lp, cfg: ModelConfig, return_state: bool = False):
    """h: [B, S, d] -> [B, S, d] (residual applied by caller).

    return_state additionally yields the post-sequence recurrent state
    (s [B,H,N,P], conv [B,W-1,conv_ch]) so prefill can hand off to the
    recurrent decode path.
    """
    ssm = cfg.ssm
    B_, S, d = h.shape
    inner = ssm.expand * d
    H, N = cfg.num_heads, ssm.state_dim
    P = inner // H
    Q = min(ssm.chunk, S)

    h = constrain_batch(h)
    x = rms_norm(h, lp["norm"], cfg.norm_eps)
    z, conv_in, dt_raw = _split_proj(x, lp, cfg)
    conv_in_real = conv_in
    S_real = S
    # pad to a chunk multiple; padded positions get dt=0 (identity decay,
    # zero input) so the recurrent state is untouched by padding.
    pad = (-S) % Q
    if pad:
        conv_in = jnp.pad(conv_in, ((0, 0), (0, pad), (0, 0)))
        dt_raw = jnp.pad(dt_raw, ((0, 0), (0, pad), (0, 0)))
        z = jnp.pad(z, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q

    conv = jax.nn.silu(_causal_conv(conv_in, lp["conv_w"], lp["conv_b"]))
    xin, Bc, Cc = jnp.split(conv, [inner, inner + N], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + lp["dt_bias"].astype(jnp.float32))    # [B,S,H]
    if pad:
        live = (jnp.arange(S) < S_real)[None, :, None]
        dt = jnp.where(live, dt, 0.0)
    a = -jnp.exp(lp["a_log"].astype(jnp.float32))                # [H]
    da = dt * a                                                  # <= 0

    xh = xin.reshape(B_, S, H, P).astype(jnp.float32)
    xbar = xh * dt[..., None]
    Bc = Bc.astype(jnp.float32)
    Cc = Cc.astype(jnp.float32)

    # chunked views
    dac = da.reshape(B_, nc, Q, H)
    la = jnp.cumsum(dac, axis=2)                                 # [B,nc,Q,H]
    Bq = Bc.reshape(B_, nc, Q, N)
    Cq = Cc.reshape(B_, nc, Q, N)
    xq = xbar.reshape(B_, nc, Q, H, P)

    # ---- intra-chunk: Y[i] = sum_{j<=i} (C_i.B_j) exp(la_i-la_j) xbar_j
    cb = jnp.einsum("bcqn,bckn->bcqk", Cq, Bq)                   # [B,nc,Q,Q]
    li = la[:, :, :, None, :]                                    # i
    lj = la[:, :, None, :, :]                                    # j
    mask = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])
    decay = jnp.where(mask[None, None, :, :, None],
                      jnp.exp(li - lj), 0.0)                     # [B,nc,Q,Q,H]
    y_intra = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", cb, decay, xq)

    # ---- chunk states: S_c = sum_j exp(la_end - la_j) B_j (x) xbar_j
    w_end = jnp.exp(la[:, :, -1:, :] - la)                       # [B,nc,Q,H]
    s_chunk = jnp.einsum("bckn,bckh,bckhp->bchnp", Bq, w_end, xq)

    # ---- inter-chunk scan
    chunk_decay = jnp.exp(la[:, :, -1, :])                       # [B,nc,H]

    def scan_body(s_prev, xs):
        dec, s_c = xs
        s_new = s_prev * dec[:, :, None, None] + s_c
        return s_new, s_prev

    s0 = jnp.zeros((B_, H, N, P), jnp.float32)
    s_final, s_before = jax.lax.scan(
        scan_body, s0,
        (chunk_decay.transpose(1, 0, 2), s_chunk.transpose(1, 0, 2, 3, 4)))
    s_before = s_before.transpose(1, 0, 2, 3, 4)                 # [B,nc,H,N,P]

    y_inter = jnp.einsum("bcqn,bchnp,bcqh->bcqhp", Cq, s_before,
                         jnp.exp(la))
    y = (y_intra + y_inter).reshape(B_, S, H, P)
    y = y + xh * lp["skip_d"].astype(jnp.float32)[None, None, :, None]

    y = y.reshape(B_, S, inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(cfg.dtype), lp["y_norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, lp["w_out"])[:, :S_real]
    if return_state:
        W = ssm.conv_width
        conv_state = conv_in_real[:, S_real - (W - 1):, :] \
            .astype(jnp.float32)
        return out, (s_final, conv_state)
    return out


# ---------------------------------------------------------------------------
# Recurrent decode (one layer, one token)
# ---------------------------------------------------------------------------

def mamba2_decode_layer(h, lp, cfg: ModelConfig, state, conv_state):
    """h: [B, d]; state: [B,H,N,P]; conv_state: [B, W-1, conv_ch]."""
    ssm = cfg.ssm
    B_, d = h.shape
    inner = ssm.expand * d
    H, N = cfg.num_heads, ssm.state_dim
    P = inner // H

    x = rms_norm(h, lp["norm"], cfg.norm_eps)
    z, conv_in, dt_raw = _split_proj(x[:, None], lp, cfg)
    conv_in = conv_in[:, 0]
    # causal conv over [conv_state ; conv_in]
    hist = jnp.concatenate([conv_state, conv_in[:, None]], axis=1)
    w = lp["conv_w"]
    conv = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, w) + lp["conv_b"])
    conv_state = hist[:, 1:]

    xin, Bc, Cc = jnp.split(conv, [inner, inner + N], axis=-1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + lp["dt_bias"].astype(jnp.float32))    # [B,H]
    a = -jnp.exp(lp["a_log"].astype(jnp.float32))
    dec = jnp.exp(dt * a)                                        # [B,H]

    xh = xin.reshape(B_, H, P).astype(jnp.float32)
    xbar = xh * dt[..., None]
    Bf = Bc.astype(jnp.float32)
    Cf = Cc.astype(jnp.float32)

    state = (state * dec[:, :, None, None]
             + jnp.einsum("bn,bhp->bhnp", Bf, xbar))
    y = jnp.einsum("bn,bhnp->bhp", Cf, state)
    y = y + xh * lp["skip_d"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B_, inner) * jax.nn.silu(z[:, 0].astype(jnp.float32))
    y = rms_norm(y.astype(cfg.dtype), lp["y_norm"], cfg.norm_eps)
    return jnp.einsum("bk,kd->bd", y, lp["w_out"]), state, conv_state


# ---------------------------------------------------------------------------
# Sequential reference (oracle for tests)
# ---------------------------------------------------------------------------

def mamba2_forward_layer_ref(h, lp, cfg: ModelConfig):
    """O(S) sequential recurrence — ground truth for the chunked path."""
    B_, S, d = h.shape
    ssm = cfg.ssm
    inner = ssm.expand * d
    H, N = cfg.num_heads, ssm.state_dim
    P = inner // H

    x = rms_norm(h, lp["norm"], cfg.norm_eps)
    z, conv_in, dt_raw = _split_proj(x, lp, cfg)
    conv = jax.nn.silu(_causal_conv(conv_in, lp["conv_w"], lp["conv_b"]))
    xin, Bc, Cc = jnp.split(conv, [inner, inner + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + lp["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(lp["a_log"].astype(jnp.float32))
    dec = jnp.exp(dt * a)                                        # [B,S,H]
    xh = xin.reshape(B_, S, H, P).astype(jnp.float32)
    xbar = xh * dt[..., None]

    def step(s, xs):
        dec_t, b_t, c_t, xb_t = xs
        s = s * dec_t[:, :, None, None] + jnp.einsum(
            "bn,bhp->bhnp", b_t, xb_t)
        y = jnp.einsum("bn,bhnp->bhp", c_t, s)
        return s, y

    s0 = jnp.zeros((B_, H, N, P), jnp.float32)
    _, ys = jax.lax.scan(
        step, s0,
        (dec.transpose(1, 0, 2), Bc.astype(jnp.float32).transpose(1, 0, 2),
         Cc.astype(jnp.float32).transpose(1, 0, 2),
         xbar.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3)                                 # [B,S,H,P]
    y = y + xh * lp["skip_d"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B_, S, inner) * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(cfg.dtype), lp["y_norm"], cfg.norm_eps)
    return jnp.einsum("bsk,kd->bsd", y, lp["w_out"])
