"""Unified model facade over all architecture families.

`Model(cfg)` exposes:
  schema()            parameter schema (init / abstract / logical axes)
  init(rng)           concrete params
  abstract_params()   ShapeDtypeStructs (dry-run, no allocation)
  forward(params, batch)              full-sequence logits (train/score)
  prefill(params, batch, geo)         logits + decode state
  decode_step(params, state, token, [write_slot])  one-token serve step

Decode state kinds:
  attention families  -> PagedKVCache (two-tier, paper's technique)
  ssm/xlstm           -> stacked recurrent states (pinned-HBM, §6)
  hybrid              -> (ssm states, PagedKVCache over shared-attn sites)
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.kvcache import paged as paged_mod
from repro.kvcache.paged import (
    CacheGeometry, PagedKVCache, init_cache, prefill_cache,
)
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import transformer as tfm
from repro.models import xlstm as xlstm_mod
from repro.models.config import ModelConfig
from repro.models.layers import rms_norm
from repro.models.params import (
    Param, abstract_params, init_params, logical_axes,
)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ #
    # schema / params
    # ------------------------------------------------------------------ #
    def schema(self):
        cfg = self.cfg
        fam = cfg.family
        if fam in ("dense", "vlm"):
            return tfm.dense_schema(cfg)
        if fam == "moe":
            return self._moe_schema()
        if fam == "encdec":
            return tfm.encdec_schema(cfg)
        if fam == "xlstm":
            return self._xlstm_schema()
        if fam in ("ssm", "hybrid"):
            return self._hybrid_schema()
        raise ValueError(fam)

    def _moe_schema(self):
        cfg = self.cfg
        il = cfg.moe.interleave
        assert il in (1, 2), "interleave 1 or 2 supported"
        if il == 1:
            layers = {**tfm.attn_schema(cfg, cfg.num_layers),
                      **moe_mod.moe_schema(cfg, cfg.num_layers)}
        else:
            nb = cfg.num_layers // 2
            layers = {
                "dense_attn": tfm.attn_schema(cfg, nb),
                "dense_mlp": tfm.mlp_schema(cfg, nb),
                "moe_attn": tfm.attn_schema(cfg, nb),
                "moe": moe_mod.moe_schema(cfg, nb),
            }
        s = {
            "embed": Param((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                           "embed"),
            "final_norm": Param((cfg.d_model,), ("embed",), "ones"),
            "layers": layers,
        }
        if not cfg.tie_embeddings:
            s["unembed"] = Param((cfg.d_model, cfg.vocab),
                                 ("embed", "vocab"), fan_in_axes=(0,))
        return s

    def _xlstm_schema(self):
        cfg = self.cfg
        n_s = len(self._slstm_ids())
        n_m = cfg.num_layers - n_s
        s = {
            "embed": Param((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                           "embed"),
            "final_norm": Param((cfg.d_model,), ("embed",), "ones"),
            "mlstm": xlstm_mod.mlstm_schema(cfg, n_m),
            "slstm": xlstm_mod.slstm_schema(cfg, n_s),
        }
        if not cfg.tie_embeddings:
            s["unembed"] = Param((cfg.d_model, cfg.vocab),
                                 ("embed", "vocab"), fan_in_axes=(0,))
        return s

    def _hybrid_schema(self):
        cfg = self.cfg
        s = {
            "embed": Param((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                           "embed"),
            "final_norm": Param((cfg.d_model,), ("embed",), "ones"),
            "mamba": ssm_mod.mamba2_schema(cfg, cfg.num_layers),
        }
        n_attn = len(cfg.attention_layer_ids())
        if n_attn:
            # ONE weight-shared attention block (zamba2) + its MLP
            shared = {**tfm.attn_schema(cfg, 0, ()),
                      **tfm.mlp_schema(cfg, 0, ())}
            s["shared_attn"] = shared
        if not cfg.tie_embeddings:
            s["unembed"] = Param((cfg.d_model, cfg.vocab),
                                 ("embed", "vocab"), fan_in_axes=(0,))
        return s

    def _slstm_ids(self):
        cfg = self.cfg
        k = cfg.xlstm.slstm_every
        return tuple(range(k - 1, cfg.num_layers, k)) if k else ()

    def init(self, rng) -> Any:
        return init_params(self.schema(), rng, self.cfg.param_dtype)

    def abstract_params(self) -> Any:
        return abstract_params(self.schema(), self.cfg.param_dtype)

    def logical_axes(self) -> Any:
        return logical_axes(self.schema())

    # ------------------------------------------------------------------ #
    # full-sequence forward (train / prefill scoring)
    # ------------------------------------------------------------------ #
    def forward_hidden(self, params, tokens, *, extra: Optional[Dict] = None,
                       remat: bool = True):
        """Final hidden states (pre-unembed) — used by the chunked loss
        so [B, S, vocab] logits are never materialized at scale."""
        return self.forward(params, tokens, extra=extra, remat=remat,
                            _return_hidden=True)

    def forward(self, params, tokens, *, extra: Optional[Dict] = None,
                collect_kv: bool = False, remat: bool = True,
                _return_hidden: bool = False):
        cfg = self.cfg
        extra = extra or {}
        fam = cfg.family
        if _return_hidden:
            assert not collect_kv
            return self._forward_dispatch_hidden(params, tokens, extra,
                                                 remat)
        if fam == "dense":
            return tfm.dense_forward(params, cfg, tokens,
                                     collect_kv=collect_kv, remat=remat)
        if fam == "vlm":
            embeds = tfm.embed_tokens(params, cfg, tokens)
            patches = extra["patch_embeds"].astype(cfg.dtype)
            h = jnp.concatenate([patches, embeds], axis=1)
            return tfm.dense_forward(params, cfg, tokens, input_embeds=h,
                                     collect_kv=collect_kv, remat=remat)
        if fam == "encdec":
            return tfm.encdec_forward(params, cfg, tokens,
                                      extra["frame_embeds"].astype(cfg.dtype),
                                      remat=remat)
        if fam == "moe":
            return self._moe_forward(params, tokens, collect_kv=collect_kv,
                                     remat=remat)
        if fam == "xlstm":
            return self._xlstm_forward(params, tokens, remat=remat)
        if fam in ("ssm", "hybrid"):
            return self._hybrid_forward(params, tokens,
                                        collect_kv=collect_kv, remat=remat)
        raise ValueError(fam)

    def _forward_dispatch_hidden(self, params, tokens, extra, remat):
        """Same as forward() but stops before unembed."""
        import repro.models.transformer as _t
        orig = _t.unembed
        captured = {}

        def capture(params_, cfg_, h):
            captured["h"] = h
            return h[..., :1]  # dummy tiny tensor, discarded

        _t.unembed = capture
        try:
            self.forward(params, tokens, extra=extra, remat=remat)
        finally:
            _t.unembed = orig
        return captured["h"]

    def _moe_forward(self, params, tokens, *, collect_kv=False, remat=True):
        cfg = self.cfg
        h = tfm.embed_tokens(params, cfg, tokens)
        S = h.shape[1]
        positions = jnp.arange(S)[None, :]
        il = cfg.moe.interleave

        if il == 1:
            def body(carry, lp):
                carry, kv = tfm.full_attn_block(carry, lp, cfg, positions,
                                                collect_kv=collect_kv)
                carry = moe_mod.moe_block(carry, lp, cfg)
                return carry, kv
        else:
            def body(carry, lp):
                carry, kv1 = tfm.full_attn_block(
                    carry, lp["dense_attn"], cfg, positions,
                    collect_kv=collect_kv)
                carry = tfm.dense_mlp_block(carry, lp["dense_mlp"], cfg)
                carry, kv2 = tfm.full_attn_block(
                    carry, lp["moe_attn"], cfg, positions,
                    collect_kv=collect_kv)
                carry = moe_mod.moe_block(carry, lp["moe"], cfg)
                if collect_kv:
                    kv = jax.tree.map(
                        lambda a, b: jnp.stack([a, b]), kv1, kv2)
                else:
                    kv = None
                return carry, kv

        if remat:
            body = jax.checkpoint(body)
        h, kvs = jax.lax.scan(body, h, params["layers"])
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = tfm.unembed(params, cfg, h)
        if collect_kv:
            if il == 2:  # [nb, 2, ...] -> [L, ...]
                kvs = jax.tree.map(
                    lambda a: a.reshape((-1,) + a.shape[2:]), kvs)
            return logits, kvs
        return logits

    def _xlstm_forward(self, params, tokens, remat=True):
        cfg = self.cfg
        h = tfm.embed_tokens(params, cfg, tokens)
        slstm_ids = set(self._slstm_ids())
        mi = si = 0
        for l in range(cfg.num_layers):
            if l in slstm_ids:
                lp = jax.tree.map(lambda a: a[si], params["slstm"])
                fn = xlstm_mod.slstm_forward_layer
                si += 1
            else:
                lp = jax.tree.map(lambda a: a[mi], params["mlstm"])
                fn = xlstm_mod.mlstm_forward_layer
                mi += 1
            fn_c = (lambda f: (lambda hh, pp: f(hh, pp, cfg)))(fn)
            if remat:
                fn_c = jax.checkpoint(fn_c)
            h = h + fn_c(h, lp)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        return tfm.unembed(params, cfg, h)

    def _hybrid_forward(self, params, tokens, *, collect_kv=False,
                        remat=True, collect_state=False):
        cfg = self.cfg
        h = tfm.embed_tokens(params, cfg, tokens)
        S = h.shape[1]
        positions = jnp.arange(S)[None, :]
        attn_ids = cfg.attention_layer_ids()

        def mamba_body(carry, lp):
            out = ssm_mod.mamba2_forward_layer(carry, lp, cfg,
                                               return_state=collect_state)
            if collect_state:
                y, st = out
                return carry + y, st
            return carry + out, None
        if remat:
            mamba_body = jax.checkpoint(mamba_body)

        kvs, states = [], []
        prev = 0
        # the shared attention block runs AFTER the mamba block at `site`
        for site in list(attn_ids) + [cfg.num_layers]:
            end = min(site + 1, cfg.num_layers)
            if end - prev > 0:
                seg = jax.tree.map(lambda a: a[prev:end], params["mamba"])
                h, st = jax.lax.scan(mamba_body, h, seg)
                if collect_state:
                    states.append(st)
            if site < cfg.num_layers:
                # weight-shared attention block at `site`
                h, kv = tfm.full_attn_block(h, params["shared_attn"], cfg,
                                            positions, collect_kv=collect_kv)
                h = tfm.dense_mlp_block(h, params["shared_attn"], cfg)
                if collect_kv:
                    kvs.append(kv)
                prev = site + 1
            else:
                prev = site
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = tfm.unembed(params, cfg, h)
        out = (logits,)
        if collect_kv:
            out = out + (jax.tree.map(lambda *xs: jnp.stack(xs), *kvs),)
        if collect_state:
            st = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *states)
            out = out + (st,)
        return out if len(out) > 1 else logits

    # ------------------------------------------------------------------ #
    # prefill -> decode state
    # ------------------------------------------------------------------ #
    def cache_geometry(self, batch: int, max_context: int,
                       hbm_fraction: float = 0.25,
                       pad_to: int = 16) -> CacheGeometry:
        cfg = self.cfg
        n_attn = len(cfg.attention_layer_ids())
        return CacheGeometry.for_context(
            num_layers=max(n_attn, 1), batch=batch, context=max_context,
            kv_heads=cfg.kv_heads, head_dim=cfg.head_dim,
            page_tokens=cfg.kv_page_tokens, hbm_fraction=hbm_fraction,
            pad_to=pad_to, dtype=cfg.dtype)

    def prefill(self, params, tokens, geo: CacheGeometry, *,
                extra: Optional[Dict] = None):
        cfg = self.cfg
        fam = cfg.family
        if fam in ("dense", "vlm", "moe"):
            out = self.forward(params, tokens, extra=extra, collect_kv=True)
            logits, (k, v) = out
            prompt = tokens.shape[1] + (
                cfg.frontend.num_embeddings if fam == "vlm" else 0)
            cache = prefill_cache(geo, k, v, prompt)
            return logits[:, -1], cache
        if fam == "hybrid":
            logits, (k, v), st = self._hybrid_forward(
                params, tokens, collect_kv=True, collect_state=True)
            cache = prefill_cache(geo, k, v, tokens.shape[1])
            s, conv = st
            return logits[:, -1], {"ssm": {"s": s, "conv": conv},
                                   "kv": cache}
        if fam == "encdec":
            logits, (k, v), enc = tfm.encdec_forward(
                params, cfg, tokens, extra["frame_embeds"], collect_kv=True)
            cache = prefill_cache(geo, k, v, tokens.shape[1])
            return logits[:, -1], {"kv": cache, "enc": enc}
        if fam == "xlstm":
            # recurrent prefill: replay tokens through decode steps
            state = self.init_decode_state(tokens.shape[0])
            logits = None
            for t in range(tokens.shape[1]):
                logits, state = self.decode_step(params, state, tokens[:, t])
            return logits, state
        raise ValueError(f"prefill not supported for {fam}")

    # ------------------------------------------------------------------ #
    # chunked prefill (mixed prefill+decode serve steps)
    # ------------------------------------------------------------------ #
    def prefill_chunk(self, params, cache: PagedKVCache, tokens, start,
                      n_valid):
        """Consume a [B, C] prompt slice directly into the paged cache.

        The Sarathi-style half of a mixed serve step: lanes in prefill
        mode advance `n_valid` tokens from per-lane offset `start`,
        writing K/V pages in place (static placement) — no batch-1 side
        cache, no per-prompt-length compiles (C is the only traced
        shape). Returns (logits [B, C, V], cache); sampling the logits
        at index n_valid-1 yields the request's first output token on
        device. See transformer.dense_prefill_chunk.
        """
        fam = self.cfg.family
        if fam == "dense":
            return tfm.dense_prefill_chunk(params, self.cfg, cache,
                                           tokens, start, n_valid)
        if fam == "moe":
            return self._moe_prefill_chunk(params, cache, tokens, start,
                                           n_valid)
        raise NotImplementedError(
            f"chunked prefill covers cache-backed families (dense/moe); "
            f"family {fam!r} needs prefill extras or recurrent state")

    def _moe_prefill_chunk(self, params, cache, tokens, start, n_valid):
        """MoE chunked prefill: paged-attention chunk blocks + MoE FFN,
        mirroring `_moe_forward`'s layer structure (interleave 1 or 2).

        NOTE: MoE capacity routing groups over the B*C tokens of the
        slice, so (exactly as in any chunked-prefill system) capacity
        drops may differ between chunk budgets — the dense bitwise
        invariant does not extend to moe outputs.
        """
        cfg = self.cfg
        C = tokens.shape[1]
        T = cache.k_hbm.shape[3]
        pos, page, offset, valid = tfm.chunk_coords(T, C, start, n_valid)
        h = tfm.embed_tokens(params, cfg, tokens)
        il = cfg.moe.interleave

        if il == 1:
            def body(carry, xs):
                lp, kh, vh, ke, ve = xs
                hcur, pools = tfm.prefill_chunk_attn(
                    carry, lp, cfg, (kh, vh, ke, ve), pos, page, offset,
                    valid)
                hcur = moe_mod.moe_block(hcur, lp, cfg)
                return hcur, pools
            xs = (params["layers"], cache.k_hbm, cache.v_hbm,
                  cache.k_host, cache.v_host)
            h, (kh, vh, ke, ve) = jax.lax.scan(body, h, xs)
        else:
            nb = cfg.num_layers // 2

            def reshape2(a):
                return a.reshape((nb, 2) + a.shape[1:])

            c2 = jax.tree.map(reshape2, (cache.k_hbm, cache.v_hbm,
                                         cache.k_host, cache.v_host))

            def body(carry, xs):
                lp, (kh2, vh2, ke2, ve2) = xs
                hcur, pa = tfm.prefill_chunk_attn(
                    carry, lp["dense_attn"], cfg,
                    (kh2[0], vh2[0], ke2[0], ve2[0]), pos, page, offset,
                    valid)
                hcur = tfm.dense_mlp_block(hcur, lp["dense_mlp"], cfg)
                hcur, pb = tfm.prefill_chunk_attn(
                    hcur, lp["moe_attn"], cfg,
                    (kh2[1], vh2[1], ke2[1], ve2[1]), pos, page, offset,
                    valid)
                hcur = moe_mod.moe_block(hcur, lp["moe"], cfg)
                pools = jax.tree.map(lambda a, b: jnp.stack([a, b]),
                                     pa, pb)
                return hcur, pools

            h, pools2 = jax.lax.scan(body, h, (params["layers"], c2))
            kh, vh, ke, ve = jax.tree.map(
                lambda a: a.reshape((-1,) + a.shape[2:]), pools2)

        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = tfm.unembed(params, cfg, h)
        import dataclasses as dc
        cache = dc.replace(cache, k_hbm=kh, v_hbm=vh, k_host=ke, v_host=ve)
        cache = paged_mod.allocate_prompt_pages(cache, pos, valid, n_valid)
        return logits, cache

    # ------------------------------------------------------------------ #
    # decode
    # ------------------------------------------------------------------ #
    def init_decode_state(self, batch: int,
                          geo: Optional[CacheGeometry] = None):
        cfg = self.cfg
        fam = cfg.family
        if fam in ("dense", "vlm", "moe", "encdec"):
            assert geo is not None
            return init_cache(geo)
        if fam == "xlstm":
            return self._xlstm_state(batch)
        if fam in ("ssm", "hybrid"):
            ssm_state = self._mamba_state(batch)
            if geo is not None and cfg.attention_layer_ids():
                return {"ssm": ssm_state, "kv": init_cache(geo)}
            return {"ssm": ssm_state}
        raise ValueError(fam)

    def _mamba_state(self, batch):
        cfg = self.cfg
        inner = cfg.ssm.expand * cfg.d_model
        H, N = cfg.num_heads, cfg.ssm.state_dim
        P = inner // H
        L = cfg.num_layers
        return {
            "s": jnp.zeros((L, batch, H, N, P), jnp.float32),
            "conv": jnp.zeros((L, batch, cfg.ssm.conv_width - 1,
                               inner + 2 * N), jnp.float32),
        }

    def _xlstm_state(self, batch):
        cfg = self.cfg
        inner = cfg.xlstm.expand * cfg.d_model
        H = cfg.num_heads
        P = inner // H
        Ps = cfg.d_model // H
        n_s = len(self._slstm_ids())
        n_m = cfg.num_layers - n_s
        return {
            "m_C": jnp.zeros((n_m, batch, H, P, P), jnp.float32),
            "m_n": jnp.zeros((n_m, batch, H, P), jnp.float32),
            "m_m": jnp.full((n_m, batch, H), -1e30, jnp.float32),
            "m_conv": jnp.zeros((n_m, batch, cfg.xlstm.conv_width - 1,
                                 inner), jnp.float32),
            "s_c": jnp.zeros((n_s, batch, H, Ps), jnp.float32),
            "s_n": jnp.zeros((n_s, batch, H, Ps), jnp.float32),
            "s_m": jnp.full((n_s, batch, H, Ps), -1e30, jnp.float32),
            "s_h": jnp.zeros((n_s, batch, H, Ps), jnp.float32),
        }

    def decode_step(self, params, state, token, *,
                    write_slot: Optional[jax.Array] = None,
                    extra: Optional[Dict] = None,
                    use_pallas: Optional[bool] = None,
                    logical_page_mask: Optional[jax.Array] = None):
        cfg = self.cfg
        fam = cfg.family
        if logical_page_mask is not None and (
                fam == "xlstm"
                or (fam in ("ssm", "hybrid")
                    and not cfg.attention_layer_ids())):
            raise ValueError(
                f"logical_page_mask needs a paged KV cache; family {fam} "
                f"has no attention layers")
        if fam in ("dense", "vlm"):
            if write_slot is None:
                write_slot = default_write_slot(state)
            return tfm.dense_decode_step(params, cfg, state, token,
                                         write_slot, use_pallas=use_pallas,
                                         logical_page_mask=logical_page_mask)
        if fam == "moe":
            return self._moe_decode_step(params, state, token, write_slot,
                                         use_pallas, logical_page_mask)
        if fam == "xlstm":
            return self._xlstm_decode_step(params, state, token)
        if fam in ("ssm", "hybrid"):
            return self._hybrid_decode_step(params, state, token,
                                            write_slot, use_pallas,
                                            logical_page_mask)
        if fam == "encdec":
            return self._encdec_decode_step(params, state, token, extra,
                                            write_slot, use_pallas,
                                            logical_page_mask)
        raise ValueError(fam)

    def _moe_decode_step(self, params, cache, token, write_slot, use_pallas,
                         logical_page_mask=None):
        """MoE decode: attention layers use the paged cache; FFN is MoE."""
        cfg = self.cfg
        from repro.models.transformer import (
            _update_cache_after_step, attn_qkv, _bump_valid)
        from repro.kvcache.paged import write_token_layer
        from repro.kernels import ops as kops

        B = token.shape[0]
        T = cache.k_hbm.shape[3]
        pos = cache.length
        offset = pos % T
        h = tfm.embed_tokens(params, cfg, token[:, None])
        if write_slot is None:
            write_slot = default_write_slot(cache)
        cache = tfm.allocate_token_page(cache, write_slot)
        logical_page_mask = tfm.mask_write_visible(cache, logical_page_mask)
        hl, hv, el, ev = cache.tier_lists(
            logical_page_mask=logical_page_mask)
        il = cfg.moe.interleave

        def attn_part(hcur, lp, pools, slot, lists):
            k_hbm_l, v_hbm_l, k_host_l, v_host_l = pools
            hl_l, hv_l, el_l, ev_l = lists
            x = rms_norm(hcur, lp["attn_norm"], cfg.norm_eps)
            q, k, v = attn_qkv(x, lp, cfg, pos[:, None])
            k_hbm_l, v_hbm_l, k_host_l, v_host_l = write_token_layer(
                k_hbm_l, v_hbm_l, k_host_l, v_host_l, slot, offset,
                k[:, 0], v[:, 0])
            qg = q[:, 0].reshape(B, cfg.kv_heads, cfg.q_per_kv, cfg.head_dim)
            hv_new = _bump_valid(hv_l, slot, offset, T, hbm=True,
                                 hbm_pages=k_hbm_l.shape[1])
            ev_new = _bump_valid(ev_l, slot - k_hbm_l.shape[1], offset, T,
                                 hbm=False, hbm_pages=k_hbm_l.shape[1])
            o, imp = kops.tiered_paged_attention(
                qg, k_hbm_l, v_hbm_l, k_host_l, v_host_l,
                hl_l, hv_new, el_l, ev_new, use_pallas=use_pallas)
            o = o.reshape(B, 1, cfg.num_heads, cfg.head_dim)
            hcur = hcur + jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
            return hcur, (k_hbm_l, v_hbm_l, k_host_l, v_host_l), imp

        group = token.shape[0]  # single group at decode

        if il == 1:
            def body(carry, xs):
                hcur = carry
                lp, kh, vh, ke, ve, slot, hl_l, hv_l, el_l, ev_l = xs
                hcur, pools, imp = attn_part(
                    hcur, lp, (kh, vh, ke, ve), slot,
                    (hl_l, hv_l, el_l, ev_l))
                hcur = moe_mod.moe_block(hcur, lp, cfg, group_size=group)
                return hcur, pools + (imp,)
            xs = (params["layers"], cache.k_hbm, cache.v_hbm, cache.k_host,
                  cache.v_host, write_slot, hl, hv, el, ev)
            h, (kh, vh, ke, ve, imp) = jax.lax.scan(body, h, xs)
        else:
            # layers interleave dense/moe: scan over superblocks; cache
            # arrays ordered [dense0, moe0, dense1, moe1, ...]
            nb = cfg.num_layers // 2

            def reshape2(a):
                return a.reshape((nb, 2) + a.shape[1:])

            c2 = jax.tree.map(reshape2, (cache.k_hbm, cache.v_hbm,
                                         cache.k_host, cache.v_host))
            ws2 = reshape2(write_slot)
            l2 = jax.tree.map(reshape2, (hl, hv, el, ev))

            def body(carry, xs):
                hcur = carry
                lp, (kh2, vh2, ke2, ve2), slot2, (hl2, hv2, el2, ev2) = xs
                hcur, pools_a, imp_a = attn_part(
                    hcur, lp["dense_attn"],
                    (kh2[0], vh2[0], ke2[0], ve2[0]), slot2[0],
                    (hl2[0], hv2[0], el2[0], ev2[0]))
                hcur = tfm.dense_mlp_block(hcur, lp["dense_mlp"], cfg)
                hcur, pools_b, imp_b = attn_part(
                    hcur, lp["moe_attn"],
                    (kh2[1], vh2[1], ke2[1], ve2[1]), slot2[1],
                    (hl2[1], hv2[1], el2[1], ev2[1]))
                hcur = moe_mod.moe_block(hcur, lp["moe"], cfg,
                                         group_size=group)
                pools = jax.tree.map(lambda a, b: jnp.stack([a, b]),
                                     pools_a, pools_b)
                imp = jnp.stack([imp_a, imp_b])
                return hcur, pools + (imp,)

            h, (kh, vh, ke, ve, imp) = jax.lax.scan(
                body, h, (params["layers"], c2, ws2, l2))
            kh, vh, ke, ve, imp = jax.tree.map(
                lambda a: a.reshape((-1,) + a.shape[2:]),
                (kh, vh, ke, ve, imp))

        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = tfm.unembed(params, cfg, h)[:, 0]
        cache = _update_cache_after_step(cache, kh, vh, ke, ve, imp,
                                         write_slot, offset)
        return logits, cache

    def _xlstm_decode_step(self, params, state, token):
        cfg = self.cfg
        h = tfm.embed_tokens(params, cfg, token[:, None])[:, 0]
        slstm_ids = set(self._slstm_ids())
        mi = si = 0
        new = dict(state)
        for l in range(cfg.num_layers):
            if l in slstm_ids:
                lp = jax.tree.map(lambda a: a[si], params["slstm"])
                st = (state["s_c"][si], state["s_n"][si],
                      state["s_m"][si], state["s_h"][si])
                y, (c, n, m, hh) = xlstm_mod.slstm_decode_layer(
                    h, lp, cfg, st)
                new["s_c"] = new["s_c"].at[si].set(c)
                new["s_n"] = new["s_n"].at[si].set(n)
                new["s_m"] = new["s_m"].at[si].set(m)
                new["s_h"] = new["s_h"].at[si].set(hh)
                si += 1
            else:
                lp = jax.tree.map(lambda a: a[mi], params["mlstm"])
                st = (state["m_C"][mi], state["m_n"][mi],
                      state["m_m"][mi], state["m_conv"][mi])
                y, (C, n, m, conv) = xlstm_mod.mlstm_decode_layer(
                    h, lp, cfg, st)
                new["m_C"] = new["m_C"].at[mi].set(C)
                new["m_n"] = new["m_n"].at[mi].set(n)
                new["m_m"] = new["m_m"].at[mi].set(m)
                new["m_conv"] = new["m_conv"].at[mi].set(conv)
                mi += 1
            h = h + y
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = tfm.unembed(params, cfg, h[:, None])[:, 0]
        return logits, new

    def _hybrid_decode_step(self, params, state, token, write_slot,
                            use_pallas, logical_page_mask=None):
        cfg = self.cfg
        from repro.models.transformer import (
            _update_cache_after_step, attn_qkv, _bump_valid)
        from repro.kvcache.paged import write_token_layer
        from repro.kernels import ops as kops

        h = tfm.embed_tokens(params, cfg, token[:, None])[:, 0]
        ssm_state = state["ssm"]
        cache: Optional[PagedKVCache] = state.get("kv")
        attn_ids = cfg.attention_layer_ids()
        B = token.shape[0]

        new_s, new_conv = ssm_state["s"], ssm_state["conv"]
        imp_sites = []
        pools = None
        if cache is not None:
            T = cache.k_hbm.shape[3]
            pos = cache.length
            offset = pos % T
            if write_slot is None:
                write_slot = default_write_slot(cache)
            cache = tfm.allocate_token_page(cache, write_slot)
            logical_page_mask = tfm.mask_write_visible(cache,
                                                       logical_page_mask)
            hl, hv, el, ev = cache.tier_lists(
                logical_page_mask=logical_page_mask)
            pools = [cache.k_hbm, cache.v_hbm, cache.k_host, cache.v_host]

        site_i = 0
        for l in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[l], params["mamba"])
            y, s_new, c_new = ssm_mod.mamba2_decode_layer(
                h, lp, cfg, ssm_state["s"][l], ssm_state["conv"][l])
            new_s = new_s.at[l].set(s_new)
            new_conv = new_conv.at[l].set(c_new)
            h = h + y
            if l in attn_ids and cache is not None:
                sp = params["shared_attn"]
                hs = h[:, None]
                x = rms_norm(hs, sp["attn_norm"], cfg.norm_eps)
                q, k, v = attn_qkv(x, sp, cfg, pos[:, None])
                kh, vh, ke, ve = write_token_layer(
                    pools[0][site_i], pools[1][site_i], pools[2][site_i],
                    pools[3][site_i], write_slot[site_i], offset,
                    k[:, 0], v[:, 0])
                qg = q[:, 0].reshape(B, cfg.kv_heads, cfg.q_per_kv,
                                     cfg.head_dim)
                hv_new = _bump_valid(hv[site_i], write_slot[site_i], offset,
                                     T, hbm=True, hbm_pages=kh.shape[1])
                ev_new = _bump_valid(ev[site_i],
                                     write_slot[site_i] - kh.shape[1],
                                     offset, T, hbm=False,
                                     hbm_pages=kh.shape[1])
                o, imp = kops.tiered_paged_attention(
                    qg, kh, vh, ke, ve, hl[site_i], hv_new, el[site_i],
                    ev_new, use_pallas=use_pallas)
                o = o.reshape(B, 1, cfg.num_heads, cfg.head_dim)
                hs = hs + jnp.einsum("bshk,hkd->bsd", o, sp["wo"])
                hs = tfm.dense_mlp_block(hs, sp, cfg)
                h = hs[:, 0]
                pools[0] = pools[0].at[site_i].set(kh)
                pools[1] = pools[1].at[site_i].set(vh)
                pools[2] = pools[2].at[site_i].set(ke)
                pools[3] = pools[3].at[site_i].set(ve)
                imp_sites.append(imp)
                site_i += 1

        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = tfm.unembed(params, cfg, h[:, None])[:, 0]
        new_state = {"ssm": {"s": new_s, "conv": new_conv}}
        if cache is not None:
            imp = jnp.stack(imp_sites)
            cache = _update_cache_after_step(
                cache, pools[0], pools[1], pools[2], pools[3], imp,
                write_slot, offset)
            new_state["kv"] = cache
        return logits, new_state

    def _encdec_decode_step(self, params, state, token, extra, write_slot,
                            use_pallas, logical_page_mask=None):
        """Decoder step: paged self-attn + dense cross-attn.

        state: {"kv": PagedKVCache (self-attn), "enc": [B,F,d] encoder out}
        """
        cfg = self.cfg
        from repro.models.transformer import _update_cache_after_step, _ln
        from repro.kvcache.paged import write_token_layer
        from repro.kernels import ops as kops
        from repro.models.layers import repeat_kv, attention as full_attn

        cache: PagedKVCache = state["kv"]
        enc = state["enc"]
        B = token.shape[0]
        T = cache.k_hbm.shape[3]
        pos = cache.length
        offset = pos % T
        if write_slot is None:
            write_slot = default_write_slot(cache)
        cache = tfm.allocate_token_page(cache, write_slot)
        logical_page_mask = tfm.mask_write_visible(cache, logical_page_mask)
        hl, hv, el, ev = cache.tier_lists(
            logical_page_mask=logical_page_mask)

        h = (params["embed"][token]
             + params["dec_pos"][pos]).astype(cfg.dtype)[:, None]

        def body(carry, xs):
            hcur = carry
            lp, kh, vh, ke, ve, slot, hl_l, hv_l, el_l, ev_l = xs
            x = _ln(hcur, lp["ln1"], cfg.norm_eps)
            sa = lp["self_attn"]
            q = jnp.einsum("bsd,dhk->bshk", x, sa["wq"])
            k = jnp.einsum("bsd,dhk->bshk", x, sa["wk"])
            v = jnp.einsum("bsd,dhk->bshk", x, sa["wv"])
            kh, vh, ke, ve = write_token_layer(kh, vh, ke, ve, slot, offset,
                                               k[:, 0], v[:, 0])
            qg = q[:, 0].reshape(B, cfg.kv_heads, cfg.q_per_kv, cfg.head_dim)
            hv_new = tfm._bump_valid(hv_l, slot, offset, T, hbm=True,
                                     hbm_pages=kh.shape[1])
            ev_new = tfm._bump_valid(ev_l, slot - kh.shape[1], offset, T,
                                     hbm=False, hbm_pages=kh.shape[1])
            o, imp = kops.tiered_paged_attention(
                qg, kh, vh, ke, ve, hl_l, hv_new, el_l, ev_new,
                use_pallas=use_pallas)
            o = o.reshape(B, 1, cfg.num_heads, cfg.head_dim)
            hcur = hcur + jnp.einsum("bshk,hkd->bsd", o, sa["wo"])
            # cross attention over (static) encoder output
            x = _ln(hcur, lp["ln2"], cfg.norm_eps)
            ca = lp["cross_attn"]
            qx = jnp.einsum("bsd,dhk->bshk", x, ca["wq"])
            kx = jnp.einsum("bfd,dhk->bfhk", enc, ca["wk"])
            vx = jnp.einsum("bfd,dhk->bfhk", enc, ca["wv"])
            ox = full_attn(qx, repeat_kv(kx, cfg.q_per_kv),
                           repeat_kv(vx, cfg.q_per_kv), causal=False)
            hcur = hcur + jnp.einsum("bshk,hkd->bsd", ox, ca["wo"])
            x = _ln(hcur, lp["ln3"], cfg.norm_eps)
            m = jnp.einsum("bsd,df->bsf", x, lp["mlp"]["w_in"]) \
                + lp["mlp"]["b_in"]
            hcur = hcur + (jnp.einsum("bsf,fd->bsd", jax.nn.gelu(m),
                                      lp["mlp"]["w_out"])
                           + lp["mlp"]["b_out"])
            return hcur, (kh, vh, ke, ve, imp)

        xs = (params["dec_layers"], cache.k_hbm, cache.v_hbm, cache.k_host,
              cache.v_host, write_slot, hl, hv, el, ev)
        h, (kh, vh, ke, ve, imp) = jax.lax.scan(body, h, xs)
        h = _ln(h, params["dec_final"], cfg.norm_eps)
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])[:, 0]
        cache = _update_cache_after_step(cache, kh, vh, ke, ve, imp,
                                         write_slot, offset)
        return logits, {"kv": cache, "enc": enc}


def default_write_slot(cache: PagedKVCache) -> jax.Array:
    """Static-placement slot choice inside jit (no control plane):
    the token's logical page maps to HBM while room, else host.
    Matches the paper's Static Placement baseline; the serving engine
    overrides this with policy-chosen slots."""
    L, B = cache.page_table.shape[0], cache.page_table.shape[1]
    T = cache.k_hbm.shape[3]
    logical = cache.length // T                       # [B]
    existing = cache.page_table[:, jnp.arange(B), logical]   # [L, B]
    slot = jnp.where(existing >= 0, existing, logical[None, :])
    max_slot = cache.k_hbm.shape[2] + cache.k_host.shape[2] - 1
    return jnp.clip(slot, 0, max_slot).astype(jnp.int32)
