"""Core pure-JAX layer ops shared by every architecture family.

Everything is a plain function over arrays — no module framework. The
memory-hungry paths (prefill/train attention) have a chunked
flash-style implementation so the lowered graph never materializes an
[S, S] score matrix; this is also the reference semantics for the
Pallas flash kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30

# ---------------------------------------------------------------------------
# Activation-batch sharding constraints.
#
# Under FSDP the parameters are sharded on the embed dim over `data`;
# without explicit constraints GSPMD propagates that into activations
# and REPLICATES the batch dim instead (16x redundant attention compute,
# observed in the train_4k dry-run — EXPERIMENTS.md §Perf). The launch
# layer sets the batch mesh axes here; model code pins the batch dim of
# layer inputs. No-op when unset (tests, single-device).
# ---------------------------------------------------------------------------

_ACT_BATCH_AXES = None


def set_activation_batch_axes(axes) -> None:
    global _ACT_BATCH_AXES
    _ACT_BATCH_AXES = tuple(axes) if axes else None


def constrain_batch(x: jax.Array) -> jax.Array:
    """Pin dim0 of an activation to the configured batch mesh axes."""
    if _ACT_BATCH_AXES is None:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(_ACT_BATCH_AXES, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dtype) * w


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dtype) * w + b


# --- RoPE -------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 1e6) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(angles)[..., None, :]                # [..., S, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --- activations ------------------------------------------------------------

def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def gelu_mlp(x: jax.Array, w_in: jax.Array, w_out: jax.Array) -> jax.Array:
    return jnp.einsum("...f,fd->...d",
                      jax.nn.gelu(jnp.einsum("...d,df->...f", x, w_in)),
                      w_out)


# --- attention (full-sequence paths: train / prefill) -----------------------

def repeat_kv(x: jax.Array, q_per_kv: int) -> jax.Array:
    """[B, S, KH, D] -> [B, S, KH*q_per_kv, D]."""
    if q_per_kv == 1:
        return x
    b, s, kh, d = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :], (b, s, kh, q_per_kv, d))
    return x.reshape(b, s, kh * q_per_kv, d)


def prefix_chunk_attention(q, k, v, q_positions) -> jax.Array:
    """Causal attention of a query chunk against a prefix key buffer.

    q: [B, C, H, D] (a slice of a longer sequence); k, v: [B, S, H, D]
    (repeat GQA heads before calling); q_positions: [B, C] absolute
    position of each query. Key i is visible to the query at absolute
    position p iff i <= p — keys past the written prefix contribute
    exact zeros (NEG_INF score -> exp underflows to 0.0), so the result
    for a valid query row is bitwise-identical to `naive_attention`
    over just the visible prefix. This is what makes chunked prefill at
    any token budget reproduce the whole-prompt forward bitwise (see
    transformer.dense_prefill_chunk).
    """
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    kpos = jnp.arange(k.shape[1])
    mask = kpos[None, None, None, :] <= q_positions[:, None, :, None]
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def naive_attention(q, k, v, *, causal: bool = True,
                    q_offset: int = 0) -> jax.Array:
    """Reference attention. q: [B,Sq,H,D], k/v: [B,Sk,H,D]."""
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(sk)
        mask = kpos[None, :] <= qpos[:, None]
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def flash_attention_jnp(q, k, v, *, causal: bool = True, q_chunk: int = 512,
                        k_chunk: int = 1024, q_offset: int = 0) -> jax.Array:
    """Chunked online-softmax attention; never materializes [Sq, Sk].

    q: [B, Sq, H, D]; k, v: [B, Sk, H, D] (same H — repeat GQA before).
    Memory: O(q_chunk * k_chunk) scores per (batch, head).
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    sq_real, sk_real = sq, sk
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, sk)
    # pad to chunk multiples; padded K positions are masked out below,
    # padded Q rows are computed and truncated.
    pad_q = (-sq) % q_chunk
    pad_k = (-sk) % k_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        sq += pad_q
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        sk += pad_k
    scale = d ** -0.5

    nk = sk // k_chunk
    # Single scan over KV chunks with the FULL q resident: one loop level
    # keeps GSPMD sharding propagation intact (a nested map-over-q-chunks
    # made the partitioner replicate the batch dim of the score tensor —
    # 16x redundant compute; see EXPERIMENTS.md §Perf). Live memory is
    # one [B, H, Sq, k_chunk] score block.
    qbh = q.transpose(0, 2, 1, 3)                       # [B,H,Sq,D]
    kc = k.reshape(b, nk, k_chunk, h, d).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nk, k_chunk, h, d).transpose(1, 0, 3, 2, 4)
    qpos = jnp.arange(sq) + q_offset

    def body(carry, inputs):
        m, l, acc = carry
        ki, k_blk, v_blk = inputs                       # [B,H,kc,D]
        s = jnp.einsum("bhqd,bhkd->bhqk", qbh, k_blk) \
               .astype(jnp.float32) * scale
        kpos = ki * k_chunk + jnp.arange(k_chunk)
        mask = kpos[None, :] < sk_real                  # padded K invisible
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        s = jnp.where(jnp.broadcast_to(mask, s.shape[2:])[None, None],
                      s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(v_blk.dtype), v_blk
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (jnp.arange(nk), kc, vc))
    out = (acc / jnp.maximum(l, 1e-20)[..., None]).astype(q.dtype)
    out = out.transpose(0, 2, 1, 3)                     # [B,Sq,H,D]
    return out[:, :sq_real]


def attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
              flash_threshold: int = 2048) -> jax.Array:
    """Dispatch: small sequences use the naive path (cheap on CPU tests),
    long sequences the chunked flash path (bounded memory when lowered)."""
    if q.shape[1] * k.shape[1] <= flash_threshold ** 2:
        return naive_attention(q, k, v, causal=causal, q_offset=q_offset)
    return flash_attention_jnp(q, k, v, causal=causal, q_offset=q_offset)
