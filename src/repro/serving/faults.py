"""Deterministic fault-injection plane for the fused serve loop.

The paper's premise is that tier bandwidth is a *runtime variable* —
so a production-shaped engine must keep serving (and keep its headroom
accounting honest) when the memory system misbehaves. This module is
the injection side of that contract: a `FaultPlane` is a **seeded,
static schedule** of adverse events, queried by `ServingEngine.serve`
at every chunk boundary and folded into the fused chunk as *data,
never shape* — the serve executable with the fault channel compiled in
is the SAME executable whether or not any fault fires (the
one-executable and zero-retrace pins hold with injection active,
asserted by tests/test_chaos.py and `perf_engine.py --ci`).

Fault taxonomy (all windows/steps are fused serve-step indices, i.e.
`ContinuousBatcher.step_idx` units):

  TierFault       host-tier bandwidth degradation / latency spike: the
                  spec's HBM/link/DRAM bandwidths are scaled inside
                  [start, stop). Feeds (a) the per-step Eq. (1)-(5)
                  pricing of `StepStats` (`latency_model.degraded_spec`)
                  and (b) the cost_aware policy's payback
                  recalibration (`DevicePolicy.recalibrate`, values
                  re-uploaded into the scan-threaded policy state at
                  the boundary). Tokens are unaffected by construction
                  — bandwidth is a pricing input, not a compute input.
  MigrationFault  migration-plan drop / partial-commit inside
                  [start, stop): per step, only the first
                  `ceil(commit_frac * budget)` live promote rows (and
                  their paired demote rows) of the `MigrationPlan`
                  commit (`throttle_plan`, jit-safe). Placement — and
                  therefore telemetry and the bridge's scores —
                  reflects the *committed* moves only. Under
                  `EngineConfig.overlap_migrations` the caps throttle
                  the COMMIT of the one-step-lagged STAGED buffer
                  (post-revalidation), so the chaos contract is
                  identical in both modes: plans exist, capped rows
                  land, the rest evaporate.
  PoolFault       page-pool shrink wave: at `step` the scheduler's
                  pool gains `delta` pages (negative = shrink).
                  Reserved pages stay reserved, so `free_pages` may go
                  negative until completions release them; admission
                  stalls meanwhile and permanently-unfittable queued
                  requests are rejected instead of deadlocking.
  PoisonFault     poisoned logits: from `step` on, request `rid`'s
                  lane has its logits overwritten with NaN. The
                  engine's (always-on) non-finite sampling guard
                  quarantines the lane — no token is emitted from the
                  poisoned step, the request ends `failed`, its pages
                  release through the existing masked
                  `control.release_lanes`, and every other lane keeps
                  serving bitwise-identically.

Determinism contract: a `FaultPlane` is pure data — the schedule
depends only on its constructor arguments (or on `FaultPlane.random`'s
seed), and fault application depends only on the engine step index,
never on wall-clock time or host load. Replaying the same requests,
seed, and plane reproduces the same statuses and the same tokens.

Granularity: tier scales, migration caps, and poison masks are exact
per step (threaded through the scan as per-step arrays); pool deltas
land at the chunk boundary whose window covers their step.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.latency_model import degraded_spec
from repro.core.tiers import MemorySystemSpec
from repro.kvcache.migrate import MigrationPlan

#: sentinel commit cap meaning "no migration fault this step" — larger
#: than any real plan capacity, so `throttle_plan` is an identity.
NO_FAULT_CAP = np.int32(2**30)


@dataclasses.dataclass(frozen=True)
class TierFault:
    """Scale the memory system's bandwidths inside [start, stop)."""

    start: int
    stop: int
    hbm_scale: float = 1.0
    link_scale: float = 1.0
    dram_scale: float = 1.0

    def active(self, step: int) -> bool:
        """Whether this fault window covers `step`."""
        return self.start <= step < self.stop


@dataclasses.dataclass(frozen=True)
class MigrationFault:
    """Drop (commit_frac=0) or partially commit migration plans inside
    [start, stop): per planning step only the first
    `ceil(commit_frac * budget)` live promote rows land."""

    start: int
    stop: int
    commit_frac: float = 0.0

    def active(self, step: int) -> bool:
        """Whether this fault window covers `step`."""
        return self.start <= step < self.stop


@dataclasses.dataclass(frozen=True)
class PoolFault:
    """Resize the scheduler's page pool by `delta` pages at `step`
    (negative = shrink wave; a later positive delta models recovery)."""

    step: int
    delta: int


@dataclasses.dataclass(frozen=True)
class PoisonFault:
    """Overwrite request `rid`'s logits with NaN from `step` on (until
    the engine's sampling guard quarantines the lane)."""

    rid: int
    step: int


@dataclasses.dataclass(frozen=True)
class FaultPlane:
    """A static, deterministic schedule of injected faults (see the
    module docstring for taxonomy + contract). Passed to
    `ServingEngine.serve(..., faults=plane)`; safe to reuse across
    serve calls (it is pure data and never mutated)."""

    tier: Tuple[TierFault, ...] = ()
    migration: Tuple[MigrationFault, ...] = ()
    pool: Tuple[PoolFault, ...] = ()
    poison: Tuple[PoisonFault, ...] = ()

    # ------------------------------------------------------------------ #
    # host-side queries (chunk-boundary cadence)
    # ------------------------------------------------------------------ #
    def scales_at(self, step: int) -> Tuple[float, float, float]:
        """(hbm, link, dram) bandwidth scales active at `step` —
        overlapping windows compose multiplicatively."""
        h = k = d = 1.0
        for f in self.tier:
            if f.active(step):
                h *= f.hbm_scale
                k *= f.link_scale
                d *= f.dram_scale
        return h, k, d

    def spec_at(self, step: int, base: MemorySystemSpec
                ) -> MemorySystemSpec:
        """The (possibly degraded) memory-system spec governing `step`:
        `base` with the active tier-fault scales applied."""
        h, k, d = self.scales_at(step)
        if (h, k, d) == (1.0, 1.0, 1.0):
            return base
        return degraded_spec(base, hbm_scale=h, link_scale=k,
                             dram_scale=d)

    def commit_caps(self, step0: int, stride: int,
                    budget_rows: int) -> np.ndarray:
        """Per-step migration commit caps for the chunk starting at
        `step0`, int32 [stride]: `NO_FAULT_CAP` on fault-free steps,
        else `ceil(commit_frac * budget_rows)` (0 = full drop). The
        worst (smallest) active window wins when windows overlap."""
        caps = np.full((stride,), NO_FAULT_CAP, np.int32)
        for f in self.migration:
            lo = max(f.start - step0, 0)
            hi = min(f.stop - step0, stride)
            if lo < hi:
                cap = int(np.ceil(f.commit_frac * budget_rows))
                caps[lo:hi] = np.minimum(caps[lo:hi], cap)
        return caps

    def pool_delta(self, step0: int, stride: int) -> int:
        """Net page-pool delta of PoolFaults scheduled inside
        [step0, step0 + stride) — applied at that chunk's boundary."""
        return sum(f.delta for f in self.pool
                   if step0 <= f.step < step0 + stride)

    def poison_steps(self, step0: int, stride: int,
                     rids: np.ndarray) -> np.ndarray:
        """Per-step lane poison mask, bool [stride, B]: lane b is
        poisoned at chunk-local step i when a PoisonFault targets its
        bound rid and `fault.step <= step0 + i`. Free lanes (rid -1)
        are never poisoned."""
        mask = np.zeros((stride, len(rids)), bool)
        for f in self.poison:
            lanes = np.nonzero(rids == f.rid)[0]
            if lanes.size:
                lo = max(f.step - step0, 0)
                if lo < stride:
                    mask[lo:, lanes] = True
        return mask

    def window_events(self, step0: int, stride: int) -> list:
        """Schedule entries ACTIVATING inside [step0, step0 + stride),
        as telemetry event dicts — the engine stamps these into
        `ServeReport.events` so a scored stream names the faults that
        shaped its placement."""
        lo, hi = step0, step0 + stride
        out = []
        for f in self.tier:
            if lo <= f.start < hi:
                out.append({"kind": "tier_degradation", "step": f.start,
                            "stop": f.stop, "hbm_scale": f.hbm_scale,
                            "link_scale": f.link_scale,
                            "dram_scale": f.dram_scale})
        for f in self.migration:
            if lo <= f.start < hi:
                out.append({"kind": "migration_fault", "step": f.start,
                            "stop": f.stop,
                            "commit_frac": f.commit_frac})
        for f in self.pool:
            if lo <= f.step < hi:
                out.append({"kind": "pool_resize", "step": f.step,
                            "delta": f.delta})
        for f in self.poison:
            if lo <= f.step < hi:
                out.append({"kind": "logit_poison", "step": f.step,
                            "rid": f.rid})
        return out

    # ------------------------------------------------------------------ #
    @staticmethod
    def random(seed: int, *, steps: int, rids: Sequence[int] = (),
               n_tier: int = 2, n_migration: int = 2, n_pool: int = 1,
               n_poison: int = 1, max_shrink: int = 2) -> "FaultPlane":
        """A seeded random schedule over a `steps`-long stream — the
        chaos-smoke generator. Deterministic: the same (seed, kwargs)
        always builds the identical plane."""
        rng = np.random.default_rng(seed)

        def window():
            a = int(rng.integers(0, max(steps - 1, 1)))
            b = int(rng.integers(a + 1, steps + 1))
            return a, b

        tier = []
        for _ in range(n_tier):
            a, b = window()
            tier.append(TierFault(
                start=a, stop=b,
                link_scale=float(rng.uniform(0.1, 0.8)),
                dram_scale=float(rng.uniform(0.25, 1.0))))
        migration = []
        for _ in range(n_migration):
            a, b = window()
            migration.append(MigrationFault(
                start=a, stop=b,
                commit_frac=float(rng.choice([0.0, 0.5]))))
        pool = [PoolFault(step=int(rng.integers(0, max(steps, 1))),
                          delta=-int(rng.integers(1, max_shrink + 1)))
                for _ in range(n_pool)]
        poison = []
        if rids:
            picks = rng.choice(np.asarray(list(rids)),
                               size=min(n_poison, len(rids)),
                               replace=False)
            poison = [PoisonFault(rid=int(r),
                                  step=int(rng.integers(0, max(steps, 1))))
                      for r in picks]
        return FaultPlane(tier=tuple(tier), migration=tuple(migration),
                          pool=tuple(pool), poison=tuple(poison))


# -------------------------------------------------------------------------- #
# jit-safe plan throttling (the traced half of the migration fault)
# -------------------------------------------------------------------------- #

def throttle_plan(plan: MigrationPlan, cap) -> MigrationPlan:
    """Commit only the first `cap` live promote rows of a plan (and
    their index-paired demote rows); the rest become -1 sentinel no-ops.

    `cap` is a traced int32 scalar — DATA, so a fault-free step
    (cap >= capacity) is a bitwise identity and the executable never
    retraces across fault schedules. Demote rows are masked with the
    SAME row mask as promotes (`plan_by_score` pairs demote i with
    promote i), so a partial commit can never orphan half a swap.

    In overlap mode the engine applies this to the STAGED plan after
    `control.revalidate_plan` masked its hazards — throttling the
    commit, never the planning, so a zero cap (full drop / static
    fallback) still leaves the pipeline staging fresh plans that then
    evaporate, exactly like the inline path's drop semantics."""
    live = plan.pro_layer >= 0
    keep = (jnp.cumsum(live.astype(jnp.int32)) <= cap) & live

    def m(a):
        return jnp.where(keep, a, jnp.int32(-1))

    return MigrationPlan(
        m(plan.pro_layer), m(plan.pro_batch), m(plan.pro_src),
        m(plan.pro_dst), m(plan.pro_logical),
        m(plan.dem_layer), m(plan.dem_batch), m(plan.dem_src),
        m(plan.dem_dst), m(plan.dem_logical))
