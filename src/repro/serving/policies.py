"""Pluggable on-device placement policies — the policy plane of the
fused serve loop.

The paper scores seven placement policies against the SA upper bound in
the host simulator (`repro.core.placement`), but a simulator verdict is
only as good as its model. This module puts the same policy *family* on
the live hot path: every policy below is jit-safe, statically shaped,
and plans through the shared fixed-capacity pairing core
(`control.plan_by_score`), so each one compiles into ONE serve
executable per geometry — swapping policies swaps a traced function,
never the architecture. The simulator bridge
(`repro.serving.trace_bridge`) then closes the loop by scoring each
policy's live telemetry against the SA bound and the Belady oracle.

Protocol (duck-typed, no registration of the engine required):

  init_state(geo) -> pytree     policy state threaded through the
                                serve `lax.scan` (empty tuple for
                                stateless policies). Values may change
                                every step; shapes may not (zero
                                retraces across the stream). Under a
                                mesh the engine shards state leaves by
                                shape (`launch.shardings.policy_state_
                                shardings`): [L, B, ...] and [B] leaves
                                follow the lanes over `data`, scalars
                                (cost_aware's payback bars) replicate.
  plan(cache, state, active, budget, read_mask=None)
      -> (MigrationPlan, state, (n_promotes, n_demotes))
                                one planning step. The plan's capacity
                                must be the geometry constant
                                `control.plan_capacity` so
                                `apply_migrations` compiles once.
                                `read_mask` (bool [L, B, max_pages],
                                optional) is the page set THIS step's
                                attention actually read — the engine's
                                pre-decode Quest mask, or every
                                pre-decode page when dense — so
                                history-tracking policies see the same
                                access stream the telemetry records.

Plan-ahead semantics (`EngineConfig.overlap_migrations`): under the
overlap pipeline a plan built at step N commits at step N+1, so the
policy is planning for the step AFTER next — `read_mask` becomes a
one-step-ahead re-reference oracle (decode reads are strongly
self-similar step to step: the same prompt pages stream every step, and
the Quest mask drifts by at most the EMA update). Every registered
policy then additionally PROTECTS the read set's HBM residents from
eviction (`protect_read_residents`: score +inf, so no candidate can
displace a page the next step will almost surely read — evicting one
would force the commit to race the very read it serves). Candidate
ranking is unchanged; `static` plans nothing either way, and `quest`
already ranks by its own next-step mask foresight, which subsumes the
oracle. Protection is values-only, so both modes share one traced
planner. The oracle needs a SPARSE read set to discriminate: dense
attention (attention_sparsity 0) reads every alive page each step, so
protecting the read set would freeze placement entirely — plan-ahead
therefore activates only when attention_sparsity > 0, and dense
overlap streams plan with inline scoring (the pipeline still overlaps
the commit; only the extra protection is skipped).

Registered policies (EngineConfig.policy):

  static      never migrates — an empty plan, the paper's baseline #2.
  importance  the attention-mass-EMA hysteresis planner (today's
              deployable default, `control.plan_migrations`).
  recency     LRU by last-access step — the live mirror of
              `core/placement/reactive.py`: host pages read this step
              are promoted, the least-recently-read HBM residents make
              room.
  cost_aware  importance hysteresis with thresholds DERIVED from the
              memory system's bandwidth ratios
              (`core/placement/cost_aware.payback_threshold`): a page
              is promoted only when its attention-mass share pays back
              the link cost within the importance-EMA horizon; warm
              residents are protected from eviction (hysteresis band).
  quest       promotes exactly the pages the Quest top-k mask will
              read next (one-step mask foresight — the live mirror of
              `core/placement/quest_pages.py`); mask-resident HBM
              pages are never evicted.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.placement.cost_aware import hysteresis_thresholds
from repro.kvcache.migrate import MigrationPlan
from repro.kvcache.paged import IMPORTANCE_EMA, PagedKVCache
from repro.serving import control

Counts = Tuple[jax.Array, jax.Array]
PlanResult = Tuple[MigrationPlan, Any, Counts]

_NEG_INF = jnp.float32(-jnp.inf)
_POS_INF = jnp.float32(jnp.inf)


class DevicePolicy:
    """Base class for jit-safe migration planners (see module doc)."""

    name = "base"

    def __init__(self, *, cfg, geo):
        #: one-step-ahead planning (overlap pipeline): treat `read_mask`
        #: as a re-reference oracle and protect its HBM residents from
        #: eviction. Requires a SPARSE read set to be informative:
        #: dense attention reads every alive page, so the "oracle"
        #: would protect every resident and freeze placement outright —
        #: gate on attention_sparsity > 0. Set from
        #: `EngineConfig.overlap_migrations`; duck-typed so standalone
        #: policy construction (tests, the simulator bridge) defaults
        #: to inline semantics.
        self.plan_ahead = (
            bool(getattr(cfg, "overlap_migrations", False))
            and getattr(cfg, "attention_sparsity", 0.0) > 0.0)
        del cfg, geo

    def init_state(self, geo) -> Any:
        """Fresh policy state for a stream over `geo` (pytree of arrays
        with stream-independent shapes; `()` for stateless policies)."""
        del geo
        return ()

    def plan(self, cache: PagedKVCache, state: Any, active, budget: int,
             read_mask=None) -> PlanResult:
        """One planning step -> (MigrationPlan, state, (n_pro, n_dem)).

        See the module docstring for the contract; subclasses must keep
        the plan capacity at the geometry constant and all state shapes
        static."""
        raise NotImplementedError

    def recalibrate(self, state: Any, spec) -> Any:
        """Re-derive any spec-dependent state values for a (possibly
        degraded) `MemorySystemSpec` — called by the engine at chunk
        boundaries when a tier fault changes the effective bandwidths.
        Values only, never shapes (the zero-retrace pin). Default:
        nothing in the state depends on the spec."""
        del spec
        return state


def check_read_mask(cache: PagedKVCache, read_mask) -> None:
    """Trace-time consistency check for the engine-supplied read set.

    `read_mask` is PER-LANE ([L, B, max_pages], matching the page
    table): each batch lane's column is that lane's own access stream.
    The serve-trace capture gates the same tensor by the decoding-lane
    mask before attribution, so a shape mismatch here would silently
    desynchronize policies from the telemetry the bridge scores —
    fail at trace time instead. No-op when the mask is absent."""
    assert read_mask is None or \
        read_mask.shape == cache.page_table.shape, \
        (read_mask.shape, cache.page_table.shape)


def protect_read_residents(cache: PagedKVCache, hbm_score: jax.Array,
                           read_mask) -> jax.Array:
    """Plan-ahead eviction guard: +inf the HBM score of every resident
    whose logical page is in `read_mask` — the one-step-ahead
    re-reference oracle of overlap mode (see the module docstring).

    A +inf victim score means no finite candidate can displace the slot
    (`control.plan_by_score`'s protection convention, same as
    cost_aware's hysteresis band). No-op when the mask is absent (the
    standalone / inline paths)."""
    if read_mask is None:
        return hbm_score
    ho = cache.hbm_owner
    in_read = jnp.take_along_axis(
        read_mask, jnp.maximum(ho, 0), axis=-1) & (ho >= 0)
    return jnp.where(in_read, _POS_INF, hbm_score)


_REGISTRY: Dict[str, Callable[..., DevicePolicy]] = {}


def register(name: str):
    """Class decorator: make a DevicePolicy selectable by
    `EngineConfig.policy`."""
    def deco(factory):
        assert name not in _REGISTRY, name
        _REGISTRY[name] = factory
        return factory
    return deco


def policy_names() -> Tuple[str, ...]:
    """The registered device-policy names, sorted (the valid values of
    `EngineConfig.policy`)."""
    return tuple(sorted(_REGISTRY))


def make_policy(name: str, *, cfg, geo) -> DevicePolicy:
    """Build a registered policy for an engine config + cache geometry.

    `cfg` is duck-typed (an `EngineConfig`): policies read the static
    knobs they need (promote_thresh, attention_sparsity, spec, ...) at
    construction so the planning function itself stays pure.
    """
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown device policy {name!r}; registered policies: "
            f"{', '.join(policy_names())}")
    return _REGISTRY[name](cfg=cfg, geo=geo)


@register("static")
class StaticPolicy(DevicePolicy):
    """Never migrate (paper baseline #2) — a real policy, not an engine
    special case: the step applies an all-sentinel plan, which
    `apply_migrations` drops bitwise. Plan-ahead is vacuous: an empty
    plan stages nothing, so overlap mode changes nothing here."""

    name = "static"

    def plan(self, cache, state, active, budget,
             read_mask=None) -> PlanResult:
        """Plan nothing: an all-sentinel fixed-capacity plan."""
        check_read_mask(cache, read_mask)
        L, B, _ = cache.hbm_owner.shape
        zero = jnp.zeros((), jnp.int32)
        return MigrationPlan.empty(L * B * budget), state, (zero, zero)


@register("importance")
class ImportancePolicy(DevicePolicy):
    """Attention-mass-EMA hysteresis (`control.plan_migrations`) —
    bitwise identical to the planner the fused engine shipped with."""

    name = "importance"

    def __init__(self, *, cfg, geo):
        super().__init__(cfg=cfg, geo=geo)
        self._thresh = cfg.promote_thresh

    def plan(self, cache, state, active, budget,
             read_mask=None) -> PlanResult:
        """Promote the hottest host pages by importance EMA; in
        plan-ahead mode the read set's residents are additionally
        protected (the staged commit must not race its own reads)."""
        check_read_mask(cache, read_mask)
        if not self.plan_ahead:
            plan, n_pro, n_dem = control.plan_migrations(
                cache, budget=budget, promote_thresh=self._thresh,
                active=active)
            return plan, state, (n_pro, n_dem)
        imp = cache.importance
        host_imp = control.slot_scores(imp, cache.host_owner)
        hbm_imp = control.slot_scores(imp, cache.hbm_owner)
        hbm_imp = protect_read_residents(cache, hbm_imp, read_mask)
        plan, n_pro, n_dem = control.plan_by_score(
            cache, host_imp, hbm_imp, budget=budget,
            promote_thresh=self._thresh, active=active)
        return plan, state, (n_pro, n_dem)


@register("recency")
class RecencyPolicy(DevicePolicy):
    """LRU by last-access step (live mirror of ReactiveLRU).

    A page is "accessed" when this step's read set includes it — the
    engine-supplied `read_mask` (the pre-decode Quest mask attention
    actually streamed, or every pre-decode page when dense — the exact
    access stream the trace telemetry records and the simulator mirror
    replays). Host pages accessed within `window` steps are promotion
    candidates (most recently read first); victims are the
    least-recently-read HBM residents. A candidate never displaces a
    page read at the same step (strict-inequality pairing), which is
    ReactiveLRU's "never evict the ones just accessed" rule.
    """

    name = "recency"
    window = 8

    def __init__(self, *, cfg, geo):
        super().__init__(cfg=cfg, geo=geo)
        self._sparsity = cfg.attention_sparsity

    def init_state(self, geo) -> Any:
        """Per-page last-access timestamps (-1 = never) + step count."""
        shape = (geo.num_layers, geo.batch, geo.max_pages)
        return {"last": jnp.full(shape, -1, jnp.int32),
                "step": jnp.zeros((), jnp.int32)}

    def plan(self, cache, state, active, budget,
             read_mask=None) -> PlanResult:
        """Promote recently read host pages, evict LRU residents."""
        check_read_mask(cache, read_mask)
        alive = cache.page_table >= 0
        if read_mask is not None:
            read = read_mask & alive
        elif self._sparsity > 0:
            # standalone fallback (direct policy use outside the
            # engine): approximate with the post-step mask
            read = control.quest_page_mask(cache, self._sparsity)
        else:
            read = alive
        step = state["step"] + 1
        # unallocated pages forget their timestamp: when serve()
        # releases a lane its page table clears, so a later request
        # admitted into the same lane never inherits the evicted
        # request's access history
        last = jnp.where(read, step, jnp.where(alive, state["last"], -1))
        scores = last.astype(jnp.float32)
        host_score = control.slot_scores(scores, cache.host_owner)
        hbm_score = control.slot_scores(scores, cache.hbm_owner)
        if self.plan_ahead:
            # one-step-ahead oracle: just-read residents are already
            # the most recent (strict inequality shields them), but
            # +inf makes the guarantee unconditional under the lagged
            # commit
            hbm_score = protect_read_residents(cache, hbm_score, read)
        # clamped at 0 so never-read pages (timestamp -1) don't qualify
        # while the stream is younger than the window
        thresh = jnp.maximum(step - self.window, 0).astype(jnp.float32)
        plan, n_pro, n_dem = control.plan_by_score(
            cache, host_score, hbm_score, budget=budget,
            promote_thresh=thresh, active=active)
        return plan, {"last": last, "step": step}, (n_pro, n_dem)


@register("cost_aware")
class CostAwarePolicy(DevicePolicy):
    """Bandwidth-ratio hysteresis (live mirror of CostAwareHysteresis).

    Promote threshold = `payback_threshold(spec, 1 / IMPORTANCE_EMA)`:
    the attention-mass share at which keeping the page HBM-resident
    over the EMA horizon repays one link crossing under the spec's
    Eq.(3)/(4) constants. Residents above `demote_ratio` of that
    threshold are protected from eviction — the hysteresis band that
    keeps ReactiveLRU-style churn bounded.

    The thresholds are policy STATE, not trace constants: they ride the
    scan carry as float32 scalars, so when the fault plane degrades the
    memory system mid-stream the engine recalibrates them from the
    degraded spec (`recalibrate`) without retracing the executable —
    the payback bar rises with a harsher link, exactly as the economics
    say it should.
    """

    name = "cost_aware"
    demote_ratio = 0.25

    def __init__(self, *, cfg, geo):
        super().__init__(cfg=cfg, geo=geo)
        self._base_spec = cfg.spec

    def init_state(self, geo) -> Any:
        """Payback thresholds for the base (fault-free) spec, carried
        as data so tier faults can recalibrate them mid-stream."""
        del geo
        return self.recalibrate(None, self._base_spec)

    def recalibrate(self, state: Any, spec) -> Any:
        """Thresholds re-derived for `spec` (same shapes, new values)."""
        del state
        t_pro, t_dem = hysteresis_thresholds(
            spec, 1.0 / IMPORTANCE_EMA, self.demote_ratio)
        return {"t_promote": jnp.float32(t_pro),
                "t_demote": jnp.float32(t_dem)}

    def plan(self, cache, state, active, budget,
             read_mask=None) -> PlanResult:
        """Promote pages whose attention mass repays the link cost."""
        check_read_mask(cache, read_mask)
        imp = cache.importance
        host_score = control.slot_scores(imp, cache.host_owner)
        hbm_imp = control.slot_scores(imp, cache.hbm_owner)
        # residents warmer than the demote threshold are not victims
        protected = (cache.hbm_owner >= 0) & (hbm_imp >= state["t_demote"])
        hbm_score = jnp.where(protected, _POS_INF, hbm_imp)
        if self.plan_ahead:
            # the hysteresis band protects WARM residents; the oracle
            # additionally protects the about-to-be-read ones, warm or
            # not — a cold page the next step reads is still a terrible
            # eviction under a lagged commit
            hbm_score = protect_read_residents(cache, hbm_score,
                                               read_mask)
        plan, n_pro, n_dem = control.plan_by_score(
            cache, host_score, hbm_score, budget=budget,
            promote_thresh=state["t_promote"], active=active)
        return plan, state, (n_pro, n_dem)


@register("quest")
class QuestPolicy(DevicePolicy):
    """Promote exactly what the Quest top-k mask reads next (live
    mirror of QuestPages).

    The mask over the post-step cache is the page set the NEXT step's
    attention will stream; host-resident members are promoted (hottest
    first when over budget), mask-resident HBM pages are protected,
    and the coldest non-mask residents make room. With sparsity 0 the
    mask covers every alive page, so only free HBM slots are filled —
    page-granularity prefetch degenerates to first-touch placement,
    exactly as in the simulator baseline.

    Plan-ahead is this policy's NATIVE mode: it already ranks by the
    next step's mask and protects the mask's residents, which subsumes
    the read-set oracle — overlap mode changes nothing in its scoring.
    """

    name = "quest"

    def __init__(self, *, cfg, geo):
        super().__init__(cfg=cfg, geo=geo)
        self._sparsity = cfg.attention_sparsity

    def plan(self, cache, state, active, budget,
             read_mask=None) -> PlanResult:
        """Prefetch the next step's Quest top-k read set into HBM."""
        check_read_mask(cache, read_mask)
        # deliberately NOT read_mask (this step's reads): the policy
        # prefetches for the NEXT read, so it ranks the mask over the
        # post-step cache — the page set the next attention will want
        mask = control.quest_page_mask(cache, self._sparsity)
        imp = cache.importance
        eo, ho = cache.host_owner, cache.hbm_owner
        in_mask_host = jnp.take_along_axis(
            mask, jnp.maximum(eo, 0), axis=-1) & (eo >= 0)
        host_imp = control.slot_scores(imp, eo)
        # candidates are the mask's host residents; +1 keeps every
        # member above the 0.0 threshold (importance is nonnegative)
        host_score = jnp.where(in_mask_host, 1.0 + host_imp, _NEG_INF)
        in_mask_hbm = jnp.take_along_axis(
            mask, jnp.maximum(ho, 0), axis=-1) & (ho >= 0)
        hbm_imp = control.slot_scores(imp, ho)
        hbm_score = jnp.where(in_mask_hbm, _POS_INF, hbm_imp)
        plan, n_pro, n_dem = control.plan_by_score(
            cache, host_score, hbm_score, budget=budget,
            promote_thresh=0.0, active=active)
        return plan, state, (n_pro, n_dem)
