"""Device-side (jit-safe) decode control plane.

The paper's premise is that placement decisions happen at token
cadence, so the control plane must be cheap relative to the data plane.
Everything here is statically-shaped JAX vectorized over [L, B] — no
Python loops, no host round-trips — so the whole decode step (write-slot
selection, Quest-style top-k page masking, importance-EMA migration
planning) fuses into one jitted program and can run under `lax.scan`
(see `ServingEngine.run` / `.generate` and EXPERIMENTS.md §Fused-engine).

Semantics match the original host-side planner exactly:

  * write slot: the token's logical page keeps its existing mapping;
    a fresh page takes the first free HBM slot, else the first free
    host slot, else the last host slot.
  * quest mask: keep the top-k pages by importance EMA (k from the
    sparsity target), always keeping the sink page and the two most
    recent pages.
  * migrations: per (layer, batch), promote the `budget` hottest host
    pages above `promote_thresh`; free HBM slots are consumed first
    (in slot order), then the coldest HBM residents are swapped out —
    the i-th hottest candidate displaces the i-th coldest victim only
    if strictly hotter, which reproduces the sequential early-break of
    the loop form (candidate importance is non-increasing in i while
    victim importance is non-decreasing).

Overlap mode (EXPERIMENTS.md §Async-migration) threads a STAGED
`MigrationPlan` through the serve scan carry: step N commits the plan
step N-1 staged while planning for step N+1. The hazard masking that
makes the one-step lag safe lives here — `revalidate_plan` re-checks
every staged row against the commit-time owner maps (in-flight decode /
prefill allocations invalidate rows instead of being clobbered), and
`mask_plan_lanes` drops rows for lanes the host rebound at a chunk
boundary (lane reuse can reproduce identical (slot, logical) pairs for
a different request, which owner maps cannot distinguish).

Under a device mesh (EXPERIMENTS.md §Mesh-sharding) nothing here
changes: planning is elementwise over [L, B] pools that GSPMD shards
lanes-over-`data` and heads/pages-over-`model`, plan tensors inherit
the pool shardings, and the per-boundary commit caps
(`MigrationFault` throttles) stay replicated scalars — so the control
plane partitions along with the data plane with no extra collectives.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kvcache.migrate import MigrationPlan
from repro.kvcache.paged import NO_SLOT, PagedKVCache


def choose_write_slot(cache: PagedKVCache) -> jax.Array:
    """Physical slot [L, B] receiving this step's token."""
    T = cache.k_hbm.shape[3]
    hbm_pages = cache.k_hbm.shape[2]
    host_pages = cache.k_host.shape[2]
    max_pages = cache.page_table.shape[2]
    B = cache.length.shape[0]

    logical = jnp.minimum(cache.length // T, max_pages - 1)        # [B]
    existing = cache.page_table[:, jnp.arange(B), logical]         # [L, B]

    free_h = cache.hbm_owner < 0                                   # [L,B,Ph]
    has_h = jnp.any(free_h, axis=-1)
    first_h = jnp.argmax(free_h, axis=-1).astype(jnp.int32)
    free_e = cache.host_owner < 0
    has_e = jnp.any(free_e, axis=-1)
    first_e = jnp.argmax(free_e, axis=-1).astype(jnp.int32)

    spill = hbm_pages + jnp.where(has_e, first_e, host_pages - 1)
    fresh = jnp.where(has_h, first_h, spill)
    return jnp.where(existing >= 0, existing, fresh).astype(jnp.int32)


def quest_page_mask(cache: PagedKVCache, sparsity: float) -> jax.Array:
    """Quest-style top-k page mask, bool [L, B, max_pages].

    Keeps ceil-rounded (1 - sparsity) * n_alive pages per (layer, batch)
    ranked by importance EMA (at least 1), plus the sink page (logical
    0) and the two most recently born pages.
    """
    alive = cache.page_table >= 0                                  # [L,B,P]
    n_alive = alive.sum(axis=-1)                                   # [L,B]
    k = jnp.maximum(1, jnp.round((1.0 - sparsity)
                                 * n_alive).astype(jnp.int32))
    imp = jnp.where(alive, cache.importance, -jnp.inf)
    order = jnp.argsort(-imp, axis=-1)          # stable desc; dead last
    rank = jnp.argsort(order, axis=-1)          # rank of each page
    topk = rank < k[..., None]
    idx = jnp.arange(alive.shape[-1])[None, None, :]
    sink = idx == 0
    recent = idx >= (n_alive[..., None] - 2)
    return alive & (topk | sink | recent)


def migration_budget(geo, frac: float) -> int:
    """Per-(layer, batch) promote budget — a static Python int, so plan
    capacity (and therefore `apply_migrations`'s traced shapes) depend
    only on the cache geometry, never on step-time page counts."""
    return min(max(1, int(frac * geo.hbm_pages)),
               geo.hbm_pages, geo.host_pages)


def plan_capacity(geo, frac: float) -> int:
    """Fixed MigrationPlan capacity for a geometry: every (layer, batch)
    pair may promote (and thus demote) at most `migration_budget` pages."""
    return geo.num_layers * geo.batch * migration_budget(geo, frac)


def plan_by_score(cache: PagedKVCache, host_score: jax.Array,
                  hbm_score: jax.Array, *, budget: int,
                  promote_thresh, active: Optional[jax.Array] = None,
                  ) -> Tuple[MigrationPlan, jax.Array, jax.Array]:
    """Generic fixed-capacity promote/demote pairing by per-slot score.

    The planner core shared by every device policy (see
    `repro.serving.policies`): per (layer, batch), promote the `budget`
    highest-scoring host slots above `promote_thresh`; free HBM slots
    are consumed first, then the lowest-scoring residents are swapped
    out — the i-th best candidate displaces the i-th worst victim only
    if strictly higher-scoring, reproducing the sequential early-break
    of the loop form.

    host_score [L, B, Pe]: candidate score per host slot. -inf marks
      an ineligible slot (free, or excluded by the policy).
    hbm_score [L, B, Ph]: victim score per HBM slot. -inf marks a free
      slot (always a valid destination); +inf protects a resident from
      eviction (a candidate's finite score can never beat it).
    promote_thresh: float or traced scalar — candidates must exceed it.

    Returns (plan, n_promotes, n_demotes); the plan's capacity is
    L * B * budget regardless of how many rows are live, so
    `apply_migrations` compiles exactly once per geometry.

    `active` (bool [B], optional) gates planning per batch lane: lanes
    whose slot holds no live request (continuous batching) plan no
    moves, so completed/empty lanes never churn pages and their counts
    never pollute the telemetry.
    """
    ho, eo = cache.hbm_owner, cache.host_owner
    L, B, Ph = ho.shape
    Pe = eo.shape[2]
    assert 1 <= budget <= min(Ph, Pe), (budget, Ph, Pe)

    # best `budget` candidate host slots
    cand_imp, cand_slot = jax.lax.top_k(host_score, budget)       # [L,B,M]
    cand_logical = jnp.take_along_axis(eo, cand_slot, axis=-1)

    # destination ranking: free HBM slots (score -inf) first, then the
    # worst residents — ascending stable sort does both at once
    dst_slot = jnp.argsort(hbm_score, axis=-1)[..., :budget].astype(jnp.int32)
    victim_imp = jnp.take_along_axis(hbm_score, dst_slot, axis=-1)
    victim_logical = jnp.take_along_axis(ho, dst_slot, axis=-1)

    promote = (cand_imp > promote_thresh) & (victim_imp < cand_imp)
    if active is not None:
        promote = promote & active[None, :, None]
    demote = promote & (victim_logical >= 0)   # dst was occupied: swap out

    lidx = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[:, None, None],
                            promote.shape)
    bidx = jnp.broadcast_to(jnp.arange(B, dtype=jnp.int32)[None, :, None],
                            promote.shape)

    def rows(ok, *cols):
        return [jnp.where(ok, c, -1).reshape(-1).astype(jnp.int32)
                for c in cols]

    plan = MigrationPlan(
        # promote: host slot cand_slot -> hbm slot dst_slot
        *rows(promote, lidx, bidx, cand_slot, dst_slot, cand_logical),
        # demote: hbm slot dst_slot -> the host slot vacated by the
        # promotion (cand_slot), carrying the victim's logical page
        *rows(demote, lidx, bidx, dst_slot, cand_slot, victim_logical),
    )
    return plan, promote.sum(), demote.sum()


def _mask_plan_rows(plan: MigrationPlan, keep: jax.Array) -> MigrationPlan:
    """Sentinel out every plan row where `keep` is False — BOTH halves
    with the same [M] mask (`plan_by_score` pairs demote i with promote
    i, and a demote row is live only when its promote is), so a masked
    plan never orphans half a swap."""
    def m(a):
        return jnp.where(keep, a, jnp.int32(-1))

    return MigrationPlan(
        m(plan.pro_layer), m(plan.pro_batch), m(plan.pro_src),
        m(plan.pro_dst), m(plan.pro_logical),
        m(plan.dem_layer), m(plan.dem_batch), m(plan.dem_src),
        m(plan.dem_dst), m(plan.dem_logical))


def revalidate_plan(plan: MigrationPlan, cache: PagedKVCache
                    ) -> MigrationPlan:
    """Hazard-mask a STAGED plan against the commit-time owner maps.

    In overlap mode (`EngineConfig.overlap_migrations`) a plan is built
    at step N and commits at step N+1, so the steps in between — the
    next decode's fresh-page allocation (`allocate_token_page`), the
    prefill plane's page registration, a competing commit — may have
    changed the placement the plan assumed. A promote row survives only
    when the world still matches the plan:

      * its source host slot still holds the planned logical page
        (``host_owner[src] == logical`` — a release, re-admission, or
        earlier promote of that page invalidates the row);
      * its destination is still what the plan paired it with: the
        planned victim for swap rows (``hbm_owner[dem_src] ==
        dem_logical``), a still-free slot for fill rows
        (``hbm_owner[dst] < 0`` — a decode/prefill allocation into the
        slot in the interim kills the row rather than letting the
        commit clobber a page the in-flight step just wrote).

    Demote rows are masked with the SAME row mask (index-paired swaps,
    as in `faults.throttle_plan`). This is values-only masking over the
    fixed-capacity plan — jit-safe, zero retraces — and it makes the
    staged commit idempotent against every in-flight mutation the scan
    can produce; the one hazard owner maps cannot express (a released
    lane re-bound to a DIFFERENT request with the same deterministic
    static placement) is handled by `mask_plan_lanes` at chunk
    boundaries.
    """
    ho, eo = cache.hbm_owner, cache.host_owner
    Ph, Pe = ho.shape[2], eo.shape[2]

    def gather(owner, l, b, s, bound):
        return owner[jnp.clip(l, 0, owner.shape[0] - 1),
                     jnp.maximum(b, 0),
                     jnp.clip(s, 0, bound - 1)]

    live = plan.pro_layer >= 0
    src_owner = gather(eo, plan.pro_layer, plan.pro_batch,
                       plan.pro_src, Pe)
    src_ok = src_owner == plan.pro_logical
    dst_owner = gather(ho, plan.pro_layer, plan.pro_batch,
                       plan.pro_dst, Ph)
    swap = plan.dem_layer >= 0
    victim_owner = gather(ho, plan.dem_layer, plan.dem_batch,
                          plan.dem_src, Ph)
    dst_ok = jnp.where(swap, victim_owner == plan.dem_logical,
                       dst_owner < 0)
    return _mask_plan_rows(plan, live & src_ok & dst_ok)


def mask_plan_lanes(plan: MigrationPlan, stale: jax.Array
                    ) -> MigrationPlan:
    """Drop every staged row targeting a `stale` lane (bool [B]).

    The chunk-boundary half of overlap-mode hazard masking: a plan
    staged in the previous chunk may reference a lane the host released
    or (re)admitted at the boundary. `revalidate_plan` cannot catch the
    reuse case — static placement is deterministic, so a re-admitted
    request can reproduce the exact (slot, logical) pairs of the
    evicted one with a DIFFERENT request's pages — so the engine masks
    freshly (re)bound lanes out of the staged buffer explicitly before
    the chunk runs (tests/test_serve_trace.py lane-reuse pin)."""
    lane = jnp.maximum(plan.pro_batch, 0)
    keep = (plan.pro_layer >= 0) & ~stale[lane]
    return _mask_plan_rows(plan, keep)


def slot_scores(values: jax.Array, owner: jax.Array) -> jax.Array:
    """Gather per-logical-page `values` [L, B, max_pages] to per-slot
    scores [L, B, P] through an owner map; free slots score -inf."""
    gathered = jnp.take_along_axis(values, jnp.maximum(owner, 0), axis=-1)
    return jnp.where(owner >= 0, gathered, jnp.float32(-jnp.inf))


def plan_migrations(cache: PagedKVCache, *, budget: int,
                    promote_thresh: float,
                    active: Optional[jax.Array] = None,
                    ) -> Tuple[MigrationPlan, jax.Array, jax.Array]:
    """Importance-EMA hysteresis planner, vectorized over [L, B].

    The `importance` device policy: `plan_by_score` over the
    attention-mass EMA — the hottest host-resident pages above
    `promote_thresh` displace the coldest HBM residents.
    """
    imp = cache.importance                                         # [L,B,P]
    host_imp = slot_scores(imp, cache.host_owner)
    hbm_imp = slot_scores(imp, cache.hbm_owner)
    return plan_by_score(cache, host_imp, hbm_imp, budget=budget,
                         promote_thresh=promote_thresh, active=active)


# --------------------------------------------------------------------------
# per-slot (batch-lane) ops for the continuous-batching serve loop.
# All are jit-safe [L, B]-vectorized: the fused step runs every lane and
# these gate which lanes' state survives, so admissions/completions never
# change traced shapes (zero retraces across the request stream).
# --------------------------------------------------------------------------

def lane_modes(active: jax.Array, prefilled: jax.Array,
               prompt_len: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-lane mode flags for a MIXED prefill+decode serve step.

    Returns (prefilling, decoding), disjoint bool [B]: a live lane
    prefills until its prompt is fully consumed, then decodes. The
    split is computed on device from the chunk carry, so a lane flips
    from prefill to decode mid-chunk without any host involvement —
    and it gates the whole control plane: the decode plane's write-slot
    choice / Quest masking / sampling apply to decoding lanes (the
    decode plane still RUNS every lane — `lane_merge` discards the
    others bitwise), while `plan_migrations(active=decoding)` keeps the
    migration planner off half-prefilled lanes so chunked prefill lands
    exactly the Static Placement that `prefill_cache` would (the
    bitwise-parity anchor). Half-filled prefill pages stay
    placement-visible throughout: `allocate_prompt_pages` registers
    them in the owner maps, so `occupancy` telemetry and the next
    step's write-slot choice count them as resident.
    """
    prefilling = active & (prefilled < prompt_len)
    return prefilling, active & ~prefilling

def _lane_bcast(active: jax.Array, ndim: int, axis: int) -> jax.Array:
    """Reshape a [B] lane mask to broadcast at `axis` of an ndim array."""
    shape = [1] * ndim
    shape[axis] = active.shape[0]
    return active.reshape(shape)


def lane_merge(old: PagedKVCache, new: PagedKVCache,
               active: jax.Array) -> PagedKVCache:
    """Keep `new` for active lanes, `old` for the rest (active bool [B]).

    With `active` all-True this is a bitwise identity on `new`, which is
    what makes a single-request `serve` reproduce `generate` exactly.
    """
    def m1(o, n):
        return jnp.where(_lane_bcast(active, n.ndim, 1), n, o)

    return PagedKVCache(
        k_hbm=m1(old.k_hbm, new.k_hbm), v_hbm=m1(old.v_hbm, new.v_hbm),
        k_host=m1(old.k_host, new.k_host),
        v_host=m1(old.v_host, new.v_host),
        page_table=m1(old.page_table, new.page_table),
        hbm_owner=m1(old.hbm_owner, new.hbm_owner),
        host_owner=m1(old.host_owner, new.host_owner),
        length=jnp.where(active, new.length, old.length),
        importance=m1(old.importance, new.importance))


def release_lanes(cache: PagedKVCache, lanes: jax.Array) -> PagedKVCache:
    """Reclaim completed lanes (bool [B]): every page they own returns to
    the free pool — owner maps and page table cleared, length zeroed,
    importance reset — so `choose_write_slot` and `plan_migrations` see
    the slots as free destinations immediately. Pool data is left in
    place (unreachable once unmapped)."""
    def clr(arr, fill):
        return jnp.where(_lane_bcast(lanes, arr.ndim, 1), fill, arr)

    return dataclasses.replace(
        cache,
        page_table=clr(cache.page_table, NO_SLOT),
        hbm_owner=clr(cache.hbm_owner, NO_SLOT),
        host_owner=clr(cache.host_owner, NO_SLOT),
        length=jnp.where(lanes, 0, cache.length),
        importance=clr(cache.importance, 0.0))


def insert_lane(cache: PagedKVCache, lane_cache: PagedKVCache,
                lane: jax.Array) -> PagedKVCache:
    """Bind a prefilled batch-1 cache to lane `lane` (int32 scalar) of
    the batched cache. One compile for all lanes: the lane index is
    data, not shape. No longer on the serve admission path (chunked
    prefill writes pages in place — PR 3); kept for the
    eager-admission baseline in benchmarks/perf_engine.py and as the
    building block for future recurrent/hybrid-state lane insertion."""
    B = cache.length.shape[0]
    onehot = jnp.arange(B) == lane

    def ins1(dst, src):
        return jnp.where(_lane_bcast(onehot, dst.ndim, 1), src, dst)

    return PagedKVCache(
        k_hbm=ins1(cache.k_hbm, lane_cache.k_hbm),
        v_hbm=ins1(cache.v_hbm, lane_cache.v_hbm),
        k_host=ins1(cache.k_host, lane_cache.k_host),
        v_host=ins1(cache.v_host, lane_cache.v_host),
        page_table=ins1(cache.page_table, lane_cache.page_table),
        hbm_owner=ins1(cache.hbm_owner, lane_cache.hbm_owner),
        host_owner=ins1(cache.host_owner, lane_cache.host_owner),
        length=jnp.where(onehot, lane_cache.length[0], cache.length),
        importance=ins1(cache.importance, lane_cache.importance))


def page_tiers(cache: PagedKVCache) -> jax.Array:
    """Read-time placement codes, int8 [L, B, max_pages]: 0 = HBM,
    1 = host DRAM, -1 = unallocated (`core.placement.base` tier codes).

    The batched telemetry channel of the trace bridge: sampled
    post-decode / pre-migration inside the fused step, this is the
    placement the step's attention reads actually hit — `generate`
    capture keeps lane 0, `serve` capture keeps every lane so the
    bridge can attribute per-request streams (see
    `repro.serving.trace_bridge`).
    """
    slot = cache.page_table                                 # [L, B, P]
    hbm_pages = cache.k_hbm.shape[2]
    return jnp.where(
        slot < 0, jnp.int8(-1),
        jnp.where(slot < hbm_pages, jnp.int8(0), jnp.int8(1)))


def occupancy(cache: PagedKVCache) -> jax.Array:
    """[2] int32: resident page counts (HBM, host) summed over [L, B] —
    the per-step read traffic in pages for Eq. (3)/(4) telemetry."""
    return jnp.stack([(cache.hbm_owner >= 0).sum(),
                      (cache.host_owner >= 0).sum()]).astype(jnp.int32)
