"""SLO plane: per-tier latency targets, admission shedding, goodput.

Production serving is scored on GOODPUT — requests completed within
their latency SLOs per second — not raw throughput: a stream that
saturates the decode plane while every interactive request blows its
TTFT target is worthless. This module gives the serve loop the three
pieces (EXPERIMENTS.md §Workloads):

  * `SLOTarget` / `SLOPolicy` — per-TIER TTFT/TPOT targets
    (`Request.tier` names the tier; the workload plane in
    `benchmarks/workloads.py` stamps tiers from its priority mix).
  * SLO-aware admission — `SLOPolicy.should_shed` projects a QUEUED
    request's earliest achievable TTFT (wait so far + estimated
    prefill time at the measured step cadence) and tells
    `ServingEngine.serve` to shed it as `rejected` (error code
    "slo_shed") when the projection already exceeds the target: a
    request that cannot meet its SLO should not drag decode TPOT for
    every live lane. Shedding applies to queued requests only, AFTER
    deadline/cancel reaping, so no request is ever counted both
    "timeout" and SLO-shed.
  * `score_goodput` — fraction of submitted requests that finished
    "ok" within (scaled) targets, from either the wall-clock stamps
    or the paper's MODELED per-request latency (Eq. (1)-(5) via
    `trace_bridge.score_serve`'s `request_scores`). The modeled view
    is the placement-sensitive one: on CPU hosts wall clocks cannot
    see what dynamic placement bought, the modeled TPOT can.

`serve(..., slo=policy)` layers this ON TOP of the `prefill_budget`
token bucket: the bucket shapes WHEN admitted prefill work runs, the
SLO policy decides WHETHER queued work is still worth admitting.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Optional

import numpy as np

from repro.serving.scheduler import Request

#: tier name used when a request's tier has no explicit target
DEFAULT_TIER = "default"


@dataclasses.dataclass(frozen=True)
class SLOTarget:
    """One tier's latency contract (seconds)."""

    ttft_s: float                      # time to first token
    tpot_s: float                      # time per output token after it

    def scaled(self, scale: float) -> "SLOTarget":
        """Both targets multiplied by `scale` (2.0 = twice as loose)."""
        return SLOTarget(self.ttft_s * scale, self.tpot_s * scale)


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """Per-tier SLO targets + the admission shedding rule.

    `targets` maps tier names to `SLOTarget`; a request whose tier is
    missing falls back to the `DEFAULT_TIER` entry, and to NO target
    (never shed, never scored) when that is absent too. `shed_slack`
    loosens the shed projection (2.0 = shed only when the projected
    TTFT is past twice the target) so estimation noise cannot shed
    borderline requests that would have made it.
    """

    targets: Mapping[str, SLOTarget] = dataclasses.field(
        default_factory=dict)
    shed_slack: float = 1.0

    def target_for(self, req: Request) -> Optional[SLOTarget]:
        """The request's tier target, falling back to `DEFAULT_TIER`
        and then to None (no contract)."""
        tier = req.tier if req.tier is not None else DEFAULT_TIER
        tgt = self.targets.get(tier)
        if tgt is None and tier != DEFAULT_TIER:
            tgt = self.targets.get(DEFAULT_TIER)
        return tgt

    def projected_ttft(self, req: Request, now: float,
                       est_step_s: Optional[float],
                       prefill_chunk: int) -> float:
        """Earliest achievable TTFT for a QUEUED request: the wait it
        has already eaten plus its prefill time at the measured serve
        cadence (unknown before the first chunk lands -> 0, so early
        boundaries shed only on wait already incurred)."""
        waited = now - req.submitted_at
        if est_step_s is None:
            return waited
        steps = math.ceil(req.prompt_len / max(1, prefill_chunk))
        return waited + steps * est_step_s

    def should_shed(self, req: Request, now: float,
                    est_step_s: Optional[float],
                    prefill_chunk: int) -> Optional[str]:
        """Return a human-readable reason to shed `req`, or None."""
        tgt = self.target_for(req)
        if tgt is None:
            return None
        proj = self.projected_ttft(req, now, est_step_s, prefill_chunk)
        bar = tgt.ttft_s * self.shed_slack
        if proj > bar:
            return (f"projected TTFT {proj:.4f}s exceeds "
                    f"{req.tier or DEFAULT_TIER} target "
                    f"{tgt.ttft_s:.4f}s (slack {self.shed_slack:g})")
        return None

    @staticmethod
    def uniform(ttft_s: float, tpot_s: float,
                shed_slack: float = 1.0) -> "SLOPolicy":
        """One target for every request, tiered or not."""
        return SLOPolicy({DEFAULT_TIER: SLOTarget(ttft_s, tpot_s)},
                         shed_slack=shed_slack)


def _wall_latencies(r: Request):
    """(ttft_s, tpot_s) from the request's wall-clock stamps; inf when
    a stamp is missing (never counts as within-SLO)."""
    if r.first_token_at is None:
        return float("inf"), float("inf")
    ttft = r.first_token_at - r.submitted_at
    if r.finished_at is None or len(r.output) <= 1:
        return ttft, 0.0
    return ttft, (r.finished_at - r.first_token_at) / (len(r.output) - 1)


def score_goodput(report, policy: SLOPolicy, *, scale: float = 1.0,
                  latency: str = "wall") -> Dict[str, object]:
    """Score a `ServeReport` against (scaled) SLO targets.

    A request is GOOD iff its terminal status is "ok" AND it met its
    tier's targets at `scale` (scale 2.0 = twice-as-loose SLOs —
    sweeping `scale` traces the goodput-under-SLO curve). Shed,
    rejected, failed, cancelled and timed-out requests all count
    against goodput: they were submitted and not served within SLO.

    latency="wall" judges both TTFT and TPOT from the wall stamps.
    latency="modeled" judges TPOT from the paper's per-request modeled
    seconds (`report.request_scores[rid]["live_total_s"] / steps`, the
    Eq. (1)-(5) price of the request's decode reads under the achieved
    placement — requires `trace_bridge.score_serve(..., report=...)`
    to have stamped the report) and leaves TTFT out of the verdict:
    prefill is not priced by the access model. The modeled view is how
    placement policies are compared at equal targets.

    Returns the goodput row (also stamped onto `report.goodput` when
    the attribute exists): request/token goodput fractions, good
    counts, and the per-tier split.
    """
    assert latency in ("wall", "modeled"), latency
    statuses = report.statuses
    total = len(statuses)
    good = 0
    good_tokens = 0
    per_tier: Dict[str, Dict[str, int]] = {}
    for r in report.completed:
        tier = r.tier if r.tier is not None else DEFAULT_TIER
        row = per_tier.setdefault(tier, {"good": 0, "total": 0})
        row["total"] += 1
        if r.status != "ok":
            continue
        tgt = policy.target_for(r)
        if tgt is None:
            met = True                 # no contract -> "ok" suffices
        else:
            tgt = tgt.scaled(scale)
            ttft, tpot = _wall_latencies(r)
            if latency == "modeled":
                sc = report.request_scores.get(r.rid)
                if sc is None or not sc.get("steps"):
                    met = False                 # unscored: never good
                else:
                    tpot = sc["live_total_s"] / sc["steps"]
                    met = tpot <= tgt.tpot_s
            else:
                met = ttft <= tgt.ttft_s and tpot <= tgt.tpot_s
        if met:
            good += 1
            good_tokens += len(r.output)
            row["good"] += 1
    for r in report.rejected:
        tier = r.tier if r.tier is not None else DEFAULT_TIER
        per_tier.setdefault(tier, {"good": 0, "total": 0})["total"] += 1
    out = {
        "scale": float(scale),
        "latency": latency,
        "goodput": good / total if total else 1.0,
        "good_requests": int(good),
        "total_requests": int(total),
        "good_tokens": int(good_tokens),
        "shed_requests": int(sum(
            1 for r in report.rejected
            if r.error is not None and r.error.code == "slo_shed")),
        "per_tier": {t: {"good": int(v["good"]),
                         "total": int(v["total"]),
                         "goodput": v["good"] / v["total"]
                         if v["total"] else 1.0}
                     for t, v in sorted(per_tier.items())},
    }
    if hasattr(report, "goodput"):
        report.goodput = dict(out)
    return out


def ttft_decomposition_residual(report) -> np.ndarray:
    """Per-request |queue_wait + prefill_s + throttle_s - TTFT| for
    every completed request with a first token — the regression
    surface for the attribution contract (exact up to float rounding
    of the chunk-stride stamps; see EXPERIMENTS.md §Workloads)."""
    res = []
    for r in report.completed:
        if r.first_token_at is None or r.admitted_at is None:
            continue
        ttft = r.first_token_at - r.submitted_at
        parts = r.queue_wait_s + r.prefill_s + r.throttle_s
        res.append(abs(parts - ttft))
    return np.asarray(res, np.float64)
