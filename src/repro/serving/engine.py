"""Serving engine: the paper's dynamic KV placement as a live feature.

Per decode step:
  1. (data plane, jit) `decode_step` over the two-tier paged cache with
     optional Quest-style page bypassing; emits per-page attention-mass
     importance stats for free (fused in the attention kernel).
  2. (control plane, host) the placement policy turns importance stats
     into a bounded `MigrationPlan` (promote hot host pages / demote
     cold HBM pages) — no foresight, exactly the runtime-policy regime
     the paper's SA bound upper-bounds.
  3. (data plane, jit) `apply_migrations` swaps pages between pools.
  4. telemetry: every byte the step moved is priced with the paper's
     Eq.(1)-(5) under a `MemorySystemSpec`, so real runs and the
     simulator are directly comparable (EXPERIMENTS.md §Repro-live).

Engine policies: "static" (never migrate), "importance" (cost-aware
hysteresis on the attention-mass EMA — our deployable beyond-paper
policy), "lru" (promote-most-recent analog using recency of mass).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.latency_model import StepTraffic, step_latency
from repro.core.tiers import MemorySystemSpec, TPU_V5E
from repro.kvcache.migrate import MigrationPlan, apply_migrations
from repro.kvcache.paged import CacheGeometry, PagedKVCache
from repro.models.model import Model, default_write_slot


@dataclasses.dataclass
class EngineConfig:
    max_context: int = 512
    hbm_fraction: float = 0.25
    policy: str = "importance"
    #: fraction of pages bypassed at attention (0 = dense attention)
    attention_sparsity: float = 0.0
    #: migration budget per step, as a fraction of HBM pages
    migration_budget_frac: float = 0.1
    promote_thresh: float = 0.02     # attention-mass EMA threshold
    spec: MemorySystemSpec = TPU_V5E


@dataclasses.dataclass
class StepStats:
    modeled_latency_s: float
    h_read: float
    e_read: float
    m_in: float
    m_out: float
    hbm_hit_rate: float


class ServingEngine:
    def __init__(self, model: Model, params, cfg: EngineConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.stats: List[StepStats] = []

    # ------------------------------------------------------------------ #
    def start(self, prompts: jax.Array, extra=None):
        geo = self.model.cache_geometry(
            prompts.shape[0], self.cfg.max_context,
            hbm_fraction=self.cfg.hbm_fraction)
        self.geo = geo
        logits, state = self.model.prefill(self.params, prompts, geo,
                                           extra=extra)
        self.state = state
        return logits

    @property
    def _cache(self) -> PagedKVCache:
        st = self.state
        return st if isinstance(st, PagedKVCache) else st["kv"]

    def _set_cache(self, cache):
        if isinstance(self.state, PagedKVCache):
            self.state = cache
        else:
            self.state = {**self.state, "kv": cache}

    # ------------------------------------------------------------------ #
    def step(self, token: jax.Array) -> jax.Array:
        cache = self._cache
        write_slot, mask = self._control_plane(cache)
        kwargs = {}
        if mask is not None and self.model.cfg.family in ("dense", "vlm"):
            from repro.models import transformer as tfm
            logits, cache_new = tfm.dense_decode_step(
                self.params, self.model.cfg, cache, token, write_slot,
                logical_page_mask=jnp.asarray(mask))
            self._set_cache(cache_new)
        else:
            logits, state = self.model.decode_step(
                self.params, self.state, token, write_slot=write_slot)
            self.state = state
            cache_new = self._cache

        plan, traffic = self._plan_migrations(cache_new)
        if plan is not None:
            self._set_cache(apply_migrations(self._cache, plan))
        self._record(traffic, mask)
        return logits

    # ------------------------------------------------------------------ #
    # control plane
    # ------------------------------------------------------------------ #
    def _control_plane(self, cache: PagedKVCache):
        """Choose the write slot for this token + the attention mask."""
        geo = self.geo
        length = int(np.asarray(cache.length)[0])
        T = geo.page_tokens
        logical = min(length // T, geo.max_pages - 1)
        pt = np.asarray(cache.page_table)          # [L,B,maxP]
        L, B = pt.shape[0], pt.shape[1]

        # write slot: existing mapping, else first free HBM slot, else
        # first free host slot (policy "static" semantics for new pages)
        ho = np.asarray(cache.hbm_owner)
        eo = np.asarray(cache.host_owner)
        ws = np.zeros((L, B), np.int32)
        for l in range(L):
            for b in range(B):
                if pt[l, b, logical] >= 0:
                    ws[l, b] = pt[l, b, logical]
                else:
                    free_h = np.nonzero(ho[l, b] < 0)[0]
                    if len(free_h):
                        ws[l, b] = free_h[0]
                    else:
                        free_e = np.nonzero(eo[l, b] < 0)[0]
                        ws[l, b] = geo.hbm_pages + (free_e[0] if len(free_e)
                                                    else geo.host_pages - 1)

        mask = None
        sp = self.cfg.attention_sparsity
        if sp > 0:
            imp = np.asarray(cache.importance)     # [L,B,maxP]
            alive = pt >= 0
            mask = np.zeros_like(alive)
            n_alive = alive.sum(-1)                # [L,B]
            for l in range(L):
                for b in range(B):
                    k = max(1, int(round((1 - sp) * n_alive[l, b])))
                    cand = np.nonzero(alive[l, b])[0]
                    top = cand[np.argsort(-imp[l, b, cand], kind="stable")][:k]
                    mask[l, b, top] = True
                    mask[l, b, cand[:1]] = True          # sink page
                    mask[l, b, cand[-2:]] = True         # recency pages
        return jnp.asarray(ws), mask

    def _plan_migrations(self, cache: PagedKVCache):
        if self.cfg.policy == "static":
            return None, self._traffic(cache, 0, 0)
        imp = np.asarray(cache.importance)
        ho = np.asarray(cache.hbm_owner)
        eo = np.asarray(cache.host_owner)
        L, B = ho.shape[0], ho.shape[1]
        budget = max(1, int(self.cfg.migration_budget_frac
                            * self.geo.hbm_pages))
        promotes, demotes = [], []
        for l in range(L):
            for b in range(B):
                host_pages = np.nonzero(eo[l, b] >= 0)[0]
                if not len(host_pages):
                    continue
                host_logical = eo[l, b, host_pages]
                host_imp = imp[l, b, host_logical]
                order = np.argsort(-host_imp, kind="stable")
                hot = [(host_pages[i], host_logical[i], host_imp[i])
                       for i in order[:budget]
                       if host_imp[i] > self.cfg.promote_thresh]
                if not hot:
                    continue
                hbm_pages = np.nonzero(ho[l, b] >= 0)[0]
                hbm_logical = ho[l, b, hbm_pages]
                hbm_imp = imp[l, b, hbm_logical]
                cold_order = np.argsort(hbm_imp, kind="stable")
                free = np.nonzero(ho[l, b] < 0)[0].tolist()
                ci = 0
                for src, logical, h_imp in hot:
                    if free:
                        dst = free.pop(0)
                    elif ci < len(cold_order):
                        # swap: demote the coldest resident first
                        victim = cold_order[ci]
                        if hbm_imp[victim] >= h_imp:
                            break   # nothing colder than the candidate
                        vslot = hbm_pages[victim]
                        # host slot freed by this promotion
                        demotes.append((l, b, vslot, src,
                                        hbm_logical[victim]))
                        dst = vslot
                        ci += 1
                    else:
                        break
                    promotes.append((l, b, src, dst, logical))
        if not promotes and not demotes:
            return None, self._traffic(cache, 0, 0)
        cap = max(len(promotes), len(demotes), 1)
        plan = MigrationPlan.build(cap, promotes, demotes)
        return plan, self._traffic(cache, len(promotes), len(demotes))

    # ------------------------------------------------------------------ #
    def _traffic(self, cache, n_pro, n_dem):
        geo = self.geo
        pb = geo.page_bytes()
        ho = np.asarray(cache.hbm_owner) >= 0
        eo = np.asarray(cache.host_owner) >= 0
        # dense attention reads every resident page; sparse reads are
        # rescaled by (1 - sparsity)
        frac = 1.0 - self.cfg.attention_sparsity
        h_read = float(ho.sum()) * pb * frac
        e_read = float(eo.sum()) * pb * frac
        return dict(h_read=h_read, e_read=e_read,
                    m_in=n_pro * pb, m_out=n_dem * pb,
                    h_write=pb / geo.page_tokens, e_write=0.0)

    def _record(self, traffic, mask):
        t = StepTraffic(**traffic)
        lat = float(step_latency(t, self.cfg.spec))
        denom = traffic["h_read"] + traffic["e_read"]
        self.stats.append(StepStats(
            modeled_latency_s=lat,
            h_read=traffic["h_read"], e_read=traffic["e_read"],
            m_in=traffic["m_in"], m_out=traffic["m_out"],
            hbm_hit_rate=traffic["h_read"] / denom if denom else 1.0))

    # ------------------------------------------------------------------ #
    def summary(self) -> Dict[str, float]:
        if not self.stats:
            return {}
        lat = np.array([s.modeled_latency_s for s in self.stats])
        return {
            "steps": len(self.stats),
            "modeled_total_s": float(lat.sum()),
            "modeled_tokens_per_s": len(lat) / float(lat.sum()),
            "mean_hbm_hit_rate": float(np.mean(
                [s.hbm_hit_rate for s in self.stats])),
            "migrated_bytes": float(sum(s.m_in + s.m_out
                                        for s in self.stats)),
        }
