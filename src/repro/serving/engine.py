"""Serving engine: the paper's dynamic KV placement as a live feature.

The entire decode step runs as ONE jitted, statically-shaped program on
device (see `repro.serving.control` and EXPERIMENTS.md §Fused-engine):

  1. control plane (jit): write-slot selection, Quest-style top-k page
     masking, and the importance-EMA migration planner, vectorized over
     [L, B] — no Python loops, no host round-trips.
  2. data plane (jit): `decode_step` over the two-tier paged cache;
     per-page attention-mass importance stats fall out of the attention
     kernel for free.
  3. data plane (jit): `apply_migrations` executes a FIXED-capacity
     `MigrationPlan` (capacity depends only on geometry and
     `migration_budget_frac`), so it compiles exactly once.
  4. telemetry: the step emits a tiny [4] int32 vector (resident HBM /
     host pages, promotes, demotes); the host prices it with the
     paper's Eq.(1)-(5) under a `MemorySystemSpec`.

Two drive modes share the identical step function, so their logits are
bitwise identical and their byte accounting matches exactly:

  eager  `step(token)`         — one jitted call + host readback per
                                 token (the debugging / reference path)
  fused  `run(tokens)` /       — `lax.scan` over chunks of
         `generate(token, n)`    `telemetry_stride` steps with the
                                 cache donated; the host reads back one
                                 [stride, 4] stats array per chunk.

Engine policies: "static" (never migrate) and "importance" (cost-aware
hysteresis on the attention-mass EMA — our deployable beyond-paper
policy).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.latency_model import StepTraffic, step_latency
from repro.core.tiers import MemorySystemSpec, TPU_V5E
from repro.kvcache.migrate import apply_migrations
from repro.kvcache.paged import PagedKVCache
from repro.models.model import Model
from repro.serving import control


@dataclasses.dataclass
class EngineConfig:
    max_context: int = 512
    hbm_fraction: float = 0.25
    policy: str = "importance"
    #: fraction of pages bypassed at attention (0 = dense attention)
    attention_sparsity: float = 0.0
    #: migration budget per step, as a fraction of HBM pages
    migration_budget_frac: float = 0.1
    promote_thresh: float = 0.02     # attention-mass EMA threshold
    spec: MemorySystemSpec = TPU_V5E
    #: fused-mode scan length: decode steps run on device between
    #: telemetry readbacks (1 = eager cadence, larger = fewer syncs)
    telemetry_stride: int = 32


@dataclasses.dataclass
class StepStats:
    modeled_latency_s: float
    h_read: float
    e_read: float
    m_in: float
    m_out: float
    hbm_hit_rate: float


def _get_cache(state) -> PagedKVCache:
    return state if isinstance(state, PagedKVCache) else state["kv"]


def _set_cache(state, cache):
    if isinstance(state, PagedKVCache):
        return cache
    return {**state, "kv": cache}


class ServingEngine:
    def __init__(self, model: Model, params, cfg: EngineConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.stats: List[StepStats] = []

    # ------------------------------------------------------------------ #
    def start(self, prompts: jax.Array, extra=None):
        geo = self.model.cache_geometry(
            prompts.shape[0], self.cfg.max_context,
            hbm_fraction=self.cfg.hbm_fraction)
        self.geo = geo
        logits, state = self.model.prefill(self.params, prompts, geo,
                                           extra=extra)
        self.state = state
        self._build_step_fns()
        return logits

    @property
    def _cache(self) -> PagedKVCache:
        return _get_cache(self.state)

    # ------------------------------------------------------------------ #
    # the fused step: control plane + data plane + migration, all jit
    # ------------------------------------------------------------------ #
    def _build_step_fns(self):
        cfg, model, geo = self.cfg, self.model, self.geo
        sparsity = cfg.attention_sparsity
        masked = sparsity > 0 and model.cfg.family in ("dense", "vlm")
        migrate = cfg.policy != "static"
        budget = control.migration_budget(geo, cfg.migration_budget_frac)
        thresh = cfg.promote_thresh

        def step_fn(params, state, token):
            cache = _get_cache(state)
            kwargs = {"write_slot": control.choose_write_slot(cache)}
            if masked:
                kwargs["logical_page_mask"] = control.quest_page_mask(
                    cache, sparsity)
            logits, state = model.decode_step(params, state, token,
                                              **kwargs)
            cache = _get_cache(state)
            # read traffic is counted on post-decode, pre-migration
            # residency (the step's attention read the old placement)
            occ = control.occupancy(cache)
            if migrate:
                plan, n_pro, n_dem = control.plan_migrations(
                    cache, budget=budget, promote_thresh=thresh)
                state = _set_cache(state, apply_migrations(cache, plan))
                moves = jnp.stack([n_pro, n_dem]).astype(jnp.int32)
            else:
                moves = jnp.zeros((2,), jnp.int32)
            return logits, state, jnp.concatenate([occ, moves])

        def chunk_fn(params, state, tokens):
            """Teacher-forced fused decode over tokens [n, B]."""
            def body(st, tok):
                logits, st, stats = step_fn(params, st, tok)
                return st, (logits, stats)
            state, (logits, stats) = jax.lax.scan(body, state, tokens)
            return state, logits, stats

        def gen_fn(params, state, token, n):
            """Greedy self-feeding fused decode for n steps."""
            def body(carry, _):
                st, tok = carry
                logits, st, stats = step_fn(params, st, tok)
                nxt = jnp.argmax(logits, -1).astype(jnp.int32)
                return (st, nxt), (nxt, stats)
            (state, token), (toks, stats) = jax.lax.scan(
                body, (state, token), None, length=n)
            return state, token, toks, stats

        self._step_jit = jax.jit(step_fn, donate_argnums=(1,))
        self._chunk_jit = jax.jit(chunk_fn, donate_argnums=(1,))
        self._gen_jit = jax.jit(gen_fn, donate_argnums=(1,),
                                static_argnums=(3,))

    # ------------------------------------------------------------------ #
    # drive modes
    # ------------------------------------------------------------------ #
    def step(self, token: jax.Array) -> jax.Array:
        """Eager: one device dispatch + one telemetry sync per token."""
        logits, self.state, stats = self._step_jit(
            self.params, self.state, token)
        self._record(np.asarray(stats)[None])
        return logits

    def run(self, tokens: jax.Array) -> jax.Array:
        """Fused teacher-forced decode. tokens [K, B] -> logits [K, B, V].

        Runs `lax.scan` chunks of `telemetry_stride` steps; telemetry is
        read back once per chunk. Produces bitwise-identical logits and
        identical StepStats accounting to K calls of `step()`.
        """
        K = tokens.shape[0]
        if K == 0:
            return jnp.zeros((0, tokens.shape[1], self.model.cfg.vocab))
        stride = max(1, self.cfg.telemetry_stride)
        out = []
        for s in range(0, K, stride):
            self.state, logits, stats = self._chunk_jit(
                self.params, self.state, tokens[s:s + stride])
            self._record(np.asarray(stats))
            out.append(logits)
        return out[0] if len(out) == 1 else jnp.concatenate(out, axis=0)

    def generate(self, token: jax.Array, steps: int) -> jax.Array:
        """Fused greedy generation from `token` [B] -> tokens [steps, B]."""
        if steps == 0:
            return jnp.zeros((0,) + token.shape, jnp.int32)
        stride = max(1, self.cfg.telemetry_stride)
        out = []
        done = 0
        while done < steps:
            n = min(stride, steps - done)
            self.state, token, toks, stats = self._gen_jit(
                self.params, self.state, token, n)
            self._record(np.asarray(stats))
            out.append(toks)
            done += n
        return out[0] if len(out) == 1 else jnp.concatenate(out, axis=0)

    # ------------------------------------------------------------------ #
    # telemetry (host side, Eq. (1)-(5) pricing)
    # ------------------------------------------------------------------ #
    def _record(self, stats: np.ndarray):
        """stats: [n, 4] int32 rows of (hbm_pages, host_pages, promotes,
        demotes) straight off the device."""
        geo = self.geo
        pb = geo.page_bytes()
        frac = 1.0 - self.cfg.attention_sparsity
        for h_pages, e_pages, n_pro, n_dem in stats:
            traffic = dict(
                h_read=float(h_pages) * pb * frac,
                e_read=float(e_pages) * pb * frac,
                m_in=float(n_pro) * pb, m_out=float(n_dem) * pb,
                h_write=pb / geo.page_tokens, e_write=0.0)
            lat = float(step_latency(StepTraffic(**traffic), self.cfg.spec))
            denom = traffic["h_read"] + traffic["e_read"]
            self.stats.append(StepStats(
                modeled_latency_s=lat,
                h_read=traffic["h_read"], e_read=traffic["e_read"],
                m_in=traffic["m_in"], m_out=traffic["m_out"],
                hbm_hit_rate=traffic["h_read"] / denom if denom else 1.0))

    def summary(self) -> Dict[str, float]:
        if not self.stats:
            return {}
        lat = np.array([s.modeled_latency_s for s in self.stats])
        return {
            "steps": len(self.stats),
            "modeled_total_s": float(lat.sum()),
            "modeled_tokens_per_s": len(lat) / float(lat.sum()),
            "mean_hbm_hit_rate": float(np.mean(
                [s.hbm_hit_rate for s in self.stats])),
            "migrated_bytes": float(sum(s.m_in + s.m_out
                                        for s in self.stats)),
        }
