"""Serving engine: the paper's dynamic KV placement as a live feature.

The entire decode step runs as ONE jitted, statically-shaped program on
device (see `repro.serving.control` and EXPERIMENTS.md §Fused-engine):

  1. control plane (jit): write-slot selection, Quest-style top-k page
     masking, and the importance-EMA migration planner, vectorized over
     [L, B] — no Python loops, no host round-trips.
  2. data plane (jit): `decode_step` over the two-tier paged cache;
     per-page attention-mass importance stats fall out of the attention
     kernel for free.
  3. data plane (jit): `apply_migrations` executes a FIXED-capacity
     `MigrationPlan` (capacity depends only on geometry and
     `migration_budget_frac`), so it compiles exactly once.
  4. telemetry: the step emits a tiny [4] int32 vector (resident HBM /
     host pages, promotes, demotes); the host prices it with the
     paper's Eq.(1)-(5) under a `MemorySystemSpec`.

Drive modes share the identical step function, so their logits are
bitwise identical and their byte accounting matches exactly:

  eager  `step(token)`         — one jitted call + host readback per
                                 token (the debugging / reference path)
  fused  `run(tokens)` /       — `lax.scan` over chunks of
         `generate(token, n)`    `telemetry_stride` steps with the
                                 cache donated; the host reads back one
                                 [stride, 4] stats array per chunk.
  serve  `serve(requests)`     — the headline API: continuous batching
                                 over the same fused chunks, where each
                                 step is a MIXED prefill+decode step:
                                 decoding lanes emit one sampled token
                                 (temperature/top-k/top-p, greedy at
                                 temperature 0) while prefilling lanes
                                 consume a `prefill_chunk`-token slice
                                 of their prompt, writing pages
                                 directly into their lane of the
                                 shared cache at an offset. The first
                                 output token is sampled ON DEVICE at
                                 the step prefill crosses prompt_len
                                 (TTFT is a device event); admission,
                                 completion and page reclaim happen at
                                 chunk boundaries without retracing —
                                 ONE executable for the whole stream,
                                 whatever the prompt-length mix.
                                 Returns a `ServeReport` (completed
                                 requests + TTFT/TPOT percentiles).

Engine policies are a pluggable PLANE (`repro.serving.policies`): every
registered `DevicePolicy` — static, importance, recency, cost_aware,
quest — plans through the same fixed-capacity `control.plan_by_score`
core and threads its own (statically shaped) state through the scan,
so each policy runs the full serve stream on ONE compiled executable.
`EngineConfig.trace_telemetry` additionally captures per-step page
accesses + placements — lane 0 for the single-stream modes, every lane
(plus lane->request bindings) for `serve` — which
`repro.serving.trace_bridge` converts into simulator traces (stitched
per request for serve streams) and scores against the paper's SA upper
bound.

`EngineConfig.overlap_migrations` pipelines the migration plane inside
the serve scan: step N commits the (revalidated, fault-throttled) plan
staged at step N-1 concurrently with decode compute, and plans for
step N+1 off this step's read set — a double-buffered plan/commit
split with one-step-ahead KV prefetch (EXPERIMENTS.md
§Async-migration). Decode semantics are placement-invariant, so the
pipeline changes WHEN pages move, never what attention computes;
`EngineConfig.measured_payback` additionally recalibrates cost_aware's
payback bars from a measured migration microbenchmark.

Scaling out: `ServingEngine(model, params, cfg, mesh=...)` runs the
identical serve loop across a jax device mesh — cache pools, migration
plans, policy state, and the fault channel become mesh-sharded pytrees
under the sharding rules in `repro.launch.shardings`, with one
executable and zero retraces per (policy, mesh). See the `serve`
docstring and EXPERIMENTS.md §Mesh-sharding.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.latency_model import StepTraffic, step_latency
from repro.core.tiers import MemorySystemSpec, TPU_V5E
from repro.kvcache.migrate import MigrationPlan, apply_migrations
from repro.kvcache.paged import (
    PagedKVCache, abstract_cache, host_memory_kind, init_cache,
)
from repro.models.model import Model
from repro.serving import control
from repro.serving.faults import FaultPlane, NO_FAULT_CAP, throttle_plan
from repro.serving.policies import make_policy, policy_names
from repro.serving.sampling import (
    SamplingConfig, lane_key, make_sampler, split_lanes,
)
from repro.serving.scheduler import (
    ContinuousBatcher, Request, RequestError,
)
from repro.serving.slo import SLOPolicy


@dataclasses.dataclass
class EngineConfig:
    """Static engine configuration, baked into the jitted step
    functions at build time (changing any field recompiles once; no
    field may change mid-stream). Selects the cache geometry split
    (`max_context`, `hbm_fraction`), the placement policy and its
    knobs, attention sparsity, the fused-scan stride, chunked-prefill
    budgets, EOS, and trace capture."""

    max_context: int = 512
    hbm_fraction: float = 0.25
    policy: str = "importance"
    #: fraction of pages bypassed at attention (0 = dense attention)
    attention_sparsity: float = 0.0
    #: migration budget per step, as a fraction of HBM pages
    migration_budget_frac: float = 0.1
    promote_thresh: float = 0.02     # attention-mass EMA threshold
    spec: MemorySystemSpec = TPU_V5E
    #: fused-mode scan length: decode steps run on device between
    #: telemetry readbacks (1 = eager cadence, larger = fewer syncs)
    telemetry_stride: int = 32
    #: chunked-prefill token budget: prompt tokens each PREFILLING lane
    #: consumes per mixed serve step. A static shape — lane index and
    #: prompt offset are data — so one serve-chunk executable covers
    #: every prompt length; chunking is bitwise-invisible (any budget
    #: reproduces the whole-prompt prefill exactly).
    prefill_chunk: int = 32
    #: per-BATCH prefill token budget for mixed serve steps (None =
    #: uncapped). A token bucket refilled `prefill_budget` tokens per
    #: step: the prefill plane runs only when the accrued budget covers
    #: the step's total prompt-slice demand across lanes, so a heavy
    #: prefill wave dilutes over steps instead of taxing every decode
    #: step — decode TPOT under the wave improves, TTFT of the wave
    #: stretches. GREEDY streams are token-for-token unchanged
    #: (schedule only); sampled streams (temperature > 0) stay
    #: per-request reproducible but draw from a shifted point of the
    #: lane's key chain, since each lane's PRNG advances every step
    #: and the budget moves the prefill-to-decode crossing. Per-lane
    #: `prefill_chunk` still bounds each slice.
    prefill_budget: Optional[int] = None
    #: stop token for `serve` (None = budget-only completion)
    eos_id: Optional[int] = None
    #: capture per-step (page access, read-time placement) telemetry
    #: for the simulator bridge (`repro.serving.trace_bridge`).
    #: step/run/generate keep batch lane 0 (`trace_bridge.collect`);
    #: `serve` keeps EVERY lane plus its chunk's lane->request bindings
    #: so the bridge can stitch per-REQUEST traces across admission/
    #: reclaim boundaries (`trace_bridge.collect_serve`/`attribute`).
    #: Pure observation: tokens, StepStats, and executable counts are
    #: identical with capture on or off.
    trace_telemetry: bool = False
    #: policy fallback: after this many CONSECUTIVE chunk boundaries
    #: whose migration commits were fully dropped (a MigrationFault
    #: window forcing cap 0 at some step), `serve` degrades the policy
    #: to static behavior by uploading all-zero commit caps — same
    #: executable, migrations masked as data — and stamps a
    #: "policy_fallback" event. The fallback is sticky for the stream.
    fallback_commit_faults: int = 3
    #: policy fallback: degrade to static when a tier fault pushes the
    #: effective HBM:DRAM bandwidth ratio past this MULTIPLE of the
    #: base spec's ratio (relative, so GH200 ~9.8x and TPU v5e ~25.6x
    #: base ratios share one knob) — with the host tier that slow,
    #: migrating pages toward it can no longer pay back.
    fallback_tier_ratio: float = 8.0
    #: async-migration pipeline (EXPERIMENTS.md §Async-migration): the
    #: serve scan carries a STAGED MigrationPlan — step N commits the
    #: plan staged at step N-1 (revalidated against the commit-time
    #: owner maps, then throttled by the fault channel) concurrently
    #: with its decode compute, and plans for step N+1 off this step's
    #: read set (the one-step-ahead re-reference oracle; every policy
    #: grows `plan_ahead` eviction protection, active under sparse
    #: attention). False keeps the serial plan-then-commit step — the
    #: bitwise inline baseline. Decode semantics are
    #: placement-invariant (attention reads pages wherever they live),
    #: so the pipeline shifts placement timing — hit fractions,
    #: modeled latency, and at most the floating-point association of
    #: the per-tier LSE merge when interim placements differ.
    #: Serve-path only: step/run/generate always run inline.
    overlap_migrations: bool = False
    #: calibrate cost_aware's payback thresholds from MEASURED per-page
    #: migration latency instead of the modeled spec: a one-shot
    #: microbenchmark at serve start times the jitted commit
    #: (full-capacity plan vs empty plan) and inverts Eq. (3)'s move
    #: cost into an effective link bandwidth. Telemetry PRICING stays
    #: on `spec` (the model is the model); only the policy's
    #: promote/demote bars move, and tier-fault degradations compose
    #: onto the measured spec for recalibration. Stamps a
    #: "payback_measured" event; falls back to the modeled spec when
    #: the measurement can't resolve the link term.
    measured_payback: bool = False


@dataclasses.dataclass
class StepStats:
    """One decode step's modeled cost under the paper's Eq. (1)-(5):
    the latency and the byte volumes (HBM / host reads, migrations in /
    out) the engine's device telemetry priced for that step, plus the
    step's HBM hit rate (fraction of read bytes served from HBM)."""

    modeled_latency_s: float
    h_read: float
    e_read: float
    m_in: float
    m_out: float
    hbm_hit_rate: float


@dataclasses.dataclass
class ServeReport:
    """`serve()`'s return value: the completed requests plus
    request-level latency percentiles (seconds) — TTFT measured from
    `submitted_at` to the boundary where the on-device first token is
    read back, TPOT as decode seconds per token after the first.
    Sequence-like over `completed`, so `for r in report` / `report[0]`
    / `len(report)` keep working at PR 2 call sites.

    `completed` holds every request that occupied a lane — terminal
    status "ok", or "failed"/"cancelled"/"timeout" when the engine
    quarantined or reaped it mid-flight; `rejected` holds requests
    refused before admission (invalid, infeasible, duplicate rid, or
    reaped while still queued), each with a typed `Request.error`.
    `statuses` maps every submitted rid to its terminal status — the
    stream NEVER raises on a per-request condition, so the mapping is
    exhaustive. `events` is the chronological degradation log (injected
    faults activating, pool resizes, policy fallback) a faulted stream
    accumulated — see `repro.serving.faults`.

    When the stream ran with `EngineConfig.trace_telemetry` and the
    bridge scored it (`trace_bridge.score_serve(..., report=...)`),
    `request_scores` maps each request id to its attributed placement
    scores (`hit_fraction`, `bound_fraction`, ...) and `headroom`
    carries the aggregate stream's live-vs-SA-bound summary. Both stay
    empty otherwise — scoring replays the SA oracle and is a
    deliberate post-pass, not part of the serve hot loop."""

    completed: List[Request]
    ttft: Dict[str, float] = dataclasses.field(default_factory=dict)
    tpot: Dict[str, float] = dataclasses.field(default_factory=dict)
    #: TTFT decomposition percentiles: queue_wait / prefill / throttle
    #: (per request the three sum to TTFT — queue_wait is submit ->
    #: first chunk, prefill the seconds of steps that consumed prompt
    #: tokens, throttle the budget-starved + boundary-overhead rest)
    ttft_parts: Dict[str, Dict[str, float]] = \
        dataclasses.field(default_factory=dict)
    #: EOS accounting: {"eos_id", "eos_stops", "budget_stops"} — how
    #: many "ok" requests stopped on the configured EOS id vs ran out
    #: their token budget (`Request.stop_reason` per request)
    eos: Dict[str, object] = dataclasses.field(default_factory=dict)
    #: goodput-under-SLO row (stamped by `slo.score_goodput`; empty
    #: when the stream was not scored against an SLOPolicy)
    goodput: Dict[str, object] = dataclasses.field(default_factory=dict)
    #: requests refused before admission (typed `Request.error` each)
    rejected: List[Request] = dataclasses.field(default_factory=list)
    #: chronological degradation events (fault activations, pool
    #: resizes, payback recalibrations, policy fallback)
    events: List[dict] = dataclasses.field(default_factory=list)
    #: rid -> per-request attribution scores (trace_bridge.score_serve)
    request_scores: Dict[int, Dict[str, float]] = \
        dataclasses.field(default_factory=dict)
    #: aggregate stream headroom (live vs SA/Belady/static totals)
    headroom: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def statuses(self) -> Dict[int, str]:
        """rid -> terminal status, exhaustive over every request that
        entered `serve` (completed and rejected alike)."""
        return {r.rid: r.status for r in self.completed + self.rejected}

    @staticmethod
    def build(completed: List[Request],
              rejected: Optional[List[Request]] = None,
              events: Optional[List[dict]] = None,
              eos_id: Optional[int] = None) -> "ServeReport":
        """Assemble a report from terminal requests: TTFT/TPOT
        mean/p50/p95 from the completed requests' wall-clock stamps,
        the TTFT decomposition percentiles, and EOS-stop counts."""
        def pct(vals):
            if not vals:
                return {}
            v = np.asarray(vals, np.float64)
            return {"mean": float(v.mean()),
                    "p50": float(np.percentile(v, 50)),
                    "p95": float(np.percentile(v, 95))}

        ttfts = [r.first_token_at - r.submitted_at for r in completed
                 if r.first_token_at is not None]
        tpots = [(r.finished_at - r.first_token_at)
                 / (len(r.output) - 1)
                 for r in completed
                 if r.first_token_at is not None
                 and r.finished_at is not None and len(r.output) > 1]
        # decomposition percentiles over requests the chunked loop
        # attributed (the eager-admission baseline stamps first tokens
        # at admission, before any chunk runs — no decomposition there)
        attributed = [r for r in completed
                      if r.first_token_at is not None
                      and r.admitted_at is not None]
        parts = {
            "queue_wait": pct([r.queue_wait_s for r in attributed]),
            "prefill": pct([r.prefill_s for r in attributed]),
            "throttle": pct([r.throttle_s for r in attributed]),
        }
        eos = {
            "eos_id": eos_id,
            "eos_stops": sum(1 for r in completed
                             if r.stop_reason == "eos"),
            "budget_stops": sum(1 for r in completed
                                if r.stop_reason == "budget"),
        }
        return ServeReport(completed=list(completed), ttft=pct(ttfts),
                           tpot=pct(tpots), ttft_parts=parts, eos=eos,
                           rejected=list(rejected or []),
                           events=list(events or []))

    def __iter__(self):
        return iter(self.completed)

    def __len__(self) -> int:
        return len(self.completed)

    def __getitem__(self, i):
        return self.completed[i]


def _get_cache(state) -> PagedKVCache:
    return state if isinstance(state, PagedKVCache) else state["kv"]


def _set_cache(state, cache):
    if isinstance(state, PagedKVCache):
        return cache
    return {**state, "kv": cache}


class ServingEngine:
    """The live serving engine over the two-tier paged KV cache.

    Owns the jitted fused step (control plane + decode + migration, see
    the module docstring) and exposes the drive modes: eager `step`,
    fused `run`/`generate`, and the continuous-batching `serve`. Device
    telemetry is priced per step into `self.stats` (`StepStats`,
    Eq. (1)-(5)); with `EngineConfig.trace_telemetry` the raw page
    access/placement stream is additionally kept for the simulator
    bridge (`repro.serving.trace_bridge`)."""

    def __init__(self, model: Model, params, cfg: EngineConfig,
                 mesh=None):
        if cfg.policy not in policy_names():
            raise ValueError(
                f"unknown EngineConfig.policy {cfg.policy!r}; registered "
                f"device policies: {', '.join(policy_names())}")
        if cfg.prefill_budget is not None and cfg.prefill_budget < 1:
            raise ValueError(
                f"EngineConfig.prefill_budget must be >= 1 tokens/step "
                f"or None (uncapped), got {cfg.prefill_budget}")
        if mesh is not None and "model" not in mesh.axis_names:
            raise ValueError(
                f"ServingEngine mesh needs a 'model' axis (and usually "
                f"'data'); got axes {mesh.axis_names}")
        self.model = model
        self.params = params
        self.cfg = cfg
        #: optional jax device mesh: `serve` then pins NamedShardings
        #: on the fused chunk — KV pools tensor-parallel over kv_heads
        #: or pages (`launch.shardings._kv_shard_axis`), lanes
        #: data-parallel over `batch_axes` — and places params / cache
        #: / policy state once per stream. None = single device, the
        #: exact pre-mesh behavior. A constructor argument, not an
        #: EngineConfig field: the compiled executables are keyed on
        #: it (`_ensure_step_fns`), but a Mesh is device state, not a
        #: serializable config value.
        self.mesh = mesh
        #: feature-detected pinned host memory kind ("pinned_host" on
        #: real TPU/GPU runtimes, None on CPU) — probed ONCE at
        #: construction; overlap-mode serve places its host pools there
        #: (single-device streams only: a mesh pins its own shardings)
        #: so the staged commit's cross-pool scatter is a true host-link
        #: DMA the decode compute hides.
        self._host_memory_kind = host_memory_kind()
        self.stats: List[StepStats] = []
        self._sampling = SamplingConfig()
        #: raw (stats, access, tier) chunks when cfg.trace_telemetry
        #: (consumed by repro.serving.trace_bridge.collect)
        self._trace_log: List[tuple] = []

    # ------------------------------------------------------------------ #
    def start(self, prompts: jax.Array, extra=None):
        """Prefill `prompts` [B, S] into a fresh cache and return the
        last-position logits; resets stats and any captured trace.
        The single-stream entry point — `serve` manages its own cache
        and admission, `start` is for step/run/generate driving."""
        geo = self.model.cache_geometry(
            prompts.shape[0], self.cfg.max_context,
            hbm_fraction=self.cfg.hbm_fraction)
        self.geo = geo
        logits, state = self.model.prefill(self.params, prompts, geo,
                                           extra=extra)
        self.state = state
        self._ensure_step_fns()
        self._pstate = self._policy.init_state(geo)
        self._trace_log = []
        self._trace_prompt_len = int(prompts.shape[1])
        return logits

    @property
    def _cache(self) -> PagedKVCache:
        return _get_cache(self.state)

    # ------------------------------------------------------------------ #
    # the fused step: control plane + data plane + migration, all jit
    # ------------------------------------------------------------------ #
    def _ensure_step_fns(self):
        """(Re)build the jitted step functions only when the cache
        geometry, sampling config, or engine config changed, so repeated
        `serve`/`start` calls over the same shapes reuse the compiled
        executables (cfg is part of the key because the step closures
        bake in policy/threshold/stride/eos; the mesh because the serve
        jit pins its shardings)."""
        key = (self.geo, self._sampling, dataclasses.astuple(self.cfg),
               self.mesh)
        if getattr(self, "_fns_key", None) != key:
            self._build_step_fns()
            self._fns_key = key

    def _build_step_fns(self):
        cfg, model, geo = self.cfg, self.model, self.geo
        overlap = cfg.overlap_migrations
        sparsity = cfg.attention_sparsity
        fam = model.cfg.family
        has_cache = fam in ("dense", "vlm", "moe", "encdec") or (
            fam in ("ssm", "hybrid")
            and bool(model.cfg.attention_layer_ids()))
        masked = sparsity > 0 and has_cache
        policy = make_policy(cfg.policy, cfg=cfg, geo=geo)
        self._policy = policy
        budget = control.migration_budget(geo, cfg.migration_budget_frac)
        capture = cfg.trace_telemetry
        eos = cfg.eos_id
        sampler = make_sampler(self._sampling)
        self._sampler = sampler

        def step_fn(params, state, pstate, token, active=None,
                    mig_cap=None):
            cache = _get_cache(state)
            kwargs = {"write_slot": control.choose_write_slot(cache)}
            mask = None
            if masked:
                mask = control.quest_page_mask(cache, sparsity)
                kwargs["logical_page_mask"] = mask
            # the read set this step's attention streams: the Quest
            # mask (already alive-gated), or every pre-decode page —
            # handed to the policy (so access-history policies track
            # the true stream) and to the telemetry capture
            read = mask if mask is not None else cache.page_table >= 0
            logits, state = model.decode_step(params, state, token,
                                              **kwargs)
            if active is not None:
                # per-slot masking: inactive lanes keep their pre-step
                # cache verbatim (no token write, no length bump)
                state = _set_cache(state, control.lane_merge(
                    cache, _get_cache(state), active))
            cache = _get_cache(state)
            # read traffic is counted on post-decode, pre-migration
            # residency (the step's attention read the old placement)
            occ = control.occupancy(cache)
            plan, pstate, (n_pro, n_dem) = policy.plan(
                cache, pstate, active, budget, read_mask=read)
            if mig_cap is not None:
                # migration-fault channel (serve only): commit at most
                # `mig_cap` promote rows this step — cap is traced DATA
                # (NO_FAULT_CAP = identity), so the clean and faulted
                # streams share one executable. Telemetry counts the
                # COMMITTED moves, so pricing and the bridge's scores
                # see the placement that actually happened.
                plan = throttle_plan(plan, mig_cap)
                n_pro, n_dem = plan.row_counts()
            moves = jnp.stack([n_pro, n_dem]).astype(jnp.int32)
            base = jnp.concatenate([occ, moves])
            if capture:
                # full-batch read set + read-time placement (post-decode
                # so the step's fresh page is included, pre-migration).
                # `_record` keeps lane 0 for the generate bridge; the
                # serve capture keeps every lane for per-request
                # attribution (trace_bridge.collect_serve).
                stats = (base, read, control.page_tiers(cache))
            else:
                stats = (base,)
            state = _set_cache(state, apply_migrations(cache, plan))
            return logits, state, pstate, stats

        def chunk_fn(params, state, pstate, tokens):
            """Teacher-forced fused decode over tokens [n, B]."""
            def body(carry, tok):
                st, ps = carry
                logits, st, ps, stats = step_fn(params, st, ps, tok)
                return (st, ps), (logits, stats)
            (state, pstate), (logits, stats) = jax.lax.scan(
                body, (state, pstate), tokens)
            return state, pstate, logits, stats

        def gen_fn(params, state, pstate, token, n):
            """Greedy self-feeding fused decode for n steps."""
            def body(carry, _):
                st, ps, tok = carry
                logits, st, ps, stats = step_fn(params, st, ps, tok)
                nxt = jnp.argmax(logits, -1).astype(jnp.int32)
                return (st, ps, nxt), (nxt, stats)
            (state, pstate, token), (toks, stats) = jax.lax.scan(
                body, (state, pstate, token), None, length=n)
            return state, pstate, token, toks, stats

        def step_overlap_fn(params, state, pstate, staged, token, active,
                            mig_cap):
            """Overlap-mode serve step: the double-buffered plan/commit
            split. Three stages, all in one traced program:

              1. decode against the PRE-commit placement (the commit
                 lands "concurrently" with this compute — on real
                 hardware the staged cross-pool scatter is an async DMA
                 the forward hides; in the traced program it is
                 sequenced after the decode so the step's reads see the
                 old placement, the bitwise expression of overlap);
              2. COMMIT the plan staged one step ago: hazard-revalidated
                 against the commit-time owner maps
                 (`control.revalidate_plan` — a page never commits into
                 a slot the in-flight step just allocated) and throttled
                 by the fault channel (the chaos caps govern what
                 COMMITS, exactly as inline — telemetry counts committed
                 moves);
              3. PLAN for the step after next on the post-commit
                 placement, with this step's read set as the
                 one-step-ahead re-reference oracle (`plan_ahead`
                 policies protect it from eviction). The fresh plan is
                 the new staged carry.
            """
            cache = _get_cache(state)
            kwargs = {"write_slot": control.choose_write_slot(cache)}
            mask = None
            if masked:
                mask = control.quest_page_mask(cache, sparsity)
                kwargs["logical_page_mask"] = mask
            read = mask if mask is not None else cache.page_table >= 0
            logits, state = model.decode_step(params, state, token,
                                              **kwargs)
            state = _set_cache(state, control.lane_merge(
                cache, _get_cache(state), active))
            cache = _get_cache(state)
            # occupancy + read-time placement are PRE-commit: this
            # step's attention read the old placement
            occ = control.occupancy(cache)
            tiers = control.page_tiers(cache) if capture else None
            commit = control.revalidate_plan(staged, cache)
            commit = throttle_plan(commit, mig_cap)
            n_pro, n_dem = commit.row_counts()
            cache = apply_migrations(cache, commit)
            state = _set_cache(state, cache)
            staged, pstate, _ = policy.plan(cache, pstate, active,
                                            budget, read_mask=read)
            moves = jnp.stack([n_pro, n_dem]).astype(jnp.int32)
            base = jnp.concatenate([occ, moves])
            stats = (base, read, tiers) if capture else (base,)
            return logits, state, pstate, staged, stats

        serveable = fam in ("dense", "moe")
        if serveable:
            C = max(1, cfg.prefill_chunk)
            S_cap = geo.max_tokens
            B = geo.batch
            Pb = cfg.prefill_budget
            use_budget = Pb is not None
            pf_logits_sds, _ = jax.eval_shape(
                lambda c, t, s, n: model.prefill_chunk(self.params, c,
                                                       t, s, n),
                abstract_cache(geo),
                jax.ShapeDtypeStruct((B, C), jnp.int32),
                jax.ShapeDtypeStruct((B,), jnp.int32),
                jax.ShapeDtypeStruct((B,), jnp.int32))

        def _serve_chunk_impl(params, state, pstate, staged, token, active,
                              remaining, keys, prefilled, prompt_len,
                              prompt_buf, credits, mig_caps, poison):
            """One fused chunk of MIXED prefill+decode steps.

            Carries per-slot (token, active, remaining budget, PRNG key,
            prompt progress) through `lax.scan`; per step the lane-mode
            split (`control.lane_modes`) is derived on device, decoding
            lanes run the decode plane (emitting into `emitted`, -1
            elsewhere) and prefilling lanes consume a C-token prompt
            slice (`model.prefill_chunk` — skipped via `lax.cond` when
            no lane is prefilling). The step where a lane's prefill
            crosses prompt_len samples its FIRST token from the last
            prompt position's logits (reported via `first`, not
            `emitted`, so telemetry still prices decode steps only) and
            the lane starts decoding the next step — all without host
            involvement. Completion (EOS / budget, including instant
            budget-1/EOS at the crossing) flips the lane's active bit
            on device; the host reclaims and re-admits at the chunk
            boundary.

            Fault channel (always compiled in — values, never shapes):
            `mig_caps` [stride] int32 caps each step's migration
            commits (`NO_FAULT_CAP` = untouched) and `poison`
            [stride, B] bool overwrites a lane's logits with NaN. The
            non-finite sampling guard is ALWAYS on, injected or not: a
            lane whose logits go NaN/Inf emits nothing that step, flips
            inactive, and is flagged in the `failed` output so the host
            completes it with status "failed" — every other lane's
            tokens are bitwise what they are in a clean run.

            Overlap mode threads one more carry leaf: the STAGED
            `MigrationPlan` — step N's decode plane commits the plan
            staged at N-1 and stages a fresh one (`step_overlap_fn`);
            pure-prefill steps pass it through untouched (the next
            decode's revalidation catches any prefill-allocated slot
            it names).
            """
            def body(carry, xs):
                if overlap:
                    st, ps, stg, tok, act, rem, ks, prog, cred = carry
                else:
                    st, ps, tok, act, rem, ks, prog, cred = carry
                    stg = None
                cap, poi = xs
                pf, dec = control.lane_modes(act, prog, prompt_len)

                # decode plane: skipped (lax.cond) on pure-prefill
                # steps — step_fn with dec all-False is a bitwise
                # no-op on the cache (lane_merge freezes every lane,
                # the planner plans nothing) and its stats row is
                # filtered at the boundary, so skipping it only saves
                # the dead forward
                def run_dec(args):
                    if overlap:
                        return step_overlap_fn(params, args[0], args[1],
                                               args[2], args[3], dec, cap)
                    return step_fn(params, args[0], args[1], args[2], dec,
                                   mig_cap=cap)

                def skip_dec(args):
                    c = _get_cache(args[0])
                    occ = control.occupancy(c)
                    vocab = pf_logits_sds.shape[-1]
                    base = jnp.concatenate([occ,
                                            jnp.zeros((2,), jnp.int32)])
                    if capture:
                        # pure-prefill step: no decode reads. The tier
                        # snapshot keeps the ys pytree static; the
                        # bridge drops these rows (no lane emitted).
                        nostats = (base,
                                   jnp.zeros(c.page_table.shape, bool),
                                   control.page_tiers(c))
                    else:
                        nostats = (base,)
                    zeros = jnp.zeros((B, vocab), pf_logits_sds.dtype)
                    if overlap:
                        # no decode, no commit: the staged plan waits
                        return (zeros, args[0], args[1], args[2],
                                nostats)
                    return (zeros, args[0], args[1], nostats)

                if overlap:
                    logits, st, ps, stg, stats = jax.lax.cond(
                        dec.any(), run_dec, skip_dec, (st, ps, stg, tok))
                else:
                    logits, st, ps, stats = jax.lax.cond(
                        dec.any(), run_dec, skip_dec, (st, ps, tok))
                if capture:
                    # decode-plane attribution only: a lane's reads
                    # count while it DECODES — prefilling lanes' pages
                    # are write traffic, not part of the access model
                    stats = (stats[0], stats[1] & dec[None, :, None],
                             stats[2])
                # poison injection + non-finite sampling guard. The
                # injected NaN and a genuinely non-finite model output
                # take the same quarantine path: the lane emits nothing
                # this step, keeps its budget, and flips inactive.
                nanv = jnp.asarray(jnp.nan, logits.dtype)
                logits = jnp.where((dec & poi)[:, None], nanv, logits)
                bad = dec & ~jnp.isfinite(logits).all(axis=-1)
                dec_ok = dec & ~bad
                ks, sub = split_lanes(ks)
                nxt = sampler(logits, sub)
                rem = rem - dec_ok.astype(rem.dtype)
                fin = dec_ok & (rem <= 0)
                if eos is not None:
                    fin = fin | (dec_ok & (nxt == eos))
                emitted = jnp.where(dec_ok, nxt, -1)
                tok = jnp.where(dec_ok, nxt, tok)
                act = act & ~fin & ~bad

                # prefill plane: a C-token slice per prefilling lane,
                # written straight into its pages at offset `prog`
                n_val = jnp.where(pf, jnp.clip(prompt_len - prog, 0, C),
                                  0).astype(jnp.int32)
                if use_budget:
                    # per-batch token bucket: accrue Pb tokens/step
                    # (capped at one full step's demand) and run the
                    # prefill plane only when the bucket covers the
                    # step's TOTAL demand — heavy prefill waves dilute
                    # over steps instead of taxing every decode step
                    want_tot = n_val.sum()
                    cred = jnp.minimum(cred + jnp.int32(Pb),
                                       jnp.int32(B * C))
                    run_now = cred >= want_tot
                    n_val = jnp.where(run_now, n_val, 0)
                    cred = cred - jnp.where(run_now, want_tot, 0)
                idx = jnp.clip(prog[:, None] + jnp.arange(C), 0,
                               S_cap - 1)
                sl_toks = jnp.take_along_axis(prompt_buf, idx, axis=1)
                cache = _get_cache(st)

                def run_pf(args):
                    c, t, s, n = args
                    return model.prefill_chunk(params, c, t, s, n)

                def skip_pf(args):
                    return (jnp.zeros(pf_logits_sds.shape,
                                      pf_logits_sds.dtype), args[0])

                # (n_val > 0).any() == pf.any() when unbudgeted (a
                # prefilling lane always wants >= 1 token); under a
                # budget it additionally skips bucket-starved steps
                logits_c, cache = jax.lax.cond(
                    (n_val > 0).any(), run_pf, skip_pf,
                    (cache, sl_toks, prog, n_val))
                st = _set_cache(st, cache)
                prog = prog + n_val
                crossed = pf & (prog >= prompt_len)
                last = jnp.clip(n_val - 1, 0, C - 1)
                logits1 = jnp.take_along_axis(
                    logits_c, last[:, None, None], axis=1)[:, 0]
                # the same poison + guard protects the crossing sample:
                # a lane poisoned (or non-finite) at its first token
                # fails before emitting anything
                nanv1 = jnp.asarray(jnp.nan, logits1.dtype)
                logits1 = jnp.where((pf & poi)[:, None], nanv1, logits1)
                bad0 = crossed & ~jnp.isfinite(logits1).all(axis=-1)
                crossed = crossed & ~bad0
                tok0 = sampler(logits1, sub)
                first = jnp.where(crossed, tok0, -1)
                tok = jnp.where(crossed, tok0, tok)
                rem = rem - crossed.astype(rem.dtype)
                fin0 = crossed & (rem <= 0)
                if eos is not None:
                    fin0 = fin0 | (crossed & (tok0 == eos))
                act = act & ~fin0 & ~bad0
                if overlap:
                    out_carry = (st, ps, stg, tok, act, rem, ks, prog,
                                 cred)
                else:
                    out_carry = (st, ps, tok, act, rem, ks, prog, cred)
                # n_val is the step's ACTUAL prompt consumption per
                # lane (0 on budget-starved steps) — the host's TTFT
                # decomposition splits a prefilling lane's chunk time
                # into prefill vs throttle off exactly this readback
                return out_carry, (emitted, first, bad | bad0, n_val,
                                   stats)

            if overlap:
                carry = (state, pstate, staged, token, active, remaining,
                         keys, prefilled, credits)
            else:
                carry = (state, pstate, token, active, remaining, keys,
                         prefilled, credits)
            carry, (emitted, first, failed, pf_tok, stats) = jax.lax.scan(
                body, carry, (mig_caps, poison))
            if overlap:
                (state, pstate, staged, token, active, remaining, keys,
                 prefilled, credits) = carry
                return (state, pstate, staged, token, active, remaining,
                        keys, prefilled, credits, emitted, first, failed,
                        pf_tok, stats)
            (state, pstate, token, active, remaining, keys, prefilled,
             credits) = carry
            return (state, pstate, token, active, remaining, keys,
                    prefilled, credits, emitted, first, failed, pf_tok,
                    stats)

        if overlap:
            def serve_chunk_fn(params, state, pstate, staged, token,
                               active, remaining, keys, prefilled,
                               prompt_len, prompt_buf, credits, stale,
                               mig_caps, poison):
                # boundary hygiene (overlap only): lanes the host
                # released or (re)bound since the plan was staged carry
                # rows revalidation cannot catch — static placement is
                # deterministic, so a re-admitted request can reproduce
                # the evicted one's exact (slot, logical) pairs. Mask
                # them out before the chunk runs.
                staged = control.mask_plan_lanes(staged, stale)
                return _serve_chunk_impl(
                    params, state, pstate, staged, token, active,
                    remaining, keys, prefilled, prompt_len, prompt_buf,
                    credits, mig_caps, poison)
        else:
            def serve_chunk_fn(params, state, pstate, token, active,
                               remaining, keys, prefilled, prompt_len,
                               prompt_buf, credits, mig_caps, poison):
                return _serve_chunk_impl(
                    params, state, pstate, None, token, active,
                    remaining, keys, prefilled, prompt_len, prompt_buf,
                    credits, mig_caps, poison)

        self._step_jit = jax.jit(step_fn, donate_argnums=(1, 2))
        self._chunk_jit = jax.jit(chunk_fn, donate_argnums=(1, 2))
        self._gen_jit = jax.jit(gen_fn, donate_argnums=(1, 2),
                                static_argnums=(4,))
        #: mesh placements for serve-stream inputs (params / cache /
        #: policy state), set when a mesh is attached (serve() applies
        #: them with jax.device_put before the first chunk)
        self._serve_place = None
        if serveable and self.mesh is not None:
            self._build_sharded_serve_jit(serve_chunk_fn)
        else:
            if serveable:
                # overlap additionally donates the staged-plan carry
                # (small, but donation keeps the carry a fixed point)
                donate = (1, 2, 3) if overlap else (1, 2)
                self._serve_jit = jax.jit(serve_chunk_fn,
                                          donate_argnums=donate)
            self._release_jit = jax.jit(control.release_lanes,
                                        donate_argnums=(0,))

    def _build_sharded_serve_jit(self, serve_chunk_fn):
        """Pin the fused serve chunk's shardings on `self.mesh`.

        Explicit `in_shardings`/`out_shardings` rather than trusting
        GSPMD's defaults, for three reasons: (1) the donated carries
        (cache, policy state) must come back in EXACTLY the sharding
        they went in, or chunk-to-chunk re-layout would defeat donation
        and could oscillate into retraces — pinning out == in makes the
        sharding a fixed point; (2) host-built chunk inputs (tokens,
        masks, the prompt buffer) are uncommitted numpy uploads, so the
        in_shardings place them lane-sharded for free; (3) the rules
        themselves are the documented surface (EXPERIMENTS.md
        §Mesh-sharding) — KV pools over kv_heads or pages, lanes over
        `data`, fault caps replicated. Stats outputs stay unpinned
        (`None`): they are read back to host each boundary either way.
        """
        from repro.launch import shardings as shd
        mesh, model, geo = self.mesh, self.model, self.geo
        sh = shd.serve_shardings(geo, mesh)
        pshard = shd.param_shardings(model.logical_axes(),
                                     model.abstract_params(), mesh,
                                     "serve")
        pstate_abs = jax.eval_shape(
            lambda: self._policy.init_state(geo))
        psh = shd.policy_state_shardings(pstate_abs, geo, mesh)
        lane, lane_kv = sh["lane"], sh["lane_kv"]
        rep, step_lane = sh["rep"], sh["step_lane"]
        cache_sh = sh["cache"]
        if self.cfg.overlap_migrations:
            # the staged-plan carry is a new donated leaf: replicated
            # ([M] row vectors — the fault plane's convention: plans
            # are global control state, not per-shard), out == in so
            # the carry sharding is a fixed point; `stale` is a
            # per-lane boundary input
            plan_sh = sh["plan"]
            in_sh = (pshard, cache_sh, psh, plan_sh, lane, lane, lane,
                     lane_kv, lane, lane, lane_kv, rep, lane, rep,
                     step_lane)
            out_sh = (cache_sh, psh, plan_sh, lane, lane, lane, lane_kv,
                      lane, rep, step_lane, step_lane, step_lane,
                      step_lane, None)
            donate = (1, 2, 3)
        else:
            in_sh = (pshard, cache_sh, psh, lane, lane, lane, lane_kv,
                     lane, lane, lane_kv, rep, rep, step_lane)
            out_sh = (cache_sh, psh, lane, lane, lane, lane_kv, lane,
                      rep, step_lane, step_lane, step_lane, step_lane,
                      None)
            donate = (1, 2)
        self._serve_jit = jax.jit(serve_chunk_fn, donate_argnums=donate,
                                  in_shardings=in_sh,
                                  out_shardings=out_sh)
        self._release_jit = jax.jit(control.release_lanes,
                                    donate_argnums=(0,),
                                    in_shardings=(cache_sh, lane),
                                    out_shardings=cache_sh)
        self._serve_place = {"params": pshard, "cache": cache_sh,
                             "pstate": psh, "rep": rep,
                             "plan": sh["plan"]}

    # ------------------------------------------------------------------ #
    # drive modes
    # ------------------------------------------------------------------ #
    def step(self, token: jax.Array) -> jax.Array:
        """Eager: one device dispatch + one telemetry sync per token."""
        logits, self.state, self._pstate, stats = self._step_jit(
            self.params, self.state, self._pstate, token)
        self._record(tuple(np.asarray(x)[None] for x in stats))
        return logits

    def run(self, tokens: jax.Array) -> jax.Array:
        """Fused teacher-forced decode. tokens [K, B] -> logits [K, B, V].

        Runs `lax.scan` chunks of `telemetry_stride` steps; telemetry is
        read back once per chunk. Produces bitwise-identical logits and
        identical StepStats accounting to K calls of `step()`.
        """
        K = tokens.shape[0]
        if K == 0:
            return jnp.zeros((0, tokens.shape[1], self.model.cfg.vocab))
        stride = max(1, self.cfg.telemetry_stride)
        out = []
        for s in range(0, K, stride):
            self.state, self._pstate, logits, stats = self._chunk_jit(
                self.params, self.state, self._pstate,
                tokens[s:s + stride])
            self._record(tuple(np.asarray(x) for x in stats))
            out.append(logits)
        return out[0] if len(out) == 1 else jnp.concatenate(out, axis=0)

    def generate(self, token: jax.Array, steps: int) -> jax.Array:
        """Fused greedy generation from `token` [B] -> tokens [steps, B]."""
        if steps == 0:
            return jnp.zeros((0,) + token.shape, jnp.int32)
        stride = max(1, self.cfg.telemetry_stride)
        out = []
        done = 0
        while done < steps:
            n = min(stride, steps - done)
            self.state, self._pstate, token, toks, stats = self._gen_jit(
                self.params, self.state, self._pstate, token, n)
            self._record(tuple(np.asarray(x) for x in stats))
            out.append(toks)
            done += n
        return out[0] if len(out) == 1 else jnp.concatenate(out, axis=0)

    # ------------------------------------------------------------------ #
    # continuous-batching serve loop (the headline API)
    # ------------------------------------------------------------------ #
    def serve(self, requests: Sequence[Request], *,
              num_slots: Optional[int] = None,
              sampling: Optional[SamplingConfig] = None,
              seed: int = 0, total_pages: Optional[int] = None,
              max_skips: int = 8,
              faults: Optional[FaultPlane] = None,
              slo: Optional[SLOPolicy] = None) -> ServeReport:
        """Drive a request stream end-to-end through the fused hot path.

        A fixed batch of `num_slots` cache lanes runs as ONE jitted
        `lax.scan` chunk per `telemetry_stride` steps of MIXED
        prefill+decode steps: decoding lanes emit one sampled token
        while prefilling lanes consume a `prefill_chunk`-token slice of
        their prompt, written straight into their lane's pages at an
        offset (`Model.prefill_chunk`). The per-lane mode flip —
        including sampling the request's first token at the step
        prefill crosses prompt_len — happens on device, so admissions,
        mode transitions and completions never change traced shapes:
        ONE serve-chunk executable across the whole stream, whatever
        the prompt-length mix (no per-length admission compiles, no
        whole-batch stall while a prompt prefills).

        Per chunk boundary the host: reads back emitted + first tokens
        and the per-slot (active, remaining, prefilled) carry, completes
        finished requests (EOS or budget, decided ON DEVICE) with one
        masked `control.release_lanes` call covering every completion
        in the chunk, and admits queued requests — pure bookkeeping
        (`_admit_lane`): a prompt row, counters, and a sampling key.

        Sampling (temperature / top-k / top-p) runs inside the fused
        loop with per-slot PRNG keys derived from (`seed`, request id);
        the default `SamplingConfig()` is greedy, and a single
        full-length request then reproduces `generate` bitwise — as
        does chunked prefill at ANY budget vs the whole-prompt forward
        (tests/test_chunked_prefill.py).

        Returns a `ServeReport`: completed requests (token ids in
        `req.output`) plus TTFT/TPOT percentiles from the per-request
        wall-clock stamps.

        With `EngineConfig.trace_telemetry` the chunk additionally
        reads back every lane's page read set and read-time placement
        (decode plane only — prefill writes never enter the access
        model) stamped with the chunk's lane->request bindings;
        `trace_bridge.collect_serve`/`attribute` stitch those into
        per-request simulator traces and `trace_bridge.score_serve`
        scores the stream (and each request) against the SA upper
        bound. Capture is pure observation: tokens, StepStats, and the
        one-executable-per-stream property are unchanged.

        Failure semantics (see `repro.serving.faults` and
        EXPERIMENTS.md §Fault-injection): serve NEVER raises on a
        per-request condition. Invalid requests (missing prompt,
        `max_new_tokens < 1`, prompt+budget over the cache capacity),
        duplicates, and pool-infeasible footprints are REJECTED with a
        typed error while the rest of the stream proceeds; per-request
        `deadline_s` and `cancel()` are honored at chunk boundaries
        ("timeout"/"cancelled" — live lanes release their pages, queued
        requests are dropped); a lane whose logits go non-finite is
        quarantined on device and completed as "failed". Every request
        ends in exactly one terminal status (`ServeReport.statuses`).

        With `EngineConfig.overlap_migrations` the migration plane runs
        as a two-phase, double-buffered pipeline inside the same scan:
        each step COMMITS the plan staged at the previous step
        (revalidated against the current owner maps and throttled by
        the fault channel) concurrently with its decode compute, then
        PLANS for the next step using this step's read set as a
        one-step-ahead re-reference oracle (`DevicePolicy.plan_ahead`,
        active when the read set is sparse). Decode SEMANTICS are
        placement-invariant — attention reads the same pages wherever
        they reside — so overlap mode computes the same stream; when
        interim placements differ from the inline engine's, the
        per-tier LSE merge may associate floating point differently
        (the serve==generate bitwise pin is inline-mode). The
        zero-retrace / one-executable pins hold per (policy, mesh,
        overlap). See EXPERIMENTS.md §Async-migration.

        Constructed with a device mesh (`ServingEngine(..., mesh=m)`),
        the SAME loop runs sharded: the chunk executable is compiled
        with pinned `NamedSharding`s (KV pools tensor-parallel over
        kv_heads or pages, lanes data-parallel, fault caps replicated
        — `repro.launch.shardings.serve_shardings`), the cache /
        policy-state carries stay device-resident and donated per
        shard, and boundary readbacks gather transparently. Placement
        is values-only, so the zero-retrace and one-executable pins
        hold per mesh, and tokens + terminal statuses match the
        single-device stream (tests/test_mesh_serve.py; EXPERIMENTS.md
        §Mesh-sharding).

        Open-loop traffic: a request with `arrival_s > 0` is held back
        and SUBMITTED at the first chunk boundary whose wall clock
        (relative to stream start) passes its arrival offset — the
        workload plane's load driver (`benchmarks/workloads.py`). The
        arrival pattern is pure DATA: bursty, diurnal, and Poisson
        streams all drive the same serve-chunk executable (an idle
        stream with pending arrivals sleeps between boundaries; shapes
        never change). `queue_wait_s` then measures real queueing.

        `slo` layers SLO-aware admission on top of the
        `prefill_budget` token bucket: at every chunk boundary, AFTER
        deadline/cancel reaping, each QUEUED request's earliest
        achievable TTFT is projected (wait so far + prompt prefill at
        the measured per-step cadence) and requests past their tier's
        target are shed as `rejected` with error code "slo_shed" —
        early, before they cost a lane or drag decode TPOT. A request
        is never counted both "timeout" and SLO-shed: deadline reaping
        runs first and removes it from the queue. Per-request TTFT
        decomposition (`queue_wait_s` + `prefill_s` + `throttle_s` ==
        TTFT, exact at the chunk-stride stamp resolution) lands on
        every chunk-admitted request; `ServeReport.ttft_parts` carries
        the percentiles and `slo.score_goodput` turns a report + an
        `SLOPolicy` into the goodput row.

        `faults` optionally injects a deterministic adversity schedule
        (`FaultPlane`): tier-bandwidth degradation reprices telemetry
        under the degraded spec and recalibrates cost_aware paybacks;
        migration faults throttle plan commits; pool faults resize the
        scheduler's page pool; poison faults NaN a lane's logits. The
        fault channel is compiled into the serve executable as DATA
        (per-step caps + poison masks), so a clean run and a faulted
        run share ONE executable and fault-free lanes produce bitwise
        identical tokens. Degradations are stamped into
        `ServeReport.events`, and repeated commit drops or a tier
        ratio past `EngineConfig.fallback_tier_ratio` degrade the
        policy to static behavior (all commits masked) for the rest of
        the stream.
        """
        cfg = self.cfg
        fam = self.model.cfg.family
        if fam not in ("dense", "moe"):
            raise NotImplementedError(
                f"serve() drives cache-backed decode states (dense/moe); "
                f"family {fam!r} needs prefill extras or recurrent-state "
                f"lane insertion")
        if not requests:
            return ServeReport(completed=[])
        B = num_slots if num_slots is not None else min(len(requests), 4)
        geo = self.model.cache_geometry(
            B, cfg.max_context, hbm_fraction=cfg.hbm_fraction)
        self.geo = geo
        # overlap mode backs the host-tier pools with pinned_host
        # memory when the platform exposes it (TPU/GPU) so the staged
        # commit's gathers become true async DMA; single-device only —
        # under a mesh the cache shardings own placement. On CPU
        # `host_memory_kind()` is None and this is the plain init.
        host_kind = self._host_memory_kind \
            if (cfg.overlap_migrations and self.mesh is None) else None
        self.state = init_cache(geo, host_kind=host_kind)
        self.stats = []
        self._sampling = sampling or SamplingConfig()
        self._ensure_step_fns()
        pstate = self._policy.init_state(geo)
        if self._serve_place is not None:
            # mesh placement, once per stream: shard the fresh cache +
            # policy state (the donated carries) and the params to the
            # exact shardings the serve jit pins — every later chunk
            # then reuses the placement (device_put on an
            # already-matching pytree is a no-op)
            self.state = jax.device_put(self.state,
                                        self._serve_place["cache"])
            pstate = jax.device_put(pstate, self._serve_place["pstate"])
            self.params = jax.device_put(self.params,
                                         self._serve_place["params"])
        credits = jnp.zeros((), jnp.int32)   # prefill token bucket
        if self._serve_place is not None:
            # committed-replicated from chunk one, like every later
            # chunk's device output — an uncommitted first value would
            # fork the jit's input-sharding cache key (2 entries, same
            # lowering) and break the one-executable pin
            credits = jax.device_put(credits, self._serve_place["rep"])
        #: per-chunk (access, tier, emitted, first, rids, prompt_len)
        #: when cfg.trace_telemetry (trace_bridge.collect_serve)
        self._serve_trace_log = []

        pool = total_pages if total_pages is not None \
            else B * geo.max_pages
        batcher = ContinuousBatcher(B, pool, page_tokens=geo.page_tokens,
                                    max_skips=max_skips)
        self.batcher = batcher
        # per-request validation: an invalid request is REJECTED with a
        # typed error; everyone else keeps serving (no batch-wide abort)
        def submit_one(r: Request) -> None:
            if r.prompt is None:
                batcher.reject_submit(
                    r, "empty_prompt",
                    f"request {r.rid}: serve() needs prompt tokens")
            elif r.max_new_tokens < 1:
                batcher.reject_submit(
                    r, "zero_budget",
                    f"request {r.rid}: max_new_tokens must be >= 1")
            elif r.prompt_len + r.max_new_tokens > geo.max_tokens:
                batcher.reject_submit(
                    r, "infeasible_context",
                    f"request {r.rid}: {r.prompt_len}+{r.max_new_tokens}"
                    f" tokens exceed cache capacity {geo.max_tokens}")
            else:
                batcher.submit(r)   # may itself reject (duplicate /
                #                     pool-infeasible footprint)

        # open-loop load driver: requests with a positive arrival
        # offset are held back and submitted at the first chunk
        # boundary whose wall clock passes them — `submitted_at` (and
        # so queue_wait/TTFT) stamps at ARRIVAL, not at serve() entry
        t_start = time.time()
        pending: List[Request] = sorted(
            (r for r in requests if r.arrival_s > 0.0),
            key=lambda r: r.arrival_s)
        for r in requests:
            if r.arrival_s <= 0.0:
                submit_one(r)

        def submit_arrivals() -> bool:
            now_rel = time.time() - t_start
            due = False
            while pending and pending[0].arrival_s <= now_rel:
                submit_one(pending.pop(0))
                due = True
            return due

        # fault plumbing: a neutral plane keeps the (always-compiled)
        # fault channel at identity values for clean runs
        faults = faults if faults is not None else FaultPlane()
        base_spec = cfg.spec
        cap_rows = control.plan_capacity(geo, cfg.migration_budget_frac)
        events: List[dict] = []
        # measured-payback recalibration (cfg.measured_payback): replace
        # the spec's MODELED link bandwidth with one derived from a
        # one-shot microbenchmark of the actual jitted migration commit
        # on this host, and re-derive cost_aware's payback bars from it.
        # Pricing (StepStats -> Eq.(1)-(5)) stays on the modeled
        # base_spec — the paper's accounting is the comparable surface;
        # only the policy's decision thresholds go empirical. Tier
        # faults compose onto whichever spec governs each consumer.
        calib_base = base_spec
        if cfg.measured_payback:
            measured, detail = self._measure_migration_spec(geo)
            if measured is not None:
                calib_base = measured
                pstate = self._policy.recalibrate(pstate, measured)
                if self._serve_place is not None:
                    pstate = jax.device_put(pstate,
                                            self._serve_place["pstate"])
            events.append({"kind": "payback_measured", "step": 0,
                           **detail})
        last_thresh = calib_base
        fallback = False
        drop_streak = 0
        # overlap mode: the staged-plan scan carry starts as an all
        # sentinel (empty) plan — step 0 commits nothing, exactly the
        # one-step pipeline fill; `stale_np` marks lanes the host
        # rebound between chunks so their staged rows get masked
        staged = None
        stale_np = np.zeros((B,), bool)
        if cfg.overlap_migrations:
            staged = MigrationPlan.empty(cap_rows)
            if self._serve_place is not None:
                staged = jax.device_put(staged, self._serve_place["plan"])

        stride = max(1, cfg.telemetry_stride)
        root = jax.random.PRNGKey(seed)
        # host-side lane state poked by _admit_lane; everything the
        # device needs is re-uploaded per chunk (small [B]-vectors plus
        # the [B, max_tokens] prompt buffer)
        hs = {
            "root": root,
            "prompt_buf": np.zeros((B, geo.max_tokens), np.int32),
            "token": np.zeros((B,), np.int32),
            "keys": np.array(jax.random.split(root, B)),
        }
        live: Dict[int, Request] = {}          # lane -> request

        def admit():
            """Admit until no progress (an admission the eager-baseline
            subclass completes instantly frees its slot for the next
            queued request within the same boundary)."""
            while True:
                admitted = batcher.admit()
                if not admitted:
                    return
                for req in admitted:
                    self._admit_lane(req, hs)
                    if req.lane >= 0:
                        live[req.lane] = req
                        # overlap: a freshly (re)bound lane's staged
                        # rows describe the PREVIOUS tenant — and
                        # deterministic static placement means a
                        # re-admission can reproduce the evicted
                        # request's exact (slot, logical) pairs, so
                        # commit-time revalidation alone cannot tell
                        # them apart. Mark the lane stale; the chunk
                        # masks its rows before anything commits.
                        stale_np[req.lane] = True

        #: EMA of the measured per-step wall seconds (from chunk
        #: spans) — the SLO shed projection's prefill-cadence estimate
        est_step_s: Optional[float] = None

        def shed_slo() -> None:
            """SLO-aware admission: project each QUEUED request's
            earliest achievable TTFT and shed hopeless ones as
            `rejected` / "slo_shed". Runs after deadline/cancel
            reaping, so "timeout" and SLO-shed are mutually exclusive
            by construction (both remove the request from the queue).
            """
            if slo is None:
                return
            now = time.time()
            for req in list(batcher.queue):
                # an expired or cancelled request belongs to the
                # reaper: never convert a due "timeout"/"cancelled"
                # into an SLO shed
                if req.cancel_requested or (
                        req.deadline_s is not None
                        and now - req.submitted_at > req.deadline_s):
                    continue
                reason = slo.should_shed(req, now, est_step_s,
                                         cfg.prefill_chunk)
                if reason is not None:
                    batcher.drop_queued(req, "rejected", "slo_shed",
                                        reason)
                    events.append({"kind": "slo_shed",
                                   "step": batcher.step_idx,
                                   "rid": req.rid, "tier": req.tier,
                                   "reason": reason})

        # stream start: admit FIRST (nobody has genuinely waited yet),
        # then shed the queued remainder that already cannot make it
        admit()
        shed_slo()
        view = batcher.device_view()
        while batcher.has_work or pending:
            if submit_arrivals():
                admit()
                shed_slo()
                view = batcher.device_view()
            if not view.active.any():
                if batcher.queue:
                    # nothing live but work queued: the head can't be
                    # admitted with every page free (footprint vs a
                    # possibly shrunken pool) — reject it and move on
                    # instead of killing the stream mid-flight
                    stuck = batcher.queue.popleft()
                    batcher.reject(
                        stuck, "admission_stalled",
                        f"needs {stuck.pages_needed} pages, pool has "
                        f"{batcher.free_pages}/{batcher.total_pages} free")
                    admit()
                    view = batcher.device_view()
                    continue
                if pending:
                    # idle stream with future arrivals (open loop):
                    # sleep toward the next one, bounded so the
                    # boundary cadence stays responsive
                    wait = pending[0].arrival_s - (time.time() - t_start)
                    if wait > 0:
                        time.sleep(min(wait, 0.05))
                    continue
                break
            step0 = batcher.step_idx
            events.extend(faults.window_events(step0, stride))
            # tier fault: reprice + recalibrate under the spec that
            # governs this chunk; past the ratio threshold, migrating
            # toward the host tier can't pay back — fall back to static
            spec_now = faults.spec_at(step0, base_spec)
            # thresholds recalibrate from `calib_base` (== base_spec
            # unless measured_payback substituted a measured link) with
            # the same tier-fault scales composed on top; PRICING stays
            # on spec_now so telemetry remains paper-comparable
            thresh_now = faults.spec_at(step0, calib_base)
            if thresh_now != last_thresh:
                pstate = self._policy.recalibrate(pstate, thresh_now)
                if self._serve_place is not None:
                    # recalibrated values are fresh host scalars —
                    # restore the pinned placement so the chunk jit's
                    # input-sharding key (and the one-executable pin)
                    # survives the boundary
                    pstate = jax.device_put(pstate,
                                            self._serve_place["pstate"])
                last_thresh = thresh_now
                events.append({
                    "kind": "payback_recalibration", "step": step0,
                    "bw_ratio": thresh_now.bw_ratio})
            if not fallback and spec_now.bw_ratio >= \
                    cfg.fallback_tier_ratio * base_spec.bw_ratio:
                fallback = True
                events.append({
                    "kind": "policy_fallback", "step": step0,
                    "reason": "tier_ratio",
                    "bw_ratio": spec_now.bw_ratio})
            caps_np = faults.commit_caps(step0, stride, cap_rows)
            if (caps_np == 0).any():
                drop_streak += 1
            else:
                drop_streak = 0
            if not fallback and \
                    drop_streak >= max(1, cfg.fallback_commit_faults):
                fallback = True
                events.append({
                    "kind": "policy_fallback", "step": step0,
                    "reason": "commit_faults",
                    "boundaries": drop_streak})
            if fallback:
                # static fallback as DATA: all commits masked — the
                # same executable keeps running, it just stops moving
                # pages (exactly the registered `static` policy's
                # behavior: plans exist, none commit)
                caps_np = np.zeros_like(caps_np)
            poison_np = faults.poison_steps(step0, stride, view.rids)
            t0 = time.time()
            # TTFT decomposition anchor: a lane's clock switches from
            # queue_wait to prefill/throttle the instant its first
            # chunk starts running
            for req in live.values():
                if req.admitted_at is None:
                    req.admitted_at = t0
            if cfg.overlap_migrations:
                (self.state, pstate, staged, tok_d, act_d, _rem_d,
                 keys_d, prog_d, credits, emitted, first, failed,
                 pf_d, stats) = self._serve_jit(
                    self.params, self.state, pstate, staged,
                    jnp.asarray(hs["token"]), jnp.asarray(view.active),
                    jnp.asarray(view.remaining), jnp.asarray(hs["keys"]),
                    jnp.asarray(view.prefilled),
                    jnp.asarray(view.prompt_len),
                    jnp.asarray(hs["prompt_buf"]), credits,
                    jnp.asarray(stale_np), jnp.asarray(caps_np),
                    jnp.asarray(poison_np))
                # the chunk consumed the staleness marks; releases /
                # admissions below repopulate them for the next chunk
                stale_np = np.zeros((B,), bool)
            else:
                (self.state, pstate, tok_d, act_d, _rem_d, keys_d,
                 prog_d, credits, emitted, first, failed, pf_d,
                 stats) = self._serve_jit(
                    self.params, self.state, pstate,
                    jnp.asarray(hs["token"]),
                    jnp.asarray(view.active), jnp.asarray(view.remaining),
                    jnp.asarray(hs["keys"]), jnp.asarray(view.prefilled),
                    jnp.asarray(view.prompt_len),
                    jnp.asarray(hs["prompt_buf"]), credits,
                    jnp.asarray(caps_np), jnp.asarray(poison_np))
            emitted = np.asarray(emitted)               # [stride, B]
            first = np.asarray(first)                   # [stride, B]
            pf_tok = np.asarray(pf_d)                   # [stride, B]
            failed_lane = np.asarray(failed).any(axis=0)      # [B]
            hs["token"] = np.array(tok_d)               # writable copies:
            hs["keys"] = np.array(keys_d)               # admit() pokes them
            prog = np.asarray(prog_d)
            done_d = ~np.asarray(act_d)
            # telemetry: only steps where at least one lane DECODED —
            # prefill-only steps (first tokens included) are charged to
            # the prefill stage, matching the simulator's convention;
            # under a tier fault each surviving row is priced with the
            # spec governing ITS step
            row_mask = emitted.max(axis=1) >= 0
            specs = None
            if faults.tier:
                specs = [faults.spec_at(step0 + i, base_spec)
                         for i in np.nonzero(row_mask)[0]]
            self._record((np.asarray(stats[0])[row_mask],), specs=specs)
            if len(stats) == 3:
                # serve trace capture: the full-batch read set + tiers,
                # stamped with the chunk's lane->request bindings (fixed
                # within a chunk: admission only happens at boundaries)
                self._serve_trace_log.append(
                    (np.asarray(stats[1]), np.asarray(stats[2]),
                     emitted, first, view.rids.copy(),
                     view.prompt_len.copy()))
            # per-step wall-clock stamps: the chunk's device events are
            # observed at the boundary, so spread its wall time evenly
            # over the stride — TTFT/TPOT then resolve WITHIN a chunk
            # (a request finishing in one chunk still gets a per-token
            # latency, not a ~0 boundary-to-boundary delta)
            span = time.time() - t0
            est = span / stride
            est_step_s = est if est_step_s is None else \
                0.5 * (est_step_s + est)

            def stamp(row):
                return t0 + (row + 1) / stride * span

            release = np.zeros((B,), bool)
            for lane, req in list(live.items()):
                # a lane never emits both in one step: `first` at the
                # crossing step, `emitted` at decode steps after it
                rows = np.where(first[:, lane] >= 0, first[:, lane],
                                emitted[:, lane])
                got = np.nonzero(rows >= 0)[0]
                if req.first_token_at is None and \
                        req.admitted_at is not None:
                    # TTFT attribution up to the crossing row: rows
                    # where the lane ran prefill tokens are charged to
                    # prefill_s, budget-throttled rows (token bucket
                    # held the lane back) to throttle_s, and any host
                    # gap since the cursor (queue->dispatch, boundary
                    # work between chunks) to throttle_s as well — so
                    # queue_wait + prefill + throttle == TTFT exactly
                    crossed = first[:, lane].max() >= 0
                    c = int(np.argmax(first[:, lane] >= 0)) \
                        if crossed else stride - 1
                    cursor = (req.admitted_at + req.prefill_s +
                              req.throttle_s)
                    req.throttle_s += max(0.0, t0 - cursor)
                    ran = int((pf_tok[:c + 1, lane] > 0).sum())
                    w = span / stride
                    req.prefill_s += ran * w
                    req.throttle_s += (c + 1 - ran) * w
                if req.first_token_at is None and first[:, lane].max() >= 0:
                    req.first_token_at = stamp(
                        int(np.argmax(first[:, lane] >= 0)))
                    req.phase = "decoding"
                req.output.extend(int(rows[s]) for s in got)
                req.generated += len(got)
                req.prefilled = int(min(prog[lane], req.prompt_len))
                if done_d[lane]:      # EOS/budget/quarantine, on device
                    del live[lane]
                    release[lane] = True
                    if failed_lane[lane]:
                        # non-finite logits quarantined this lane: no
                        # token was emitted from the poisoned step on,
                        # pages release below, the stream keeps serving
                        batcher.complete(req, "failed", RequestError(
                            "poisoned_logits",
                            f"non-finite logits on lane {lane}"))
                    else:
                        req.stop_reason = "eos" if (
                            cfg.eos_id is not None and req.output
                            and req.output[-1] == cfg.eos_id) \
                            else "budget"
                        batcher.complete(req)
                    if got.size:
                        req.finished_at = stamp(int(got[-1]))
            # deadline + cooperative cancellation, at chunk-boundary
            # granularity: reaped lanes release pages like any other
            # completion; queued requests are dropped before admission
            now = time.time()
            for lane, req in list(live.items()):
                timed_out = req.deadline_s is not None and \
                    now - req.submitted_at > req.deadline_s
                if not (req.cancel_requested or timed_out):
                    continue
                status = "cancelled" if req.cancel_requested else "timeout"
                del live[lane]
                release[lane] = True
                batcher.complete(req, status, RequestError(
                    "cancelled" if status == "cancelled"
                    else "deadline_exceeded",
                    f"reaped at step {batcher.step_idx + stride}"))
            for req in [q for q in batcher.queue
                        if q.cancel_requested or
                        (q.deadline_s is not None and
                         now - q.submitted_at > q.deadline_s)]:
                status = "cancelled" if req.cancel_requested else "timeout"
                batcher.drop_queued(
                    req, status,
                    "cancelled" if status == "cancelled"
                    else "deadline_exceeded",
                    "reaped while queued")
            # a released lane's staged plan rows are garbage for any
            # successor tenant — stale until the next chunk masks them
            stale_np |= release
            if release.any():
                # ONE masked release per boundary covers every
                # completion in the chunk — including instant
                # budget-1/EOS crossings, which used to cost a separate
                # device call each at admission
                self.state = self._release_jit(self.state,
                                               jnp.asarray(release))
            delta = faults.pool_delta(step0, stride)
            if delta:
                batcher.resize_pool(delta)
            batcher.step_idx += stride
            # SLO shedding runs AFTER deadline/cancel reaping (so a
            # request is never both "timeout" and SLO-shed) and before
            # admission refills the freed lanes
            shed_slo()
            admit()
            view = batcher.device_view()
        return ServeReport.build(batcher.completed, batcher.rejected,
                                 events, eos_id=cfg.eos_id)

    def _measure_migration_spec(self, geo, *, iters: int = 5):
        """Microbenchmark the jitted migration commit and derive a spec
        whose link bandwidth is MEASURED rather than modeled.

        Times `apply_migrations` on a synthetic full-capacity swap plan
        (every row a promote+demote pair, so each row moves one page
        across the link in each direction) against the all-sentinel
        empty plan over the same cache — the delta isolates the
        per-page move cost from fixed dispatch overhead. The latency
        model prices a move at `1/link_bw + 1/hbm_bw` seconds per byte
        (repro.core.placement.cost_aware), so the measured
        seconds-per-byte inverts to a link bandwidth; the returned spec
        is `cfg.spec` with `link_bw` replaced and the name suffixed
        "+measured". Only cost_aware's payback thresholds consume this
        — Eq.(1)-(5) telemetry pricing stays on the modeled spec.

        Runs on the default device even under a mesh (the commit is a
        per-shard local scatter; a single-device measurement is the
        per-shard cost). Returns `(spec_or_None, detail)`: None when
        the measurement cannot be inverted — timer noise drives the
        delta non-positive, or the implied per-byte cost lands under
        the modeled HBM floor — and the caller stays fully modeled.
        `detail` is the `payback_measured` event payload either way.
        """
        base = self.cfg.spec
        cap = control.plan_capacity(geo, self.cfg.migration_budget_frac)
        L, B = geo.num_layers, geo.batch
        r = np.arange(cap, dtype=np.int32)
        pro_src = r % geo.host_pages
        pro_dst = r % geo.hbm_pages
        lay = jnp.asarray(r % L)
        bat = jnp.asarray((r // L) % B)
        plan = MigrationPlan(
            lay, bat, jnp.asarray(pro_src), jnp.asarray(pro_dst),
            jnp.asarray(r % geo.max_pages),
            lay, bat, jnp.asarray(pro_dst), jnp.asarray(pro_src),
            jnp.asarray((r + 1) % geo.max_pages))
        empty = MigrationPlan.empty(cap)
        host_kind = self._host_memory_kind if self.mesh is None else None
        cache = init_cache(geo, host_kind=host_kind)
        # jit a LOCAL wrapper, not `apply_migrations` itself: jax's
        # tracing cache keys on the wrapped function object, so jitting
        # the module-level function here would leave this measurement's
        # entry behind in every later `jax.jit(apply_migrations)`
        fn = jax.jit(lambda c, p: apply_migrations(c, p))
        # compile + warm both variants outside the timed region
        jax.block_until_ready(fn(cache, plan))
        jax.block_until_ready(fn(cache, empty))

        def best(p):
            t = float("inf")
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(cache, p))
                t = min(t, time.perf_counter() - t0)
            return t

        delta = best(plan) - best(empty)
        moved = 2 * cap * geo.page_bytes()
        detail = {"rows": int(cap), "bytes": int(moved),
                  "delta_s": float(delta),
                  "modeled_link_bw": float(base.link_bw),
                  "measured_link_bw": None}
        if delta <= 0.0 or moved == 0:
            return None, detail
        inv_link = delta / moved - 1.0 / base.hbm_bw
        if inv_link <= 0.0:
            return None, detail
        link_bw = 1.0 / inv_link
        detail["measured_link_bw"] = float(link_bw)
        spec = dataclasses.replace(base, name=base.name + "+measured",
                                   link_bw=link_bw)
        return spec, detail

    def _admit_lane(self, req: Request, hs: Dict) -> None:
        """Bind an admitted request to its cache lane for CHUNKED
        prefill: pure host bookkeeping — the prompt row, the progress
        counters (via `req.prefilled`, exported by `device_view`), and
        the lane's sampling key. No device compute, no model forward,
        no per-prompt-length compiles; the prompt starts flowing into
        the lane's pages at the next chunk's mixed steps. (The
        eager-admission baseline in benchmarks/perf_engine.py overrides
        this with the PR 2 whole-prompt forward + `insert_lane`.)"""
        lane = req.lane
        prompt = np.asarray(req.prompt).astype(np.int32).ravel()
        hs["prompt_buf"][lane, :] = 0
        hs["prompt_buf"][lane, :prompt.size] = prompt
        hs["token"][lane] = 0
        hs["keys"][lane] = np.asarray(
            lane_key(hs["root"], jnp.int32(req.rid)))

    # ------------------------------------------------------------------ #
    # telemetry (host side, Eq. (1)-(5) pricing)
    # ------------------------------------------------------------------ #
    def _record(self, stats, specs=None):
        """Price a batch of per-step device telemetry into `self.stats`.

        stats: a tuple off the device — `(base,)` or, with
        `cfg.trace_telemetry`, `(base, access, tier)` where base is
        [n, 4] int32 rows of (hbm_pages, host_pages, promotes, demotes)
        and access/tier are the per-step [n, L, B, P] page read set and
        placement; lane 0 is kept raw for the single-stream bridge
        (`trace_bridge.collect` — serve capture goes through
        `_serve_trace_log` instead, with all lanes).

        `specs`: optional per-row `MemorySystemSpec` list — under a
        tier fault, `serve` prices each surviving row with the
        (degraded) spec governing its step instead of `cfg.spec`, so
        the modeled latency of a degraded window is honest."""
        if len(stats) == 3:
            self._trace_log.append(
                (stats[0], stats[1][:, :, 0], stats[2][:, :, 0]))
        stats = stats[0]
        geo = self.geo
        pb = geo.page_bytes()
        frac = 1.0 - self.cfg.attention_sparsity
        for i, (h_pages, e_pages, n_pro, n_dem) in enumerate(stats):
            spec = specs[i] if specs is not None else self.cfg.spec
            traffic = dict(
                h_read=float(h_pages) * pb * frac,
                e_read=float(e_pages) * pb * frac,
                m_in=float(n_pro) * pb, m_out=float(n_dem) * pb,
                h_write=pb / geo.page_tokens, e_write=0.0)
            lat = float(step_latency(StepTraffic(**traffic), spec))
            denom = traffic["h_read"] + traffic["e_read"]
            self.stats.append(StepStats(
                modeled_latency_s=lat,
                h_read=traffic["h_read"], e_read=traffic["e_read"],
                m_in=traffic["m_in"], m_out=traffic["m_out"],
                hbm_hit_rate=traffic["h_read"] / denom if denom else 1.0))

    def summary(self) -> Dict[str, float]:
        """Aggregate the recorded StepStats: step count, modeled total
        seconds and tokens/s, mean HBM hit rate, migrated bytes."""
        if not self.stats:
            return {}
        lat = np.array([s.modeled_latency_s for s in self.stats])
        return {
            "steps": len(self.stats),
            "modeled_total_s": float(lat.sum()),
            "modeled_tokens_per_s": len(lat) / float(lat.sum()),
            "mean_hbm_hit_rate": float(np.mean(
                [s.hbm_hit_rate for s in self.stats])),
            "migrated_bytes": float(sum(s.m_in + s.m_out
                                        for s in self.stats)),
        }
