"""Serving engine: the paper's dynamic KV placement as a live feature.

The entire decode step runs as ONE jitted, statically-shaped program on
device (see `repro.serving.control` and EXPERIMENTS.md §Fused-engine):

  1. control plane (jit): write-slot selection, Quest-style top-k page
     masking, and the importance-EMA migration planner, vectorized over
     [L, B] — no Python loops, no host round-trips.
  2. data plane (jit): `decode_step` over the two-tier paged cache;
     per-page attention-mass importance stats fall out of the attention
     kernel for free.
  3. data plane (jit): `apply_migrations` executes a FIXED-capacity
     `MigrationPlan` (capacity depends only on geometry and
     `migration_budget_frac`), so it compiles exactly once.
  4. telemetry: the step emits a tiny [4] int32 vector (resident HBM /
     host pages, promotes, demotes); the host prices it with the
     paper's Eq.(1)-(5) under a `MemorySystemSpec`.

Drive modes share the identical step function, so their logits are
bitwise identical and their byte accounting matches exactly:

  eager  `step(token)`         — one jitted call + host readback per
                                 token (the debugging / reference path)
  fused  `run(tokens)` /       — `lax.scan` over chunks of
         `generate(token, n)`    `telemetry_stride` steps with the
                                 cache donated; the host reads back one
                                 [stride, 4] stats array per chunk.
  serve  `serve(requests)`     — the headline API: continuous batching
                                 over the same fused chunks with
                                 per-slot active masks, on-device
                                 sampling (temperature/top-k/top-p,
                                 greedy at temperature 0) and per-slot
                                 EOS/budget stop conditions; admission,
                                 completion and page reclaim happen at
                                 chunk boundaries without retracing.

Engine policies: "static" (never migrate) and "importance" (cost-aware
hysteresis on the attention-mass EMA — our deployable beyond-paper
policy).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.latency_model import StepTraffic, step_latency
from repro.core.tiers import MemorySystemSpec, TPU_V5E
from repro.kvcache.migrate import apply_migrations
from repro.kvcache.paged import PagedKVCache, init_cache, prefill_cache
from repro.models.model import Model
from repro.serving import control
from repro.serving.sampling import SamplingConfig, make_sampler, split_lanes
from repro.serving.scheduler import ContinuousBatcher, Request


@dataclasses.dataclass
class EngineConfig:
    max_context: int = 512
    hbm_fraction: float = 0.25
    policy: str = "importance"
    #: fraction of pages bypassed at attention (0 = dense attention)
    attention_sparsity: float = 0.0
    #: migration budget per step, as a fraction of HBM pages
    migration_budget_frac: float = 0.1
    promote_thresh: float = 0.02     # attention-mass EMA threshold
    spec: MemorySystemSpec = TPU_V5E
    #: fused-mode scan length: decode steps run on device between
    #: telemetry readbacks (1 = eager cadence, larger = fewer syncs)
    telemetry_stride: int = 32
    #: stop token for `serve` (None = budget-only completion)
    eos_id: Optional[int] = None


@dataclasses.dataclass
class StepStats:
    modeled_latency_s: float
    h_read: float
    e_read: float
    m_in: float
    m_out: float
    hbm_hit_rate: float


def _get_cache(state) -> PagedKVCache:
    return state if isinstance(state, PagedKVCache) else state["kv"]


def _set_cache(state, cache):
    if isinstance(state, PagedKVCache):
        return cache
    return {**state, "kv": cache}


class ServingEngine:
    def __init__(self, model: Model, params, cfg: EngineConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.stats: List[StepStats] = []
        self._sampling = SamplingConfig()

    # ------------------------------------------------------------------ #
    def start(self, prompts: jax.Array, extra=None):
        geo = self.model.cache_geometry(
            prompts.shape[0], self.cfg.max_context,
            hbm_fraction=self.cfg.hbm_fraction)
        self.geo = geo
        logits, state = self.model.prefill(self.params, prompts, geo,
                                           extra=extra)
        self.state = state
        self._ensure_step_fns()
        return logits

    @property
    def _cache(self) -> PagedKVCache:
        return _get_cache(self.state)

    # ------------------------------------------------------------------ #
    # the fused step: control plane + data plane + migration, all jit
    # ------------------------------------------------------------------ #
    def _ensure_step_fns(self):
        """(Re)build the jitted step functions only when the cache
        geometry, sampling config, or engine config changed, so repeated
        `serve`/`start` calls over the same shapes reuse the compiled
        executables (cfg is part of the key because the step closures
        bake in policy/threshold/stride/eos)."""
        key = (self.geo, self._sampling, dataclasses.astuple(self.cfg))
        if getattr(self, "_fns_key", None) != key:
            self._build_step_fns()
            self._fns_key = key

    def _build_step_fns(self):
        cfg, model, geo = self.cfg, self.model, self.geo
        sparsity = cfg.attention_sparsity
        fam = model.cfg.family
        has_cache = fam in ("dense", "vlm", "moe", "encdec") or (
            fam in ("ssm", "hybrid")
            and bool(model.cfg.attention_layer_ids()))
        masked = sparsity > 0 and has_cache
        migrate = cfg.policy != "static"
        budget = control.migration_budget(geo, cfg.migration_budget_frac)
        thresh = cfg.promote_thresh
        eos = cfg.eos_id
        sampler = make_sampler(self._sampling)
        self._sampler = sampler

        def step_fn(params, state, token, active=None):
            cache = _get_cache(state)
            kwargs = {"write_slot": control.choose_write_slot(cache)}
            if masked:
                kwargs["logical_page_mask"] = control.quest_page_mask(
                    cache, sparsity)
            logits, state = model.decode_step(params, state, token,
                                              **kwargs)
            if active is not None:
                # per-slot masking: inactive lanes keep their pre-step
                # cache verbatim (no token write, no length bump)
                state = _set_cache(state, control.lane_merge(
                    cache, _get_cache(state), active))
            cache = _get_cache(state)
            # read traffic is counted on post-decode, pre-migration
            # residency (the step's attention read the old placement)
            occ = control.occupancy(cache)
            if migrate:
                plan, n_pro, n_dem = control.plan_migrations(
                    cache, budget=budget, promote_thresh=thresh,
                    active=active)
                state = _set_cache(state, apply_migrations(cache, plan))
                moves = jnp.stack([n_pro, n_dem]).astype(jnp.int32)
            else:
                moves = jnp.zeros((2,), jnp.int32)
            return logits, state, jnp.concatenate([occ, moves])

        def chunk_fn(params, state, tokens):
            """Teacher-forced fused decode over tokens [n, B]."""
            def body(st, tok):
                logits, st, stats = step_fn(params, st, tok)
                return st, (logits, stats)
            state, (logits, stats) = jax.lax.scan(body, state, tokens)
            return state, logits, stats

        def gen_fn(params, state, token, n):
            """Greedy self-feeding fused decode for n steps."""
            def body(carry, _):
                st, tok = carry
                logits, st, stats = step_fn(params, st, tok)
                nxt = jnp.argmax(logits, -1).astype(jnp.int32)
                return (st, nxt), (nxt, stats)
            (state, token), (toks, stats) = jax.lax.scan(
                body, (state, token), None, length=n)
            return state, token, toks, stats

        def serve_chunk_fn(params, state, token, active, remaining, keys):
            """Sampled, per-slot-masked fused decode for one chunk.

            Carries per-slot (token, active, remaining budget, PRNG key)
            through `lax.scan`; emits -1 for inactive lanes. Completion
            (EOS / budget) flips the lane's active bit on device; the
            host reclaims and re-admits at the chunk boundary.
            """
            def body(carry, _):
                st, tok, act, rem, ks = carry
                logits, st, stats = step_fn(params, st, tok, act)
                ks, sub = split_lanes(ks)
                nxt = sampler(logits, sub)
                rem = rem - act.astype(rem.dtype)
                fin = act & (rem <= 0)
                if eos is not None:
                    fin = fin | (act & (nxt == eos))
                emitted = jnp.where(act, nxt, -1)
                tok = jnp.where(act, nxt, tok)
                act = act & ~fin
                return (st, tok, act, rem, ks), (emitted, stats)

            carry = (state, token, active, remaining, keys)
            carry, (emitted, stats) = jax.lax.scan(
                body, carry, None, length=max(1, cfg.telemetry_stride))
            state, token, active, remaining, keys = carry
            return state, token, active, remaining, keys, emitted, stats

        self._step_jit = jax.jit(step_fn, donate_argnums=(1,))
        self._chunk_jit = jax.jit(chunk_fn, donate_argnums=(1,))
        self._gen_jit = jax.jit(gen_fn, donate_argnums=(1,),
                                static_argnums=(3,))
        self._serve_jit = jax.jit(serve_chunk_fn, donate_argnums=(1,))
        self._insert_jit = jax.jit(control.insert_lane, donate_argnums=(0,))
        self._release_jit = jax.jit(control.release_lanes,
                                    donate_argnums=(0,))

    # ------------------------------------------------------------------ #
    # drive modes
    # ------------------------------------------------------------------ #
    def step(self, token: jax.Array) -> jax.Array:
        """Eager: one device dispatch + one telemetry sync per token."""
        logits, self.state, stats = self._step_jit(
            self.params, self.state, token)
        self._record(np.asarray(stats)[None])
        return logits

    def run(self, tokens: jax.Array) -> jax.Array:
        """Fused teacher-forced decode. tokens [K, B] -> logits [K, B, V].

        Runs `lax.scan` chunks of `telemetry_stride` steps; telemetry is
        read back once per chunk. Produces bitwise-identical logits and
        identical StepStats accounting to K calls of `step()`.
        """
        K = tokens.shape[0]
        if K == 0:
            return jnp.zeros((0, tokens.shape[1], self.model.cfg.vocab))
        stride = max(1, self.cfg.telemetry_stride)
        out = []
        for s in range(0, K, stride):
            self.state, logits, stats = self._chunk_jit(
                self.params, self.state, tokens[s:s + stride])
            self._record(np.asarray(stats))
            out.append(logits)
        return out[0] if len(out) == 1 else jnp.concatenate(out, axis=0)

    def generate(self, token: jax.Array, steps: int) -> jax.Array:
        """Fused greedy generation from `token` [B] -> tokens [steps, B]."""
        if steps == 0:
            return jnp.zeros((0,) + token.shape, jnp.int32)
        stride = max(1, self.cfg.telemetry_stride)
        out = []
        done = 0
        while done < steps:
            n = min(stride, steps - done)
            self.state, token, toks, stats = self._gen_jit(
                self.params, self.state, token, n)
            self._record(np.asarray(stats))
            out.append(toks)
            done += n
        return out[0] if len(out) == 1 else jnp.concatenate(out, axis=0)

    # ------------------------------------------------------------------ #
    # continuous-batching serve loop (the headline API)
    # ------------------------------------------------------------------ #
    def serve(self, requests: Sequence[Request], *,
              num_slots: Optional[int] = None,
              sampling: Optional[SamplingConfig] = None,
              seed: int = 0, total_pages: Optional[int] = None,
              max_skips: int = 8) -> List[Request]:
        """Drive a request stream end-to-end through the fused hot path.

        A fixed batch of `num_slots` cache lanes decodes as ONE jitted
        `lax.scan` chunk per `telemetry_stride` steps; per-slot active
        masks keep finished/empty lanes bitwise-frozen inside the chunk,
        so admissions and completions (at chunk boundaries) never change
        traced shapes — zero retraces across the whole stream.

        Per chunk boundary the host: reads back emitted tokens + the
        per-slot (active, remaining) view, completes finished requests
        (EOS or budget, decided ON DEVICE), releases their pages into
        the planner's free pool (`control.release_lanes`), and admits
        queued requests (`ContinuousBatcher.admit` -> per-request
        prefill -> `control.insert_lane`).

        Sampling (temperature / top-k / top-p) runs inside the fused
        loop with per-slot PRNG keys derived from (`seed`, request id);
        the default `SamplingConfig()` is greedy, and a single
        full-length request then reproduces `generate` bitwise.

        Returns the completed requests (token ids in `req.output`).
        """
        cfg = self.cfg
        fam = self.model.cfg.family
        if fam not in ("dense", "moe"):
            raise NotImplementedError(
                f"serve() drives cache-backed decode states (dense/moe); "
                f"family {fam!r} needs prefill extras or recurrent-state "
                f"lane insertion")
        if not requests:
            return []
        B = num_slots if num_slots is not None else min(len(requests), 4)
        geo = self.model.cache_geometry(
            B, cfg.max_context, hbm_fraction=cfg.hbm_fraction)
        for r in requests:
            if r.prompt is None:
                raise ValueError(
                    f"request {r.rid}: serve() needs prompt tokens")
            if r.max_new_tokens < 1:
                raise ValueError(
                    f"request {r.rid}: max_new_tokens must be >= 1")
            if r.prompt_len + r.max_new_tokens > geo.max_tokens:
                raise ValueError(
                    f"request {r.rid}: {r.prompt_len}+{r.max_new_tokens} "
                    f"tokens exceed cache capacity {geo.max_tokens}")
        self.geo = geo
        self.state = init_cache(geo)
        self.stats = []
        self._sampling = sampling or SamplingConfig()
        self._ensure_step_fns()

        pool = total_pages if total_pages is not None \
            else B * geo.max_pages
        batcher = ContinuousBatcher(B, pool, page_tokens=geo.page_tokens,
                                    max_skips=max_skips)
        self.batcher = batcher
        for r in requests:
            batcher.submit(r)

        root = jax.random.PRNGKey(seed)
        keys = jax.random.split(root, B)
        token = np.zeros((B,), np.int32)
        stride = max(1, cfg.telemetry_stride)
        live: Dict[int, Request] = {}          # lane -> request

        def admit():
            """Admit until no progress: an admission that completes at
            its first token (budget 1 / instant EOS) frees its slot for
            the next queued request within the same boundary."""
            nonlocal keys
            while True:
                admitted = batcher.admit()
                if not admitted:
                    return
                for req in admitted:
                    lane = req.lane
                    rkey = jax.random.fold_in(root, req.rid)
                    rkey, sub = jax.random.split(rkey)
                    logits1, lane_cache = self._prefill_lane(req)
                    self.state = self._insert_jit(self.state, lane_cache,
                                                  jnp.int32(lane))
                    # first token comes from the prefill logits
                    tok0 = int(self._sampler(logits1[None], sub[None])[0])
                    req.output.append(tok0)
                    req.generated = 1
                    keys = keys.at[lane].set(rkey)
                    done = (req.generated >= req.max_new_tokens
                            or (cfg.eos_id is not None
                                and tok0 == cfg.eos_id))
                    if done:
                        self.state = self._release_jit(
                            self.state, jnp.asarray(np.arange(B) == lane))
                        batcher.complete(req)
                    else:
                        live[lane] = req
                        token[lane] = tok0

        def carry_view():
            """The batcher's device-facing view IS the chunk carry: at a
            boundary `generated` is synced, so remaining/active match
            the device bitwise."""
            view = batcher.device_view()
            return view.active, view.remaining

        admit()
        active, remaining = carry_view()
        while batcher.has_work:
            if not active.any():
                stuck = batcher.queue[0]
                raise RuntimeError(
                    f"request {stuck.rid} needs {stuck.pages_needed} pages"
                    f" but the pool has only {batcher.total_pages}")
            (self.state, tok_d, act_d, _rem_d, keys, emitted,
             stats) = self._serve_jit(
                self.params, self.state, jnp.asarray(token),
                jnp.asarray(active), jnp.asarray(remaining), keys)
            emitted = np.asarray(emitted)               # [stride, B]
            token = np.array(tok_d)                     # writable copy:
            done_d = ~np.asarray(act_d)                 # admit() pokes it
            # telemetry: only steps where at least one lane decoded
            self._record(np.asarray(stats)[emitted.max(axis=1) >= 0])
            release = np.zeros((B,), bool)
            for lane, req in list(live.items()):
                toks = emitted[:, lane]
                toks = toks[toks >= 0]
                req.output.extend(int(t) for t in toks)
                req.generated += len(toks)
                if done_d[lane]:      # EOS/budget decided on device
                    del live[lane]
                    release[lane] = True
                    batcher.complete(req)
            if release.any():
                self.state = self._release_jit(self.state,
                                               jnp.asarray(release))
            batcher.step_idx += stride
            admit()
            active, remaining = carry_view()
        return batcher.completed

    def _prefill_lane(self, req: Request):
        """Prefill one request into a batch-1 cache lane.

        The prompt is right-padded to a page boundary so admission
        compiles once per page-rounded prompt length: under causal
        attention the pads influence nothing at positions < prompt_len,
        the padded tail of the last page sits behind the page's valid
        count (invisible to the kernel), and decode overwrites it as
        the sequence grows. Returns (last-prompt-position logits [V],
        batch-1 PagedKVCache).
        """
        geo = self.geo
        S = req.prompt_len
        pad = (-S) % geo.page_tokens
        prompt = jnp.asarray(np.asarray(req.prompt),
                             jnp.int32).reshape(1, -1)
        if pad:
            prompt = jnp.pad(prompt, ((0, 0), (0, pad)))
        geo1 = dataclasses.replace(geo, batch=1)
        logits, (k, v) = self.model.forward(self.params, prompt,
                                            collect_kv=True)
        return logits[0, S - 1], prefill_cache(geo1, k, v, S)

    # ------------------------------------------------------------------ #
    # telemetry (host side, Eq. (1)-(5) pricing)
    # ------------------------------------------------------------------ #
    def _record(self, stats: np.ndarray):
        """stats: [n, 4] int32 rows of (hbm_pages, host_pages, promotes,
        demotes) straight off the device."""
        geo = self.geo
        pb = geo.page_bytes()
        frac = 1.0 - self.cfg.attention_sparsity
        for h_pages, e_pages, n_pro, n_dem in stats:
            traffic = dict(
                h_read=float(h_pages) * pb * frac,
                e_read=float(e_pages) * pb * frac,
                m_in=float(n_pro) * pb, m_out=float(n_dem) * pb,
                h_write=pb / geo.page_tokens, e_write=0.0)
            lat = float(step_latency(StepTraffic(**traffic), self.cfg.spec))
            denom = traffic["h_read"] + traffic["e_read"]
            self.stats.append(StepStats(
                modeled_latency_s=lat,
                h_read=traffic["h_read"], e_read=traffic["e_read"],
                m_in=traffic["m_in"], m_out=traffic["m_out"],
                hbm_hit_rate=traffic["h_read"] / denom if denom else 1.0))

    def summary(self) -> Dict[str, float]:
        if not self.stats:
            return {}
        lat = np.array([s.modeled_latency_s for s in self.stats])
        return {
            "steps": len(self.stats),
            "modeled_total_s": float(lat.sum()),
            "modeled_tokens_per_s": len(lat) / float(lat.sum()),
            "mean_hbm_hit_rate": float(np.mean(
                [s.hbm_hit_rate for s in self.stats])),
            "migrated_bytes": float(sum(s.m_in + s.m_out
                                        for s in self.stats)),
        }
