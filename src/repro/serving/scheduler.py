"""Request scheduler: admission + continuous-batching bookkeeping.

Serving at scale needs more than a decode loop: requests arrive with
different prompt lengths and budgets, finish at different times, and
their KV pages must be reclaimed. This scheduler keeps a fixed-size
batch of live slots over the engine's paged cache:

  * admission — a request is admitted when a batch slot AND enough free
    logical pages exist (prompt + expected decode length);
  * completion — finished slots release their pages; the next queued
    request is admitted without stopping the batch (continuous
    batching, Sarathi/vLLM-style at step granularity);
  * fairness — FIFO with a starvation bound (max_skips).

Each request walks a lane state machine, mirrored on device by the
mixed prefill+decode serve loop (PR 3):

  queued -> prefilling -> decoding -> done

Admission binds a lane and starts CHUNKED prefill: the lane consumes a
fixed token-budget slice of its prompt per fused step (`prefilled`
tracks progress) while other lanes decode; the first output token is
sampled on device at the step prefill crosses `prompt_len`
("decoding"), and EOS/budget completion frees the lane ("done").
Wall-clock stamps (`submitted_at` / `first_token_at` / `finished_at`)
feed the TTFT/TPOT percentiles in `ServeReport`.

The scheduler is pure control plane: it never touches arrays. Two ways
to drive it:

  * `step()` — the self-contained behavioural simulation (admit, count
    one generated token per live request, complete on budget);
  * `admit()` / `complete()` / `device_view()` — the engine-facing
    protocol used by `ServingEngine.serve`: the ENGINE decides when a
    request finishes (EOS or budget, observed on device) and calls
    `complete`; at every chunk boundary `device_view` exports the
    per-slot active mask, remaining-token budgets, and slot->cache-lane
    bindings that become the fused decode loop's carry.

Page accounting uses the engine's real page size (`page_tokens`,
stamped onto each request at submit) so the scheduler can never
diverge from the cache geometry. Exercised by tests/test_serving.py
and tests/test_serve_loop.py.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

#: the exhaustive per-request dispositions (`Request.status`). Every
#: request that enters `ServingEngine.serve` (or is refused at submit)
#: ends in exactly one of these — the engine never raises mid-stream on
#: a per-request condition.
TERMINAL_STATUSES = ("ok", "rejected", "failed", "cancelled", "timeout")


@dataclasses.dataclass(frozen=True)
class RequestError:
    """Typed per-request error record, attached to `Request.error`
    whenever the terminal status is not "ok".

    code — machine-readable reason (e.g. "empty_prompt", "zero_budget",
           "infeasible_pages", "infeasible_context", "duplicate_rid",
           "poisoned_logits", "deadline_exceeded", "cancelled").
    detail — human-readable context for the report/logs.
    """

    code: str
    detail: str = ""


@dataclasses.dataclass
class Request:
    """One serving request: identity (`rid`), prompt, decode budget,
    and the per-run mutable bookkeeping the scheduler/engine stamp
    onto it (lane binding, phase, generated tokens, wall-clock
    latency marks). Reset on every `ContinuousBatcher.submit`, so a
    Request object can be re-submitted across serve calls."""

    rid: int
    prompt_len: int = 0
    max_new_tokens: int = 16
    #: prompt token ids (any int sequence) — required for real serving
    #: via `ServingEngine.serve`; optional for scheduler-only sims.
    prompt: Optional[object] = None
    #: page size used for page accounting; stamped by the batcher at
    #: submit so it always matches the engine's cache geometry.
    page_tokens: int = 16
    arrived_step: int = 0
    started_step: int = -1
    finished_step: int = -1
    generated: int = 0
    #: cache lane (batch row) bound while live; -1 when not in a slot
    lane: int = -1
    #: generated token ids (filled by the serving engine)
    output: List[int] = dataclasses.field(default_factory=list)
    #: lane state machine: queued -> prefilling -> decoding -> done
    phase: str = "queued"
    #: prompt tokens already consumed by chunked prefill
    prefilled: int = 0
    #: wall-clock request-latency stamps (TTFT = first_token_at -
    #: submitted_at; TPOT from first_token_at/finished_at/generated)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    #: terminal disposition ("pending" while in flight; ends in one of
    #: TERMINAL_STATUSES — see module constant)
    status: str = "pending"
    #: typed reason whenever status != "ok"
    error: Optional[RequestError] = None
    #: wall-clock deadline in seconds from submit (None = no deadline);
    #: checked by the engine at chunk boundaries -> status "timeout"
    deadline_s: Optional[float] = None
    #: cooperative cancellation flag (set via `cancel()`); honored by
    #: the engine at chunk boundaries -> status "cancelled"
    cancel_requested: bool = False
    #: open-loop arrival offset in seconds from stream start (the
    #: workload plane stamps this; `serve` submits the request at the
    #: first chunk boundary whose wall clock passes it — 0.0 = submit
    #: immediately, the pre-workload behavior)
    arrival_s: float = 0.0
    #: priority tier name (workload plane); an `SLOPolicy` maps it to
    #: per-tier TTFT/TPOT targets. None = no tier (never SLO-shed).
    tier: Optional[str] = None
    #: wall-clock instant the lane's first chunk started running —
    #: TTFT decomposes as queue_wait (admitted_at - submitted_at)
    #: + prefill_s + throttle_s (stamped by the engine; see
    #: EXPERIMENTS.md §Workloads)
    admitted_at: Optional[float] = None
    #: seconds of serve steps that consumed this request's prompt
    prefill_s: float = 0.0
    #: seconds the admitted lane sat prefill-stalled: prefill-budget
    #: bucket starvation plus chunk-boundary host overhead
    throttle_s: float = 0.0
    #: why an "ok" request stopped: "eos" | "budget" (None otherwise)
    stop_reason: Optional[str] = None

    @property
    def queue_wait_s(self) -> Optional[float]:
        """Seconds from submit to the lane's first serve chunk (None
        until admitted)."""
        if self.admitted_at is None:
            return None
        return self.admitted_at - self.submitted_at

    def cancel(self) -> None:
        """Request cooperative cancellation: the engine reaps the
        request at the next chunk boundary (queued requests are
        dropped immediately; live ones release their lane + pages)."""
        self.cancel_requested = True

    def __post_init__(self):
        if self.prompt is not None and not self.prompt_len:
            self.prompt_len = int(np.asarray(self.prompt).shape[-1])

    @property
    def pages_needed(self) -> int:
        """KV pages this request needs end-to-end (prompt + full decode
        budget), under the page size stamped at submit."""
        return -(-(self.prompt_len + self.max_new_tokens)
                 // self.page_tokens)


@dataclasses.dataclass
class SlotState:
    """One batch slot: the live request bound to it, or None if free."""

    request: Optional[Request] = None

    @property
    def free(self) -> bool:
        """Whether the slot can accept an admission."""
        return self.request is None


@dataclasses.dataclass
class DeviceView:
    """Device-facing snapshot of the batch: what the fused mixed
    prefill+decode loop needs to know, as arrays (see
    ServingEngine.serve). The per-lane mode (prefilling vs decoding) is
    derived ON DEVICE as `prefilled < prompt_len`, so the view is also
    the chunk carry."""
    active: np.ndarray       # [num_slots] bool — slot has a live request
    remaining: np.ndarray    # [num_slots] int32 — token budget left
    rids: np.ndarray         # [num_slots] int32 — request id, -1 if free
    prompt_len: np.ndarray   # [num_slots] int32 — prompt tokens, 0 if free
    prefilled: np.ndarray    # [num_slots] int32 — prompt progress
    lane_of: Dict[int, int]  # rid -> cache lane (page-table binding)


class ContinuousBatcher:
    """Fixed-slot continuous-batching scheduler over the paged cache
    (admission / completion / fairness — see the module docstring).
    Pure control plane: never touches arrays; the engine drives it via
    `admit`/`complete`/`device_view` at chunk boundaries."""

    def __init__(self, num_slots: int, total_pages: int,
                 page_tokens: int = 16, max_skips: int = 8):
        self.slots: List[SlotState] = [SlotState() for _ in range(num_slots)]
        self.total_pages = total_pages
        self.free_pages = total_pages
        self.page_tokens = page_tokens
        self.queue: Deque[Request] = deque()
        self.max_skips = max_skips
        self.step_idx = 0
        self.completed: List[Request] = []
        #: requests refused at submit/admission (never held a slot);
        #: each carries status="rejected" and a typed `error`
        self.rejected: List[Request] = []
        #: lane<->request attribution ledger: one row per admission,
        #: in admission order. Lane indices are REUSED across the
        #: stream, so request identity over time comes from these
        #: bindings (+ the per-chunk `DeviceView.rids` stamps the
        #: engine logs) — the trace bridge's per-request stitching
        #: relies on exactly this: a lane's telemetry belongs to
        #: whichever request was bound at that step, never to the
        #: lane number itself.
        self.bindings: List[Dict[str, int]] = []

    # ------------------------------------------------------------------ #
    def reject(self, req: Request, code: str, detail: str = "") -> None:
        """Refuse a request with a typed error record: status
        "rejected", never occupies a slot, lands in `self.rejected`.
        Also the path for reaping QUEUED requests (deadline/cancel
        before admission) — the stream keeps serving everyone else."""
        req.status = "rejected"
        req.error = RequestError(code=code, detail=detail)
        req.phase = "done"
        req.finished_step = self.step_idx
        req.finished_at = time.time()
        self.rejected.append(req)

    def drop_queued(self, req: Request, status: str, code: str,
                    detail: str = "") -> None:
        """Reap a QUEUED request with a terminal status ("cancelled" /
        "timeout"): removed from the queue, no pages to release, lands
        in `rejected` (it never held a slot)."""
        assert status in TERMINAL_STATUSES and status != "ok", status
        self.queue.remove(req)
        req.status = status
        req.error = RequestError(code=code, detail=detail)
        req.phase = "done"
        req.finished_step = self.step_idx
        req.finished_at = time.time()
        self.rejected.append(req)

    def _reset_run_state(self, req: Request) -> None:
        """Reset per-run mutable state so a Request object can be
        re-submitted (fresh serve call / sim) without carrying the
        previous run's tokens, bindings, or disposition."""
        req.page_tokens = self.page_tokens
        req.arrived_step = self.step_idx
        req.started_step = -1
        req.finished_step = -1
        req.generated = 0
        req.lane = -1
        req.output = []
        req.phase = "queued"
        req.prefilled = 0
        req.submitted_at = time.time()
        req.first_token_at = None
        req.finished_at = None
        req.status = "pending"
        req.error = None
        req.cancel_requested = False
        req.admitted_at = None
        req.prefill_s = 0.0
        req.throttle_s = 0.0
        req.stop_reason = None

    def reject_submit(self, req: Request, code: str,
                      detail: str = "") -> None:
        """Reset + reject in one step — for callers (the engine) that
        validate request CONTENTS (prompt presence, decode budget,
        cache-capacity fit) above the scheduler's pool accounting."""
        self._reset_run_state(req)
        self.reject(req, code, detail)

    def submit(self, req: Request) -> bool:
        """Queue a request (FIFO) and reset its per-run state.

        Returns True when queued. Requests that can NEVER be served —
        duplicate rid against a queued/live request (the bindings
        ledger and `complete()` match by rid, so a duplicate would
        corrupt per-request attribution), or a page footprint larger
        than the whole pool — are rejected with a typed error instead
        of poisoning the stream; the caller's other requests proceed.
        """
        self._reset_run_state(req)
        live = {s.request.rid for s in self.slots if s.request is not None}
        if any(q.rid == req.rid for q in self.queue) or req.rid in live:
            self.reject(req, "duplicate_rid",
                        f"rid {req.rid} already queued or live")
            return False
        if req.pages_needed > self.total_pages:
            self.reject(
                req, "infeasible_pages",
                f"needs {req.pages_needed} pages, pool has "
                f"{self.total_pages}")
            return False
        self.queue.append(req)
        return True

    def admit(self) -> List[Request]:
        """Admit queued requests into free slots (FIFO, starvation-bounded
        leapfrogging). Returns the newly admitted requests, each with its
        `lane` binding set."""
        skips = 0
        admitted: List[Request] = []
        requeue: List[Request] = []
        while self.queue and skips <= self.max_skips:
            lane = next((i for i, s in enumerate(self.slots) if s.free),
                        None)
            if lane is None:
                break
            req = self.queue.popleft()
            if req.pages_needed > self.total_pages:
                # pool shrank below this request's footprint after it
                # was queued — permanently unfittable; reject instead
                # of requeueing forever (deadlock under shrink faults)
                self.reject(
                    req, "infeasible_pages",
                    f"needs {req.pages_needed} pages, pool shrank to "
                    f"{self.total_pages}")
                continue
            if req.pages_needed <= self.free_pages:
                self.slots[lane].request = req
                req.lane = lane
                req.started_step = self.step_idx
                req.phase = "prefilling"
                self.free_pages -= req.pages_needed
                self.bindings.append({
                    "rid": req.rid, "lane": lane,
                    "admitted_step": self.step_idx,
                    "released_step": -1})
                admitted.append(req)
            else:
                requeue.append(req)
                skips += 1
        for r in reversed(requeue):
            self.queue.appendleft(r)
        return admitted

    def complete(self, req: Request, status: str = "ok",
                 error: Optional[RequestError] = None) -> None:
        """Release a live request's slot and pages with a terminal
        `status` (engine-driven: "ok" on EOS/budget; "failed" /
        "cancelled" / "timeout" when the engine quarantines or reaps a
        lane — pages release either way, the stream keeps serving)."""
        assert req.lane >= 0 and self.slots[req.lane].request is req, req
        assert status in TERMINAL_STATUSES, status
        for b in reversed(self.bindings):
            if b["rid"] == req.rid and b["released_step"] < 0:
                b["released_step"] = self.step_idx
                break
        self.slots[req.lane].request = None
        self.free_pages += req.pages_needed
        req.finished_step = self.step_idx
        req.finished_at = time.time()
        req.phase = "done"
        req.lane = -1
        req.status = status
        req.error = error
        self.completed.append(req)

    def resize_pool(self, delta: int) -> int:
        """Grow (+) or shrink (-) the page pool by `delta` pages — the
        scheduler half of a PoolFault. Reserved pages stay reserved:
        a shrink can drive `free_pages` negative, which simply stalls
        admission until completions release enough pages (admission
        requires `pages_needed <= free_pages`). The pool floor is 0.
        Returns the delta actually applied."""
        delta = int(delta)
        if self.total_pages + delta < 0:
            delta = -self.total_pages
        self.total_pages += delta
        self.free_pages += delta
        return delta

    def live_requests(self) -> List[Request]:
        """The requests currently bound to slots, in lane order."""
        return [s.request for s in self.slots if s.request is not None]

    # ------------------------------------------------------------------ #
    def device_view(self) -> DeviceView:
        """Export the per-slot arrays the fused serve chunk carries
        (active/remaining/rids/prompt_len/prefilled + lane bindings)."""
        n = len(self.slots)
        active = np.zeros((n,), bool)
        remaining = np.zeros((n,), np.int32)
        rids = np.full((n,), -1, np.int32)
        prompt_len = np.zeros((n,), np.int32)
        prefilled = np.zeros((n,), np.int32)
        lane_of: Dict[int, int] = {}
        for i, s in enumerate(self.slots):
            r = s.request
            if r is None:
                continue
            active[i] = True
            remaining[i] = r.max_new_tokens - r.generated
            rids[i] = r.rid
            prompt_len[i] = r.prompt_len
            prefilled[i] = r.prefilled
            lane_of[r.rid] = i
        return DeviceView(active=active, remaining=remaining, rids=rids,
                          prompt_len=prompt_len, prefilled=prefilled,
                          lane_of=lane_of)

    @property
    def has_work(self) -> bool:
        """Whether anything is queued or still live in a slot."""
        return bool(self.queue) or any(not s.free for s in self.slots)

    # ------------------------------------------------------------------ #
    def step(self) -> List[Request]:
        """Behavioural simulation: advance one decode step; returns the
        active requests. (The real engine drives admit/complete itself.)"""
        self.admit()
        active = []
        for s in self.slots:
            r = s.request
            if r is None:
                continue
            r.generated += 1
            if r.generated >= r.max_new_tokens:
                self.complete(r)
            else:
                active.append(r)
        self.step_idx += 1
        return active

    # ------------------------------------------------------------------ #
    def utilization(self) -> float:
        """Fraction of batch slots holding a live request."""
        live = sum(0 if s.free else 1 for s in self.slots)
        return live / len(self.slots)

    def page_pressure(self) -> float:
        """Fraction of the KV page pool currently reserved (1.0 when a
        shrink fault has emptied the pool entirely)."""
        if self.total_pages <= 0:
            return 1.0
        return 1.0 - self.free_pages / self.total_pages
