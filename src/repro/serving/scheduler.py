"""Request scheduler: admission + continuous-batching bookkeeping.

Serving at scale needs more than a decode loop: requests arrive with
different prompt lengths and budgets, finish at different times, and
their KV pages must be reclaimed. This scheduler keeps a fixed-size
batch of live slots over the engine's paged cache:

  * admission — a request is admitted when a batch slot AND enough free
    logical pages exist (prompt + expected decode length);
  * completion — finished slots release their pages; the next queued
    request is admitted without stopping the batch (continuous
    batching, Sarathi/vLLM-style at step granularity);
  * fairness — FIFO with a starvation bound (max_skips).

The scheduler is pure control plane: it never touches arrays. It is
exercised by tests/test_scheduler.py and examples/serve_loop.py.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional


@dataclasses.dataclass
class Request:
    rid: int
    prompt_len: int
    max_new_tokens: int
    arrived_step: int = 0
    started_step: int = -1
    finished_step: int = -1
    generated: int = 0

    @property
    def pages_needed(self) -> int:
        return -(-(self.prompt_len + self.max_new_tokens) // 16)


@dataclasses.dataclass
class SlotState:
    request: Optional[Request] = None

    @property
    def free(self) -> bool:
        return self.request is None


class ContinuousBatcher:
    def __init__(self, num_slots: int, total_pages: int,
                 max_skips: int = 8):
        self.slots: List[SlotState] = [SlotState() for _ in range(num_slots)]
        self.total_pages = total_pages
        self.free_pages = total_pages
        self.queue: Deque[Request] = deque()
        self.max_skips = max_skips
        self.step_idx = 0
        self.completed: List[Request] = []

    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        req.arrived_step = self.step_idx
        self.queue.append(req)

    def _admit(self) -> None:
        skips = 0
        requeue: List[Request] = []
        while self.queue and skips <= self.max_skips:
            slot = next((s for s in self.slots if s.free), None)
            if slot is None:
                break
            req = self.queue.popleft()
            if req.pages_needed <= self.free_pages:
                slot.request = req
                req.started_step = self.step_idx
                self.free_pages -= req.pages_needed
            else:
                requeue.append(req)
                skips += 1
        for r in reversed(requeue):
            self.queue.appendleft(r)

    # ------------------------------------------------------------------ #
    def step(self) -> List[Request]:
        """Advance one decode step; returns the active requests."""
        self._admit()
        active = []
        for s in self.slots:
            r = s.request
            if r is None:
                continue
            r.generated += 1
            if r.generated >= r.max_new_tokens:
                r.finished_step = self.step_idx
                self.completed.append(r)
                self.free_pages += r.pages_needed
                s.request = None
            else:
                active.append(r)
        self.step_idx += 1
        return active

    # ------------------------------------------------------------------ #
    def utilization(self) -> float:
        live = sum(0 if s.free else 1 for s in self.slots)
        return live / len(self.slots)

    def page_pressure(self) -> float:
        return 1.0 - self.free_pages / self.total_pages
