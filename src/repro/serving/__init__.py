from repro.serving.engine import ServingEngine, EngineConfig, StepStats
from repro.serving.policies import (
    DevicePolicy, make_policy, policy_names, register,
)
from repro.serving.sampling import SamplingConfig
from repro.serving.scheduler import ContinuousBatcher, Request

__all__ = ["ServingEngine", "EngineConfig", "StepStats", "SamplingConfig",
           "ContinuousBatcher", "Request", "DevicePolicy", "make_policy",
           "policy_names", "register"]
