"""Live serving stack: the fused two-tier decode engine, its pluggable
device placement policies, continuous-batching scheduler, on-device
sampling, deterministic fault-injection plane, and the telemetry bridge
to the placement simulator. See EXPERIMENTS.md (§Fused-engine through
§Fault-injection) for architecture."""

from repro.serving.engine import (
    ServingEngine, EngineConfig, ServeReport, StepStats,
)
from repro.serving.faults import (
    FaultPlane, MigrationFault, PoisonFault, PoolFault, TierFault,
)
from repro.serving.policies import (
    DevicePolicy, make_policy, policy_names, register,
)
from repro.serving.sampling import SamplingConfig
from repro.serving.scheduler import (
    ContinuousBatcher, Request, RequestError, TERMINAL_STATUSES,
)
from repro.serving.slo import (
    SLOPolicy, SLOTarget, score_goodput,
)

__all__ = ["ServingEngine", "EngineConfig", "ServeReport", "StepStats",
           "SamplingConfig", "ContinuousBatcher", "Request",
           "RequestError", "TERMINAL_STATUSES", "DevicePolicy",
           "make_policy", "policy_names", "register", "FaultPlane",
           "TierFault", "MigrationFault", "PoolFault", "PoisonFault",
           "SLOPolicy", "SLOTarget", "score_goodput"]
