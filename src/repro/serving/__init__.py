"""Live serving stack: the fused two-tier decode engine, its pluggable
device placement policies, continuous-batching scheduler, on-device
sampling, and the telemetry bridge to the placement simulator. See
EXPERIMENTS.md (§Fused-engine through §Serve-trace) for architecture."""

from repro.serving.engine import ServingEngine, EngineConfig, StepStats
from repro.serving.policies import (
    DevicePolicy, make_policy, policy_names, register,
)
from repro.serving.sampling import SamplingConfig
from repro.serving.scheduler import ContinuousBatcher, Request

__all__ = ["ServingEngine", "EngineConfig", "StepStats", "SamplingConfig",
           "ContinuousBatcher", "Request", "DevicePolicy", "make_policy",
           "policy_names", "register"]
