from repro.serving.engine import ServingEngine, EngineConfig, StepStats

__all__ = ["ServingEngine", "EngineConfig", "StepStats"]
