"""jit-safe token sampling for the fused decode loop.

The sampler is a STATIC configuration: `make_sampler(cfg)` returns a
pure function `(logits [B, V], keys [B]) -> tokens [B]` that is traced
into the fused step, so changing the sampling config recompiles the
serve loop (once) but sampling itself never leaves the device.

Greedy decoding is the zero-temperature special case and compiles to a
plain argmax — bitwise identical to `ServingEngine.generate`'s greedy
path, which is what the single-request parity tests pin.

Per-slot PRNG keys are threaded through `lax.scan` by the caller (see
`ServingEngine.serve`): each batch lane samples with its own key chain
rooted at `lane_key(root, rid)`, so a request's tokens depend only on
(its key, its logits) — reproducible regardless of which other
requests share the batch. The chain advances once per fused step
(`split_lanes`); a lane consumes its step subkey either for a decode
sample or — at the step where chunked prefill crosses prompt_len — for
the request's FIRST token, which is sampled on device from the last
prompt position's logits (TTFT is a device event, not a host one).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def lane_key(root: jax.Array, rid) -> jax.Array:
    """Root of a request's per-lane sampling chain: derived from
    (serve seed, request id) only, never from slot index or batch
    company. rid may be a traced int32 scalar — one compile serves
    every request."""
    return jax.random.fold_in(root, rid)


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    """Static sampling knobs baked into the fused serve executable.

    The default (temperature 0) is greedy argmax — bitwise the
    `generate` path; any change recompiles the serve loop once."""

    #: 0.0 = greedy argmax (the exact `generate` path)
    temperature: float = 0.0
    #: keep only the k most likely tokens (0 = off)
    top_k: int = 0
    #: nucleus sampling: keep the smallest set of tokens whose
    #: cumulative probability reaches top_p (1.0 = off)
    top_p: float = 1.0


def split_lanes(keys: jax.Array):
    """Advance per-lane key chains: [B] keys -> (next [B], subkeys [B])."""
    pairs = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
    return pairs[:, 0], pairs[:, 1]


def _top_k_filter(logits: jax.Array, k: int) -> jax.Array:
    kth = jax.lax.top_k(logits, k)[0][..., -1:]
    return jnp.where(logits >= kth, logits, -jnp.inf)


def _top_p_filter(logits: jax.Array, top_p: float) -> jax.Array:
    """Mask tokens outside the nucleus. Keeps every token whose
    cumulative probability BEFORE it is < top_p, so at least the most
    likely token always survives."""
    sorted_desc = -jnp.sort(-logits, axis=-1)
    probs = jax.nn.softmax(sorted_desc, axis=-1)
    cum_before = jnp.cumsum(probs, axis=-1) - probs
    keep = cum_before < top_p
    thresh = jnp.min(jnp.where(keep, sorted_desc, jnp.inf), axis=-1,
                     keepdims=True)
    return jnp.where(logits >= thresh, logits, -jnp.inf)


def make_sampler(cfg: SamplingConfig) -> Callable:
    """Build `(logits [B, V], keys [B]) -> tokens [B] int32`."""
    if cfg.temperature <= 0.0:
        def greedy(logits, keys):
            del keys
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return greedy

    def sample(logits, keys):
        x = logits.astype(jnp.float32) / cfg.temperature
        if cfg.top_k > 0:
            x = _top_k_filter(x, cfg.top_k)
        if cfg.top_p < 1.0:
            x = _top_p_filter(x, cfg.top_p)
        draw = jax.vmap(lambda key, row: jax.random.categorical(key, row))
        return draw(keys, x).astype(jnp.int32)

    return sample
