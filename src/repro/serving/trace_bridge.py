"""Live-telemetry -> simulator bridge: score the fused engine against
the paper's upper bound.

The paper "derives a theoretical upper bound, revealing substantial
headroom for runtime optimization" — this module makes that headroom a
number the LIVE engine reports. With `EngineConfig.trace_telemetry` the
fused step emits, per decode step, lane 0's page read set and read-time
placement ([L, P] each). `collect` stacks those chunks into a
`TelemetryRecord`; from it the bridge

  1. prices the live policy's ACHIEVED placement with the identical
     Eq.(1)-(5) model the simulator uses (`live_traffic` — reads from
     the captured access x tier, migrations from tier transitions,
     writes by the newest page's tier, weights excluded per the
     paper's convention, see EXPERIMENTS.md §Repro);
  2. replays the SAME access pattern through the host simulator's
     oracle policies (`layer_trace` -> `core.simulator`): the
     SA-guided upper bound and the Belady oracle, plus the static
     baseline, each per layer under the live engine's own per-layer
     HBM page budget;
  3. aggregates per-layer traffic per step (layers execute within one
     decode step, so their volumes add before the Eq.(2) max — the
     same aggregation the engine's own telemetry uses) and reports
     `bound_fraction = T_sa / T_live`: 1.0 means the live policy
     matched the foresight bound, smaller means headroom remains.

The static baseline doubles as the bridge's self-test: live static
placement and simulated static placement are the same deterministic
rule, so their scores must agree to float tolerance
(tests/test_trace_bridge.py pins this).

Serve streams (PR 5) go through the same loop under continuous
batching, where placement pressure actually comes from lane churn and
admission: with `trace_telemetry` the mixed prefill+decode chunk emits
EVERY lane's read set + read-time placement (decode plane only, so
prefill writes never enter the access model), stamped with the chunk's
lane->request bindings. `collect_serve` stacks the chunks,
`attribute` stitches each REQUEST's rows — lane indices are reused
across admissions, so identity comes from the scheduler's bindings,
never the lane number — into a per-request `TelemetryRecord`, and
`score_serve` prices both the aggregate stream (per-lane traffic
summed per step before the Eq. (2) max, exactly how per-layer traffic
already aggregates) and each request in isolation against SA / Belady
/ static under the live per-layer HBM budget. See
EXPERIMENTS.md §Serve-trace.

Telemetry read back from a meshed engine (EXPERIMENTS.md
§Mesh-sharding) arrives here as plain host numpy exactly as in the
single-device case — stats outputs are unsharded at the chunk
boundary — so the bridge needs no mesh awareness. Scores may differ
from a single-device run only within the parity tolerances (mesh
float reassociation can flip individual migration choices); the
parity suite pins hit/bound fractions to 0.02/0.05.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.experiment import Workload, run_strategy
from repro.core.latency_model import StepTraffic, step_latency
from repro.core.placement.base import DRAM, HBM, UNALLOC
from repro.core.traces import Trace


@dataclasses.dataclass
class TelemetryRecord:
    """One lane's decode stream as the simulator sees the world.

    access[s, l, p]: layer l read logical page p at decode step s.
    tier[s, l, p]:   page p's placement when step s's reads ran
                     (post-decode, pre-migration): HBM / DRAM /
                     UNALLOC tier codes from `core.placement.base`.
    moves[s]:        (promotes, demotes) the planner executed at step
                     s, summed over layers and lanes (cross-check for
                     the per-layer transition counts).
    """

    access: np.ndarray       # bool  [S, L, P]
    tier: np.ndarray         # int8  [S, L, P]
    moves: np.ndarray        # int32 [S, 2]
    page_tokens: int
    prompt_len: int          # tokens cached when the stream started
    page_bytes: int          # per-layer bytes of one page
    hbm_pages: int           # per-layer HBM slots (the live budget)

    @property
    def num_steps(self) -> int:
        """Decode steps captured in this record."""
        return self.access.shape[0]

    @property
    def num_layers(self) -> int:
        """Attention layers captured per step."""
        return self.access.shape[1]

    @property
    def num_pages(self) -> int:
        """Logical page slots per layer (the cache's max_pages)."""
        return self.access.shape[2]


def collect(engine) -> TelemetryRecord:
    """Stack an engine's captured telemetry chunks into one record.

    Drive pattern: construct the engine with
    `EngineConfig(trace_telemetry=True, ...)`, `start(prompts)` (which
    resets the log), then any mix of `step`/`run`/`generate`.
    """
    if not getattr(engine, "_trace_log", None):
        raise ValueError(
            "no trace telemetry captured — construct the engine with "
            "EngineConfig(trace_telemetry=True), start() it, and drive "
            "step()/run()/generate() before collect()")
    base = np.concatenate([c[0] for c in engine._trace_log])
    access = np.concatenate([c[1] for c in engine._trace_log])
    tier = np.concatenate([c[2] for c in engine._trace_log])
    geo = engine.geo
    return TelemetryRecord(
        access=access.astype(bool), tier=tier.astype(np.int8),
        moves=base[:, 2:4].astype(np.int32),
        page_tokens=geo.page_tokens,
        prompt_len=int(engine._trace_prompt_len),
        page_bytes=int(geo.page_bytes()), hbm_pages=int(geo.hbm_pages))


def layer_trace(rec: TelemetryRecord, layer: int) -> Trace:
    """One layer's captured stream as a simulator `Trace`.

    Logical page ids, page birth steps, and the per-step access mask
    transfer 1:1 — the live engine's per-layer placement problem IS the
    simulator's single-request problem (same page axis, same
    `prompt_len + step` newest-page arithmetic).
    """
    S = rec.num_steps
    exists = rec.tier[:, layer] != UNALLOC                  # [S, P]
    born = np.where(exists.any(axis=0), exists.argmax(axis=0),
                    S + 1).astype(np.int32)
    access = rec.access[:, layer] & exists
    alive = born[None, :] <= np.arange(S)[:, None]
    sparsity = 1.0 - access.sum() / max(int(alive.sum()), 1)
    tr = Trace(access=access, page_born=born,
               page_tokens=rec.page_tokens, prompt_len=rec.prompt_len,
               decode_len=S, sparsity=float(sparsity))
    tr.validate()
    return tr


def layer_migrations(rec: TelemetryRecord, layer: int
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """(promotes[S], demotes[S]) for one layer, recovered from tier
    transitions: the migration applied at the end of step s is visible
    as step s+1's read-time placement (the final step's moves are
    unobservable and charged as zero — one step of slack out of S)."""
    t = rec.tier[:, layer]
    promote = (t[:-1] == DRAM) & (t[1:] == HBM)
    demote = (t[:-1] == HBM) & (t[1:] == DRAM)
    z = np.zeros((1,), np.int64)
    return (np.concatenate([promote.sum(axis=1), z]),
            np.concatenate([demote.sum(axis=1), z]))


def live_traffic(rec: TelemetryRecord) -> StepTraffic:
    """Per-step traffic volumes of the live stream, aggregated over
    layers, under the simulator's byte-accounting conventions (reads
    from the access x placement product, one appended token per layer
    per step charged to the newest page's tier, weights excluded)."""
    S, L, P = rec.access.shape
    hbm_hit = rec.access & (rec.tier == HBM)
    n_h = hbm_hit.sum(axis=(1, 2))
    n_e = rec.access.sum(axis=(1, 2)) - n_h
    m_in = np.zeros(S, np.int64)
    m_out = np.zeros(S, np.int64)
    for layer in range(L):
        p, d = layer_migrations(rec, layer)
        m_in += p
        m_out += d
    newest = np.minimum((rec.prompt_len + np.arange(S))
                        // rec.page_tokens, P - 1)           # [S]
    new_tier = rec.tier[np.arange(S)[:, None],
                        np.arange(L)[None, :],
                        newest[:, None]]                     # [S, L]
    bytes_per_token = rec.page_bytes / rec.page_tokens
    return StepTraffic.from_page_counts(
        n_hbm_read=n_h, n_dram_read=n_e, n_promote=m_in, n_demote=m_out,
        page_bytes=rec.page_bytes,
        h_write=(new_tier == HBM).sum(axis=1) * bytes_per_token,
        e_write=(new_tier == DRAM).sum(axis=1) * bytes_per_token)


def hit_fraction(rec: TelemetryRecord) -> float:
    """Fraction of page reads served from HBM over the whole stream."""
    reads = int(rec.access.sum())
    hits = int((rec.access & (rec.tier == HBM)).sum())
    return hits / reads if reads else 1.0


def score_headroom(rec: TelemetryRecord, spec, *,
                   oracles: Sequence[str] = ("sa", "belady"),
                   sa_cfg=None) -> Dict[str, float]:
    """Score a live stream against the simulator's bounds.

    Replays each oracle (plus the static baseline) per layer on the
    bridged traces under the live per-layer HBM budget, sums per-layer
    traffic per step, and prices everything with the identical Eq.(2)
    max. Returns a flat dict:

      live_total_s, live_hit_fraction, static_total_s, <oracle>_total_s,
      bound_fraction (= sa_total_s / live_total_s when "sa" is among
      the oracles), headroom_vs_static (= static_total_s / live_total_s
      — the live policy's speedup over never migrating; the SA bound's
      value of the same ratio is the paper's headline headroom).
    """
    live = live_traffic(rec)
    live_total = float(np.sum(step_latency(live, spec)))
    out: Dict[str, float] = {
        "steps": float(rec.num_steps),
        "live_total_s": live_total,
        "live_hit_fraction": hit_fraction(rec),
    }
    names = dict.fromkeys(tuple(oracles) + ("static",))   # ordered dedupe
    for name in names:
        agg = oracle_traffic(rec, name, spec, sa_cfg=sa_cfg)
        out[f"{name}_total_s"] = float(np.sum(step_latency(agg, spec)))
    if live_total > 0:
        if "sa" in oracles:
            out["bound_fraction"] = out["sa_total_s"] / live_total
        out["headroom_vs_static"] = out["static_total_s"] / live_total
    return out


def oracle_traffic(rec: TelemetryRecord, name: str, spec, *,
                   sa_cfg=None) -> StepTraffic:
    """Per-step traffic of oracle `name` replayed on `rec`'s bridged
    traces under the live per-layer HBM page budget, summed over layers
    (layers execute within one decode step, so their volumes add before
    the Eq. (2) max). The building block `score_headroom` and
    `score_serve` share, exposed so callers can re-aggregate across
    requests before pricing."""
    wl = Workload(bytes_per_token_layer=rec.page_bytes // rec.page_tokens,
                  num_layers=1)
    budget_bytes = float(rec.hbm_pages * rec.page_bytes)
    agg: Optional[StepTraffic] = None
    for layer in range(rec.num_layers):
        res = run_strategy(name, layer_trace(rec, layer), spec, wl,
                           budget_bytes, sa_cfg=sa_cfg)
        agg = res.step_traffic if agg is None else agg + res.step_traffic
    return agg


# --------------------------------------------------------------------------
# serve streams: capture, per-request stitching, and attribution scoring
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ServeTraceRecord:
    """A full continuous-batching serve stream's decode-plane telemetry.

    Per captured step s and batch lane b:

    access[s, l, b, p]:  layer l of lane b read logical page p while
                         DECODING at step s (prefilling / inactive
                         lanes contribute no reads — prefill writes are
                         outside the access model).
    tier[s, l, b, p]:    page p's read-time placement (post-decode,
                         pre-migration) — HBM / DRAM / UNALLOC codes.
    emitted[s, b]:       the token lane b decoded at step s, -1 if the
                         lane did not decode (prefilling, crossing, or
                         idle). The stitching predicate.
    first[s, b]:         the first token sampled at lane b's
                         prefill->decode crossing, -1 elsewhere
                         (a prefill-plane event, excluded from traces).
    rids[s, b]:          the request bound to lane b during step s's
                         chunk, -1 when the lane is free. Lane indices
                         are REUSED across admissions; this is the
                         identity channel.
    prompt_len[s, b]:    the bound request's prompt length in tokens.
    """

    access: np.ndarray       # bool  [S, L, B, P]
    tier: np.ndarray         # int8  [S, L, B, P]
    emitted: np.ndarray      # int32 [S, B]
    first: np.ndarray        # int32 [S, B]
    rids: np.ndarray         # int32 [S, B]
    prompt_len: np.ndarray   # int32 [S, B]
    page_tokens: int
    page_bytes: int          # per-layer bytes of one page
    hbm_pages: int           # per-layer HBM slots (the live budget)

    @property
    def num_steps(self) -> int:
        """Captured serve steps (prefill-only steps included)."""
        return self.access.shape[0]

    @property
    def num_lanes(self) -> int:
        """Batch lanes (serve slots) in the stream."""
        return self.access.shape[2]


@dataclasses.dataclass
class RequestAttribution:
    """One request's stitched slice of a serve stream.

    `record` is the request's decode stream in exactly the shape the
    single-stream bridge emits (so `layer_trace` / `live_traffic` /
    `score_headroom` apply verbatim); `rows` maps each of its steps
    back to the global serve step axis (for cross-request aggregation)
    and `lanes` names the lane it occupied there. `record.moves` is
    recovered from tier transitions — the planner's counts aggregate
    over lanes and cannot be attributed per request."""

    rid: int
    record: TelemetryRecord
    rows: np.ndarray         # int64 [S_r] global serve step indices
    lanes: np.ndarray        # int64 [S_r] lane occupied at each row


def collect_serve(engine) -> ServeTraceRecord:
    """Stack a serve stream's captured telemetry chunks into one record.

    Drive pattern: construct the engine with
    `EngineConfig(trace_telemetry=True, ...)` and call
    `serve(requests)`; each chunk boundary logs the chunk's read sets,
    placements, emitted/first tokens, and lane->request bindings
    (fixed within a chunk — admission happens only at boundaries).
    """
    log = getattr(engine, "_serve_trace_log", None)
    if not log:
        raise ValueError(
            "no serve trace telemetry captured — construct the engine "
            "with EngineConfig(trace_telemetry=True) and drive serve() "
            "before collect_serve()")
    def tile(chunk, row):
        n = chunk[0].shape[0]
        return np.broadcast_to(row, (n,) + row.shape)

    geo = engine.geo
    return ServeTraceRecord(
        access=np.concatenate([c[0] for c in log]).astype(bool),
        tier=np.concatenate([c[1] for c in log]).astype(np.int8),
        emitted=np.concatenate([c[2] for c in log]).astype(np.int32),
        first=np.concatenate([c[3] for c in log]).astype(np.int32),
        rids=np.concatenate([tile(c, c[4]) for c in log]).astype(np.int32),
        prompt_len=np.concatenate([tile(c, c[5])
                                   for c in log]).astype(np.int32),
        page_tokens=geo.page_tokens, page_bytes=int(geo.page_bytes()),
        hbm_pages=int(geo.hbm_pages))


def attribute(rec: ServeTraceRecord) -> List[RequestAttribution]:
    """Stitch each request's decode stream out of a serve record.

    A request's trace is the ordered set of (step, lane) cells where
    its lane DECODED (`emitted >= 0`) while bound to it (`rids`
    matches) — admission, the prefill phase, the first-token crossing,
    and reclaim all fall outside the predicate, so two requests reusing
    the same lane can never cross-contaminate: the earlier request's
    rows end before its release, the later one's begin after its own
    prefill, and the released lane's cleared page table (tier UNALLOC)
    never reaches either record. Requests that decoded zero steps
    (max_new_tokens == 1: only the crossing token) have no access
    pattern to score and are omitted. Ordered by first decode step.
    """
    decoded = rec.emitted >= 0                              # [S, B]
    out: List[RequestAttribution] = []
    for rid in np.unique(rec.rids[rec.rids >= 0]):
        mask = (rec.rids == rid) & decoded
        rows, lanes = np.nonzero(mask)
        if rows.size == 0:
            continue
        access = rec.access[rows, :, lanes]                 # [S_r, L, P]
        tier = rec.tier[rows, :, lanes]
        record = TelemetryRecord(
            access=access, tier=tier,
            moves=np.zeros((rows.size, 2), np.int32),
            page_tokens=rec.page_tokens,
            prompt_len=int(rec.prompt_len[rows[0], lanes[0]]),
            page_bytes=rec.page_bytes, hbm_pages=rec.hbm_pages)
        moves = np.zeros((rows.size, 2), np.int64)
        for layer in range(record.num_layers):
            p, d = layer_migrations(record, layer)
            moves[:, 0] += p
            moves[:, 1] += d
        record.moves = moves.astype(np.int32)
        out.append(RequestAttribution(rid=int(rid), record=record,
                                      rows=rows, lanes=lanes))
    out.sort(key=lambda a: int(a.rows[0]))
    return out


_TRAFFIC_FIELDS = ("h_read", "e_read", "h_write", "e_write",
                   "m_in", "m_out")


def _scatter(acc: Dict[str, np.ndarray], traffic: StepTraffic,
             rows: np.ndarray) -> None:
    """Add a request's per-step traffic into the global step axis."""
    for f in _TRAFFIC_FIELDS:
        val = np.broadcast_to(
            np.asarray(getattr(traffic, f), np.float64), rows.shape)
        acc[f][rows] += val


def score_serve(rec: ServeTraceRecord, spec, *,
                oracles: Sequence[str] = ("sa", "belady"),
                sa_cfg=None, report=None) -> Dict[str, object]:
    """Score a serve stream — aggregate and per request — against the
    simulator's bounds.

    Each attributed request is replayed per layer through the oracles
    (plus the static baseline) under the live per-layer HBM budget,
    exactly as `score_headroom` does for a single stream. Two views
    come out of the same replay:

      per request — the request's lane-private traffic priced in
        isolation (its own Eq. (2) max per step): `hit_fraction`,
        `bound_fraction`, and the oracle totals. This is the
        request-level attribution the ServeReport carries.
      aggregate — every request's per-step volumes scattered back onto
        the GLOBAL serve step axis and summed before the Eq. (2) max
        (lanes execute within one serve step, so their volumes add —
        the same aggregation per-layer traffic already gets). The
        aggregate `bound_fraction` is the paper's headroom under
        continuous batching.

    Returns {"aggregate": {...}, "requests": {rid: {...}}}. When
    `report` (a ServeReport) is given, stamps `report.request_scores`
    and `report.headroom` with the same dicts.

    Degraded streams score transparently: the telemetry a faulted
    serve run captured already reflects what actually happened —
    throttled migration commits, quarantined lanes' truncated traces,
    the placements a fallen-back (static-behaving) policy stopped
    improving — so the live totals here price the DEGRADED placement
    against the same bounds, which is the honest headroom under
    adversity. When the report carries degradation events
    (`ServeReport.events`, see `repro.serving.faults`), their count
    and the policy-fallback flag are stamped into the aggregate so a
    scored stream names the faults that shaped it.
    """
    atts = attribute(rec)
    S = rec.num_steps
    names = dict.fromkeys(tuple(oracles) + ("static",))   # ordered dedupe
    acc = {"live": {f: np.zeros(S) for f in _TRAFFIC_FIELDS}}
    for name in names:
        acc[name] = {f: np.zeros(S) for f in _TRAFFIC_FIELDS}

    requests: Dict[int, Dict[str, float]] = {}
    for att in atts:
        r = att.record
        live = live_traffic(r)
        _scatter(acc["live"], live, att.rows)
        live_total = float(np.sum(step_latency(live, spec)))
        sc: Dict[str, float] = {
            "steps": float(r.num_steps),
            "live_total_s": live_total,
            "hit_fraction": hit_fraction(r),
        }
        for name in names:
            tr = oracle_traffic(r, name, spec, sa_cfg=sa_cfg)
            _scatter(acc[name], tr, att.rows)
            sc[f"{name}_total_s"] = float(np.sum(step_latency(tr, spec)))
        if live_total > 0:
            if "sa" in oracles:
                sc["bound_fraction"] = sc["sa_total_s"] / live_total
            sc["headroom_vs_static"] = sc["static_total_s"] / live_total
        requests[att.rid] = sc

    reads = int(rec.access.sum())
    hits = int((rec.access & (rec.tier == HBM)).sum())
    agg: Dict[str, float] = {
        "steps": float(S),
        "decode_steps": float(int((rec.emitted >= 0).any(axis=1).sum())),
        "requests": float(len(atts)),
        "live_hit_fraction": hits / reads if reads else 1.0,
        "live_total_s": float(np.sum(step_latency(
            StepTraffic(**acc["live"]), spec))),
    }
    for name in names:
        agg[f"{name}_total_s"] = float(np.sum(step_latency(
            StepTraffic(**acc[name]), spec)))
    if agg["live_total_s"] > 0:
        if "sa" in oracles:
            agg["bound_fraction"] = agg["sa_total_s"] / agg["live_total_s"]
        agg["headroom_vs_static"] = \
            agg["static_total_s"] / agg["live_total_s"]

    if report is not None:
        if getattr(report, "events", None):
            agg["fault_events"] = float(len(report.events))
            agg["policy_fallback"] = float(any(
                e.get("kind") == "policy_fallback"
                for e in report.events))
        report.request_scores.update(requests)
        report.headroom.update(agg)
    return {"aggregate": agg, "requests": requests}


def goodput_curve(rec: ServeTraceRecord, spec, report, policy, *,
                  scales: Sequence[float] = (0.25, 0.5, 1.0, 2.0,
                                             4.0, 8.0),
                  latency: str = "modeled",
                  sa_cfg=None) -> Dict[str, object]:
    """Goodput-under-SLO curve for one served stream, scored against
    the live SA bound.

    Runs `score_serve` once (stamping `report.request_scores`, which
    the modeled-latency goodput view reads), then scores the report's
    terminal statuses + latencies against the SLO `policy` at each
    target scale (`repro.serving.slo.score_goodput`). The curve pairs
    with the aggregate `bound_fraction`: a policy can only convert
    placement headroom into goodput at the scales where latency — not
    admission — is the binding constraint, which is exactly what the
    per-policy curves in `BENCH_engine.json["rows"]["goodput"]` show
    (see `benchmarks/perf_engine.py --goodput-sweep`).
    """
    from repro.serving.slo import score_goodput

    scored = score_serve(rec, spec, report=report, sa_cfg=sa_cfg)
    curve = [score_goodput(report, policy, scale=s, latency=latency)
             for s in scales]
    return {"aggregate": scored["aggregate"], "curve": curve}
