"""Live-telemetry -> simulator bridge: score the fused engine against
the paper's upper bound.

The paper "derives a theoretical upper bound, revealing substantial
headroom for runtime optimization" — this module makes that headroom a
number the LIVE engine reports. With `EngineConfig.trace_telemetry` the
fused step emits, per decode step, lane 0's page read set and read-time
placement ([L, P] each). `collect` stacks those chunks into a
`TelemetryRecord`; from it the bridge

  1. prices the live policy's ACHIEVED placement with the identical
     Eq.(1)-(5) model the simulator uses (`live_traffic` — reads from
     the captured access x tier, migrations from tier transitions,
     writes by the newest page's tier, weights excluded per the
     paper's convention, see EXPERIMENTS.md §Repro);
  2. replays the SAME access pattern through the host simulator's
     oracle policies (`layer_trace` -> `core.simulator`): the
     SA-guided upper bound and the Belady oracle, plus the static
     baseline, each per layer under the live engine's own per-layer
     HBM page budget;
  3. aggregates per-layer traffic per step (layers execute within one
     decode step, so their volumes add before the Eq.(2) max — the
     same aggregation the engine's own telemetry uses) and reports
     `bound_fraction = T_sa / T_live`: 1.0 means the live policy
     matched the foresight bound, smaller means headroom remains.

The static baseline doubles as the bridge's self-test: live static
placement and simulated static placement are the same deterministic
rule, so their scores must agree to float tolerance
(tests/test_trace_bridge.py pins this).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.experiment import Workload, run_strategy
from repro.core.latency_model import StepTraffic, step_latency
from repro.core.placement.base import DRAM, HBM, UNALLOC
from repro.core.traces import Trace


@dataclasses.dataclass
class TelemetryRecord:
    """One lane's decode stream as the simulator sees the world.

    access[s, l, p]: layer l read logical page p at decode step s.
    tier[s, l, p]:   page p's placement when step s's reads ran
                     (post-decode, pre-migration): HBM / DRAM /
                     UNALLOC tier codes from `core.placement.base`.
    moves[s]:        (promotes, demotes) the planner executed at step
                     s, summed over layers and lanes (cross-check for
                     the per-layer transition counts).
    """

    access: np.ndarray       # bool  [S, L, P]
    tier: np.ndarray         # int8  [S, L, P]
    moves: np.ndarray        # int32 [S, 2]
    page_tokens: int
    prompt_len: int          # tokens cached when the stream started
    page_bytes: int          # per-layer bytes of one page
    hbm_pages: int           # per-layer HBM slots (the live budget)

    @property
    def num_steps(self) -> int:
        return self.access.shape[0]

    @property
    def num_layers(self) -> int:
        return self.access.shape[1]

    @property
    def num_pages(self) -> int:
        return self.access.shape[2]


def collect(engine) -> TelemetryRecord:
    """Stack an engine's captured telemetry chunks into one record.

    Drive pattern: construct the engine with
    `EngineConfig(trace_telemetry=True, ...)`, `start(prompts)` (which
    resets the log), then any mix of `step`/`run`/`generate`.
    """
    if not getattr(engine, "_trace_log", None):
        raise ValueError(
            "no trace telemetry captured — construct the engine with "
            "EngineConfig(trace_telemetry=True), start() it, and drive "
            "step()/run()/generate() before collect()")
    base = np.concatenate([c[0] for c in engine._trace_log])
    access = np.concatenate([c[1] for c in engine._trace_log])
    tier = np.concatenate([c[2] for c in engine._trace_log])
    geo = engine.geo
    return TelemetryRecord(
        access=access.astype(bool), tier=tier.astype(np.int8),
        moves=base[:, 2:4].astype(np.int32),
        page_tokens=geo.page_tokens,
        prompt_len=int(engine._trace_prompt_len),
        page_bytes=int(geo.page_bytes()), hbm_pages=int(geo.hbm_pages))


def layer_trace(rec: TelemetryRecord, layer: int) -> Trace:
    """One layer's captured stream as a simulator `Trace`.

    Logical page ids, page birth steps, and the per-step access mask
    transfer 1:1 — the live engine's per-layer placement problem IS the
    simulator's single-request problem (same page axis, same
    `prompt_len + step` newest-page arithmetic).
    """
    S = rec.num_steps
    exists = rec.tier[:, layer] != UNALLOC                  # [S, P]
    born = np.where(exists.any(axis=0), exists.argmax(axis=0),
                    S + 1).astype(np.int32)
    access = rec.access[:, layer] & exists
    alive = born[None, :] <= np.arange(S)[:, None]
    sparsity = 1.0 - access.sum() / max(int(alive.sum()), 1)
    tr = Trace(access=access, page_born=born,
               page_tokens=rec.page_tokens, prompt_len=rec.prompt_len,
               decode_len=S, sparsity=float(sparsity))
    tr.validate()
    return tr


def layer_migrations(rec: TelemetryRecord, layer: int
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """(promotes[S], demotes[S]) for one layer, recovered from tier
    transitions: the migration applied at the end of step s is visible
    as step s+1's read-time placement (the final step's moves are
    unobservable and charged as zero — one step of slack out of S)."""
    t = rec.tier[:, layer]
    promote = (t[:-1] == DRAM) & (t[1:] == HBM)
    demote = (t[:-1] == HBM) & (t[1:] == DRAM)
    z = np.zeros((1,), np.int64)
    return (np.concatenate([promote.sum(axis=1), z]),
            np.concatenate([demote.sum(axis=1), z]))


def live_traffic(rec: TelemetryRecord) -> StepTraffic:
    """Per-step traffic volumes of the live stream, aggregated over
    layers, under the simulator's byte-accounting conventions (reads
    from the access x placement product, one appended token per layer
    per step charged to the newest page's tier, weights excluded)."""
    S, L, P = rec.access.shape
    hbm_hit = rec.access & (rec.tier == HBM)
    n_h = hbm_hit.sum(axis=(1, 2))
    n_e = rec.access.sum(axis=(1, 2)) - n_h
    m_in = np.zeros(S, np.int64)
    m_out = np.zeros(S, np.int64)
    for layer in range(L):
        p, d = layer_migrations(rec, layer)
        m_in += p
        m_out += d
    newest = np.minimum((rec.prompt_len + np.arange(S))
                        // rec.page_tokens, P - 1)           # [S]
    new_tier = rec.tier[np.arange(S)[:, None],
                        np.arange(L)[None, :],
                        newest[:, None]]                     # [S, L]
    bytes_per_token = rec.page_bytes / rec.page_tokens
    return StepTraffic.from_page_counts(
        n_hbm_read=n_h, n_dram_read=n_e, n_promote=m_in, n_demote=m_out,
        page_bytes=rec.page_bytes,
        h_write=(new_tier == HBM).sum(axis=1) * bytes_per_token,
        e_write=(new_tier == DRAM).sum(axis=1) * bytes_per_token)


def hit_fraction(rec: TelemetryRecord) -> float:
    """Fraction of page reads served from HBM over the whole stream."""
    reads = int(rec.access.sum())
    hits = int((rec.access & (rec.tier == HBM)).sum())
    return hits / reads if reads else 1.0


def score_headroom(rec: TelemetryRecord, spec, *,
                   oracles: Sequence[str] = ("sa", "belady"),
                   sa_cfg=None) -> Dict[str, float]:
    """Score a live stream against the simulator's bounds.

    Replays each oracle (plus the static baseline) per layer on the
    bridged traces under the live per-layer HBM budget, sums per-layer
    traffic per step, and prices everything with the identical Eq.(2)
    max. Returns a flat dict:

      live_total_s, live_hit_fraction, static_total_s, <oracle>_total_s,
      bound_fraction (= sa_total_s / live_total_s when "sa" is among
      the oracles), headroom_vs_static (= static_total_s / live_total_s
      — the live policy's speedup over never migrating; the SA bound's
      value of the same ratio is the paper's headline headroom).
    """
    live = live_traffic(rec)
    live_total = float(np.sum(step_latency(live, spec)))
    out: Dict[str, float] = {
        "steps": float(rec.num_steps),
        "live_total_s": live_total,
        "live_hit_fraction": hit_fraction(rec),
    }
    wl = Workload(bytes_per_token_layer=rec.page_bytes // rec.page_tokens,
                  num_layers=1)
    budget_bytes = float(rec.hbm_pages * rec.page_bytes)
    traces = [layer_trace(rec, layer) for layer in range(rec.num_layers)]
    names = dict.fromkeys(tuple(oracles) + ("static",))   # ordered dedupe
    for name in names:
        agg: Optional[StepTraffic] = None
        for tr in traces:
            res = run_strategy(name, tr, spec, wl, budget_bytes,
                               sa_cfg=sa_cfg)
            agg = res.step_traffic if agg is None \
                else agg + res.step_traffic
        out[f"{name}_total_s"] = float(np.sum(step_latency(agg, spec)))
    if live_total > 0:
        if "sa" in oracles:
            out["bound_fraction"] = out["sa_total_s"] / live_total
        out["headroom_vs_static"] = out["static_total_s"] / live_total
    return out
